"""Table 7 — suppressing dominant clusters (paper §5.1).

The exact query of the paper, on the production-like corpus: baseline top-5
should come from the dominant DESCRIPTIVE cluster; two suppress: tokens
should surface the buried IMPLEMENTATION cluster.
"""

from __future__ import annotations

from benchmarks.common import NOW, emit, production_db
from repro.core.materializer import Materializer

BASE_SQL = (
    "SELECT v.id, v.score FROM vec_ops("
    "'similar:how the system works architecture diverse',"
    "'SELECT id FROM messages WHERE type = ''assistant'' "
    "AND length(content) > 300') v ORDER BY v.score DESC LIMIT 5"
)

SUP_SQL = (
    "SELECT v.id, v.score FROM vec_ops("
    "'similar:how the system works architecture diverse "
    "suppress:website landing page design tagline "
    "suppress:documentation readme community post',"
    "'SELECT id FROM messages WHERE type = ''assistant'' "
    "AND length(content) > 300') v ORDER BY v.score DESC LIMIT 5"
)


def run() -> None:
    conn, cache, chunks, emb = production_db()
    cluster_of = {c.id: c.cluster for c in chunks}
    mz = Materializer(conn, cache, now=NOW)

    _, base = mz.execute(BASE_SQL)
    _, sup = mz.execute(SUP_SQL)
    base_impl = sum(cluster_of[r[0]] == "implementation" for r in base)
    sup_impl = sum(cluster_of[r[0]] == "implementation" for r in sup)
    overlap = len({r[0] for r in base} & {r[0] for r in sup})

    emit("table7/baseline_impl_in_top5", 0.0,
         f"{base_impl}/5 scores={[round(r[1],2) for r in base]}")
    emit("table7/suppressed_impl_in_top5", 0.0,
         f"{sup_impl}/5 scores={[round(r[1],2) for r in sup]}")
    emit("table7/overlap_base_vs_suppressed", 0.0, f"{overlap}/5")
    # paper: suppression surfaces the buried cluster; none of the suppressed
    # results appeared in the baseline
    assert sup_impl > base_impl, (sup_impl, base_impl)
