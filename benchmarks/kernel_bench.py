"""Beyond-paper engine benchmarks: fused folding + batched serving.

1. reference (1 matvec per direction, the paper's numpy engine) vs fused
   (2 effective matvecs regardless of modulation count) — corpus passes drop
   from 1+k to <=2 (DESIGN.md §2.1).
2. batched query panel: (d,B) GEMM amortizes the corpus stream B ways —
   the serving-engine arithmetic-intensity win.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import NOW, emit, production_db, timed
from repro.core import modulations as M
from repro.core.grammar import parse
from repro.kernels.pem_score.ops import fold_plans


def run() -> None:
    conn, cache, chunks, emb = production_db()
    mat = cache.matrix
    days = np.maximum((NOW - cache.timestamps) / 86400.0, 0).astype(np.float32)

    for n_sup in (1, 2, 4, 8):
        tokens = "similar:system architecture decay:30 " + " ".join(
            f"suppress:noise topic {i}" for i in range(n_sup))
        plan = parse(tokens, emb, cache.embeddings_for_ids)
        t_ref = timed(lambda: M.modulate_scores(mat, days, plan), repeats=3)
        t_fus = timed(lambda: M.fused_modulate_scores(mat, days, plan), repeats=3)
        emit(f"kernel/ref_{n_sup}sup", t_ref, f"directions={plan.n_directions}")
        emit(f"kernel/fused_{n_sup}sup", t_fus,
             f"speedup={t_ref/max(t_fus,1e-9):.2f}x")

    # batched panel: B queries in one GEMM vs B sequential searches
    B = 32
    plans = [parse(f"similar:topic {i} suppress:other stuff decay:30", emb)
             for i in range(B)]
    q_pre, q_sup = fold_plans(plans)
    dec = (1.0 / (1.0 + days / 30.0)).astype(np.float32)

    def batched():
        return dec[:, None] * (mat @ q_pre) + mat @ q_sup

    def sequential():
        return [M.fused_modulate_scores(mat, days, p) for p in plans]

    t_b = timed(batched, repeats=3)
    t_s = timed(sequential, repeats=3)
    emit("kernel/batched_panel_32q", t_b, f"per-query={t_b/B*1e3:.2f}ms")
    emit("kernel/sequential_32q", t_s,
         f"batching_speedup={t_s/max(t_b,1e-9):.2f}x")
