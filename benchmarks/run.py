"""Benchmark harness: one function per paper table + beyond-paper engine
benches. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [table2 table3 ...]
    FLEX_BENCH_SCALE=0.02 ... (smoke scale)
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (behavioral, case_study, kernel_bench, latency,
                            pem_snapshot, scaling)

    suites = {
        "table2": latency.run,
        # table3 (SQL pre-filtering) folded into the snapshot's gated
        # prefilter_backends scenario; the standalone suite runs it alone
        "table3": pem_snapshot.run_prefilter,
        "table4": scaling.run,
        "table5+6": behavioral.run,
        "table7": case_study.run,
        "kernel": kernel_bench.run,
        "pem": pem_snapshot.run,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in want:
        key = name if name in suites else {"table5": "table5+6", "table6": "table5+6"}.get(name)
        if key is None:
            raise SystemExit(f"unknown suite {name}; known: {list(suites)}")
        t0 = time.time()
        suites[key]()
        print(f"# suite {key} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
