"""PEM latency snapshot -> BENCH_pem.json (the perf-trajectory anchor).

Times the Phase-2 hot path (composed-plan scoring + top-k selection)
through every cheap ExecutionBackend at the paper's headline corpus scale
(``FLEX_BENCH_SCALE`` shrinks it for smoke runs), and writes a JSON
snapshot at the repo root so successive PRs can diff the trajectory:

    PYTHONPATH=src python -m benchmarks.run pem

The ``pallas`` backend is skipped off-TPU (interpret mode measures the
emulator, not the kernel).
"""

from __future__ import annotations

import json
import platform
from pathlib import Path

import numpy as np

from benchmarks.common import DIM, NOW, SCALE, emit, production_db, timed
from repro.core.backends import get_backend, list_backends, select_candidates
from repro.core.grammar import parse

SNAPSHOT_PATH = Path(__file__).resolve().parents[1] / "BENCH_pem.json"

TOKENS = (
    "similar:how the system works architecture "
    "suppress:website landing page design "
    "from:prototype sketch to:production deployment "
    "decay:30 diverse pool:500"
)


def _bench_backends():
    import jax

    conn, cache, chunks, emb = production_db()
    plan = parse(TOKENS, emb, cache.embeddings_for_ids)
    n = cache.matrix.shape[0]
    days = np.maximum((NOW - cache.timestamps) / 86400.0, 0.0).astype(np.float32)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            continue
        backend = get_backend(name)

        t_score = timed(lambda: backend.score(cache.matrix, days, plan))
        emit(f"pem/score_{name}", t_score, f"n={n} composed-3mods")

        scores = backend.score(cache.matrix, days, plan)
        t_select = timed(
            lambda: select_candidates(cache.matrix, scores, plan.pool, plan)
        )
        emit(f"pem/select_{name}", t_select, f"pool={plan.pool} mmr")

        rows[name] = {
            "score_us": round(t_score * 1e6, 1),
            "select_us": round(t_select * 1e6, 1),
            "total_ms": round((t_score + t_select) * 1e3, 3),
        }
    return n, rows


def run() -> None:
    n, rows = _bench_backends()
    snapshot = {
        "bench": "pem_phase2_composed",
        "tokens": TOKENS,
        "corpus_chunks": n,
        "scale": SCALE,
        "dim": DIM,
        "platform": platform.machine(),
        "backends": rows,
    }
    SNAPSHOT_PATH.write_text(json.dumps(snapshot, indent=2) + "\n")
    print(f"# wrote {SNAPSHOT_PATH}", flush=True)
