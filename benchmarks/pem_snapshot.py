"""PEM latency snapshot -> BENCH_pem.json (the perf-trajectory anchor).

Times the Phase-2 hot path through every ExecutionBackend at the paper's
headline corpus scale (``FLEX_BENCH_SCALE`` shrinks it for smoke runs),
and writes a JSON snapshot at the repo root so successive PRs can diff
the trajectory:

    PYTHONPATH=src python -m benchmarks.run pem

``total_ms`` is the end-to-end FUSED path (``score_select`` + host
``finalize_candidates``) — the number the CI regression gate
(``benchmarks.check_regression``) diffs; ``score_us`` is the scoring
stage alone and ``select_us`` the derived difference (floored at zero:
device backends overlap selection with the score fetch they no longer
pay for).

Backends that cannot run meaningfully on this platform are RECORDED as
``{"skipped": "<reason>"}`` instead of silently dropped, so the per-
backend trajectory stays diffable across platforms (``pallas`` off-TPU:
interpret mode measures the emulator, not the kernel).

``delta_backends`` measures the cost of LIVENESS (the segmented-store
refactor): one delta cycle = append a ~5% segment to a warm store, query,
tombstone it, query again.  ``total_ms`` is the whole cycle — the number
the regression gate diffs — so a change that silently re-uploads or
re-traces warm segments on ingest shows up as a gate failure, not an
assumption.

``FLEX_BENCH_OUT`` overrides the output path (the CI gate writes the
smoke-scale run to a scratch file so the committed full-scale snapshot
is never clobbered).
"""

from __future__ import annotations

import json
import os
import platform
from pathlib import Path

import numpy as np

from benchmarks.common import DIM, NOW, SCALE, emit, production_db, timed
from repro.core.backends import (finalize_candidates, get_backend,
                                 list_backends)
from repro.core.grammar import parse

SNAPSHOT_PATH = Path(
    os.environ.get("FLEX_BENCH_OUT",
                   Path(__file__).resolve().parents[1] / "BENCH_pem.json")
)

TOKENS = (
    "similar:how the system works architecture "
    "suppress:website landing page design "
    "from:prototype sketch to:production deployment "
    "decay:30 diverse pool:500"
)


def _bench_backends():
    import jax

    conn, cache, chunks, emb = production_db()
    plan = parse(TOKENS, emb, cache.embeddings_for_ids)
    n = cache.matrix.shape[0]
    days = np.maximum((NOW - cache.timestamps) / 86400.0, 0.0).astype(np.float32)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        k = plan.pool

        def fused_search():
            (idx, vals), = backend.score_select(cache.matrix, days, [plan], [k])
            return finalize_candidates(cache.matrix, idx, vals, k, plan)

        t_score = timed(lambda: backend.score(cache.matrix, days, plan))
        emit(f"pem/score_{name}", t_score, f"n={n} composed-3mods")

        t_total = timed(fused_search)
        emit(f"pem/fused_{name}", t_total, f"pool={plan.pool} mmr fused")

        rows[name] = {
            "score_us": round(t_score * 1e6, 1),
            "select_us": round(max(t_total - t_score, 0.0) * 1e6, 1),
            "total_ms": round(t_total * 1e3, 3),
        }
    return n, rows


def _bench_delta():
    """Delta-ingest scenario: append+query / delete+query on a warm store."""
    import jax

    from repro.core.vectorcache import VectorCache

    conn, cache, chunks, emb = production_db()
    base_ids, base_mat = cache.ids, cache.matrix
    base_ts = cache.timestamps
    n = base_mat.shape[0]
    m = max(64, n // 20)  # ~5% delta segment
    delta_ids = np.arange(n, n + m) + int(base_ids.max()) + 1
    delta_mat = base_mat[:m]
    delta_ts = np.full(m, NOW)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_delta_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        vc = VectorCache(base_ids, base_mat, base_ts, emb, normalized=True)
        plan = parse(TOKENS, emb, vc.embeddings_for_ids)
        vc.search_plan(plan, now=NOW, engine=backend)  # warm the base

        def delta_cycle():
            vc.ingest(delta_ids, delta_mat, delta_ts, normalized=True)
            vc.search_plan(plan, now=NOW, engine=backend)
            vc.delete(delta_ids)
            vc.search_plan(plan, now=NOW, engine=backend)
            vc.compact(0.5)  # drop the dead segment between cycles

        t_cycle = timed(delta_cycle)
        emit(f"pem/delta_{name}", t_cycle,
             f"append {m} + query + delete + query")
        rows[name] = {"delta_rows": m,
                      "total_ms": round(t_cycle * 1e3, 3)}
    return rows


def run() -> None:
    n, rows = _bench_backends()
    delta_rows = _bench_delta()
    snapshot = {
        "bench": "pem_phase2_composed",
        "tokens": TOKENS,
        "corpus_chunks": n,
        "scale": SCALE,
        "dim": DIM,
        "platform": platform.machine(),
        "backends": rows,
        "delta_backends": delta_rows,
    }
    SNAPSHOT_PATH.write_text(json.dumps(snapshot, indent=2) + "\n")
    print(f"# wrote {SNAPSHOT_PATH}", flush=True)
