"""PEM latency snapshot -> BENCH_pem.json (the perf-trajectory anchor).

Times the Phase-2 hot path through every ExecutionBackend at the paper's
headline corpus scale (``FLEX_BENCH_SCALE`` shrinks it for smoke runs),
and writes a JSON snapshot at the repo root so successive PRs can diff
the trajectory:

    PYTHONPATH=src python -m benchmarks.run pem

``total_ms`` is the end-to-end FUSED path (``score_select`` + host
``finalize_candidates``) — the number the CI regression gate
(``benchmarks.check_regression``) diffs; ``score_us`` is the scoring
stage alone and ``select_us`` the derived difference (floored at zero:
device backends overlap selection with the score fetch they no longer
pay for).

Backends that cannot run meaningfully on this platform are RECORDED as
``{"skipped": "<reason>"}`` instead of silently dropped, so the per-
backend trajectory stays diffable across platforms (``pallas`` off-TPU:
interpret mode measures the emulator, not the kernel).

``delta_backends`` measures the cost of LIVENESS (the segmented-store
refactor): one delta cycle = append a ~5% segment to a warm store, query,
tombstone it, query again.  ``total_ms`` is the whole cycle — the number
the regression gate diffs — so a change that silently re-uploads or
re-traces warm segments on ingest shows up as a gate failure, not an
assumption.

``prefilter_backends`` measures Phase-1 FILTERED retrieval (the paper's
headline SQL-pre-filter scenario, formerly the standalone ``table3``
suite): a selectivity sweep (~0.1% / 5% / 50% of the corpus as
candidates) timing the masked-device path (candidates ∧ live masked to
-inf over the warm per-segment device matrices — zero per-query
gather/upload) against the gather-host path (scratch sub-corpus per
query) with the router forced each way.  ``total_ms`` — the gated
number — sums the path the DEFAULT router picks across the sweep, and
``crossover`` records the measured selectivity where masked first beats
gather on this platform.

``diverse_backends`` measures the fully-fused Phase-2 (in-graph device
MMR): a diverse-heavy lambda sweep per device-MMR backend, fused
final-k-on-device path against the host-pool comparator
(``fused_mmr=False`` + ``mmr_host``), rankings checked bit-identical
before timing.  ``filter_panel`` measures heterogeneous-filter batching:
one (N, B) candidate-mask-panel pass for a B-request cohort of DIFFERENT
weak filters against B serial per-filter masked dispatches, for
B in {4, 16}.  Both gate on the fused/batched path's ``total_ms``.

``hybrid_backends`` measures HYBRID lexical+vector fusion (the
``keyword:``/``fuse:`` surface): one dual-leg query — a decay-scoped
``similar:`` leg plus an FTS5 ``keyword:`` leg fused as
``w*vector + (1-w)*minmax(bm25)`` on device — against the pure-vector
and pure-FTS baselines, with nDCG@10/@100 over a topical-AND-fresh gold
set (BM25 cannot rank recency; the vector leg fights the descriptive
cluster's overlap vocabulary).  ``total_ms`` — the gated number — is
the hybrid path;
``latency_ratio`` records hybrid/vector (the fusion bias rides the same
fused device pass, so it must stay well under 1.5x) and ``quality_wins``
lists the metrics where hybrid beats BOTH baselines.

``serve_throughput`` measures the SERVING core, not a single pass: an
offered-load sweep (closed loop, ``load`` concurrent clients) through the
continuous-batching engine in both modes — ``sync_core`` (the legacy
one-thread phasing: parse in the serve loop, host tail serialized behind
the device pass) and ``pipelined`` (admission-time parse, tail of batch
*i* overlapped with the device pass of batch *i+1*).  Per mode:
sustained QPS and client-side p50/p99 latency per load, the engine's
``overlapped_batches`` counter, and ``total_ms`` (the whole sweep's wall
time) — the number the regression gate diffs.

Three extra rows — ``sync_core_emudev`` / ``pipelined_emudev`` /
``async_emudev`` (the pipelined scheduler with real async device
dispatch: the serve loop stays live during the device pass, so the
admission window keeps filling and ``overlapped_collects`` counts the
holds) — run the same closed-loop workload through an EMULATED
two-stage pipeline with fixed stage durations: the scoring pass models an accelerator busy for
``EMUDEV_DEVICE_MS`` (wall time, zero host CPU — what a TPU pass looks
like from the host) and the host tail models ``EMUDEV_TAIL_MS`` of
finishing work on a dedicated core.  With deterministic stages the two
walls are pure functions of the SCHEDULER: the sync core pays
``device + tail`` per batch, the pipelined core ``max(device, tail)``.
That makes the overlap win pinnable BY THE GATE on any host — including
CPU-quota-limited CI containers, where overlapping two CPU-bound numpy
stages cannot beat serial execution because the cgroup throttles the
whole process once the quota is spent (the ``host.parallel_efficiency``
calibration field records which regime produced the real-workload rows:
~2 means two usable cores, ~1 means a one-core quota).

``scale_1m`` measures the MILLION-CHUNK cross-process topology: the
corpus dealt round-robin across per-shard segmented stores behind the
``ProcessGroup`` shard-replica router.  The headline ``f32b`` row is
the blocked single-stream panel pass (one RAM trip per query instead
of one per plan direction) against the paper's 82 ms budget; the
exact-f32 group is checked bit-identical to the monolithic oracle
(shard-local MMR included), and the bf16 packed-codes row pins the
half-resident-bytes memory claim plus ranking overlap.  Gated on
``total_ms`` per row; ``FLEX_SCALE_1M=1`` runs the true 1M+ corpus
(the paper's 82 ms budget) where the smoke scale only pins the
trajectory and the oracle contract.

``cohort_throughput`` measures COHORT-STREAMED scoring: the Q-query
panel pass (``search_plan_batch`` -> ``ShardWorker._fast_pass`` Q>1)
that streams each shard's corpus from RAM ONCE per cohort instead of
once per query, against the serial per-query ``f32b`` comparator over
the same composed three-modulation queries — rankings bit-identical by
construction (the cohort pass is a loop reordering of the serial one)
and checked before timing, the one-stream-per-shard-per-cohort claim
counter-pinned via ``corpus_streams``.  Two engine rows ride along:
the continuous-batching engine under closed-loop load with cohorts
disabled (``max_batch=1``) vs enabled (``max_batch=16``), QPS +
p50/p99 per row.  Every row gates on ``total_ms``; the q16 row records
``speedup_vs_serial`` (the >=3x headline lives at ``FLEX_SCALE_1M=1``
scale — at smoke scale the corpus fits cache and the row only pins the
trajectory).

``ingest_durability`` measures the DURABLE ingest cycle: ``INSERT INTO
chunks`` with the embedder inline on the write path vs. through the
bounded queue + background vectorizer (the INSERT returns after
enqueue + journal fsync; embedding happens in scheduler idle gaps or
the close flush), p50/p99 per insert, ``total_ms`` covering inserts +
close so deferred work can't game the gate — plus
``SegmentedCorpusStore.open`` recovery walls right after a checkpoint
(0 records replayed) and after a post-snapshot delta, pinning the
O(delta)-not-O(corpus) recovery claim in milliseconds.

``FLEX_BENCH_OUT`` overrides the output path (the CI gate writes the
smoke-scale run to a scratch file so the committed full-scale snapshot
is never clobbered).
"""

from __future__ import annotations

import json
import os
import platform
from pathlib import Path

import numpy as np

from benchmarks.common import DIM, NOW, SCALE, emit, production_db, timed
from repro.core.backends import (finalize_candidates, get_backend,
                                 list_backends)
from repro.core.grammar import parse

SNAPSHOT_PATH = Path(
    os.environ.get("FLEX_BENCH_OUT",
                   Path(__file__).resolve().parents[1] / "BENCH_pem.json")
)

TOKENS = (
    "similar:how the system works architecture "
    "suppress:website landing page design "
    "from:prototype sketch to:production deployment "
    "decay:30 diverse pool:500"
)


def _bench_backends():
    import jax

    conn, cache, chunks, emb = production_db()
    plan = parse(TOKENS, emb, cache.embeddings_for_ids)
    n = cache.matrix.shape[0]
    days = np.maximum((NOW - cache.timestamps) / 86400.0, 0.0).astype(np.float32)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        k = plan.pool

        def fused_search():
            (idx, vals), = backend.score_select(cache.matrix, days, [plan], [k])
            if backend.device_mmr:
                return idx, vals  # diversity already finished on device
            return finalize_candidates(cache.matrix, idx, vals, k, plan)

        t_score = timed(lambda: backend.score(cache.matrix, days, plan))
        emit(f"pem/score_{name}", t_score, f"n={n} composed-3mods")

        t_total = timed(fused_search)
        emit(f"pem/fused_{name}", t_total, f"pool={plan.pool} mmr fused")

        rows[name] = {
            "score_us": round(t_score * 1e6, 1),
            "select_us": round(max(t_total - t_score, 0.0) * 1e6, 1),
            "total_ms": round(t_total * 1e3, 3),
        }
    return n, rows


def _bench_delta():
    """Delta-ingest scenario: append+query / delete+query on a warm store."""
    import jax

    from repro.core.vectorcache import VectorCache

    conn, cache, chunks, emb = production_db()
    base_ids, base_mat = cache.ids, cache.matrix
    base_ts = cache.timestamps
    n = base_mat.shape[0]
    m = max(64, n // 20)  # ~5% delta segment
    delta_ids = np.arange(n, n + m) + int(base_ids.max()) + 1
    delta_mat = base_mat[:m]
    delta_ts = np.full(m, NOW)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_delta_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        vc = VectorCache(base_ids, base_mat, base_ts, emb, normalized=True)
        plan = parse(TOKENS, emb, vc.embeddings_for_ids)
        vc.search_plan(plan, now=NOW, engine=backend)  # warm the base

        def delta_cycle():
            vc.ingest(delta_ids, delta_mat, delta_ts, normalized=True)
            vc.search_plan(plan, now=NOW, engine=backend)
            vc.delete(delta_ids)
            vc.search_plan(plan, now=NOW, engine=backend)
            vc.compact(0.5)  # drop the dead segment between cycles

        t_cycle = timed(delta_cycle)
        emit(f"pem/delta_{name}", t_cycle,
             f"append {m} + query + delete + query")
        rows[name] = {"delta_rows": m,
                      "total_ms": round(t_cycle * 1e3, 3)}
    return rows


PREFILTER_SELECTIVITIES = (0.001, 0.05, 0.5)
PREFILTER_TOKENS = (
    # no diverse/MMR: the host finishing tail would drown the routing
    # difference the scenario exists to measure
    "similar:how the system works architecture "
    "suppress:website landing page design decay:30 pool:100"
)


def _bench_prefilter():
    """Phase-1 filtered retrieval: masked-device vs gather-host sweep.

    For each backend and each selectivity (candidate fraction of the
    corpus), times ``search_plan(plan, candidate_ids)`` end to end with
    the router FORCED down each path, then records which path the default
    router picks.  ``total_ms`` — the gated number — sums the ROUTED path
    across the sweep, so both a slowed masked path and a mis-tuned
    threshold regress it.  ``crossover`` is the measured selectivity
    where masked first beats gather (the number the default
    ``mask_threshold`` should sit near on this platform class).
    """
    import jax

    from repro.core.backends import PrefilterRouter

    conn, cache, chunks, emb = production_db()
    plan = parse(PREFILTER_TOKENS, emb, cache.embeddings_for_ids)
    ids = cache.ids
    n = ids.shape[0]
    rng = np.random.default_rng(7)
    cand_sets = {
        sel: rng.choice(ids, size=max(1, int(round(n * sel))), replace=False)
        for sel in PREFILTER_SELECTIVITIES
    }

    on_tpu = jax.default_backend() == "tpu"
    default_router = PrefilterRouter()
    saved_router = cache.prefilter
    rows = {}
    try:
        for name in list_backends():
            if name == "pallas" and not on_tpu:
                rows[name] = {"skipped": "requires TPU (interpret mode "
                                         "measures the emulator, not the "
                                         "kernel)"}
                emit(f"pem/skip_prefilter_{name}", 0.0, "off-TPU")
                continue
            backend = get_backend(name)
            cache.search_plan(plan, now=NOW, engine=backend)  # warm segments
            sweep = {}
            total_s = 0.0
            crossover = None
            for sel in PREFILTER_SELECTIVITIES:
                cand = cand_sets[sel]
                cache.prefilter = PrefilterRouter(mask_threshold=0.0)
                t_masked = timed(lambda: cache.search_plan(
                    plan, cand, now=NOW, engine=backend))
                cache.prefilter = PrefilterRouter(mask_threshold=2.0)
                t_gather = timed(lambda: cache.search_plan(
                    plan, cand, now=NOW, engine=backend))
                routed = ("masked" if default_router.use_masked(len(cand), n)
                          else "gather")
                t_routed = t_masked if routed == "masked" else t_gather
                total_s += t_routed
                if crossover is None and t_masked <= t_gather:
                    crossover = sel
                sweep[str(sel)] = {
                    "candidates": int(len(cand)),
                    "masked_ms": round(t_masked * 1e3, 3),
                    "gather_ms": round(t_gather * 1e3, 3),
                    "routed": routed,
                }
                emit(f"pem/prefilter_{name}_sel{sel}", t_routed,
                     f"cand={len(cand)} masked={t_masked*1e3:.2f}ms "
                     f"gather={t_gather*1e3:.2f}ms routed={routed}")
            rows[name] = {
                "total_ms": round(total_s * 1e3, 3),
                "threshold": default_router.mask_threshold,
                "crossover": crossover,
                "sweep": sweep,
            }
    finally:
        cache.prefilter = saved_router
    return rows


DIVERSE_LAMBDAS = (0.3, 0.7)


def _bench_diverse():
    """Fully-fused diverse retrieval: in-graph device MMR vs host pool.

    Diverse-heavy sweep (lam in ``DIVERSE_LAMBDAS``, the headline
    pool:500 plan) per device-MMR backend, timing the FUSED path
    (``score_select`` returns the final k — the pool never leaves the
    device) against the HOST comparator (``fused_mmr=False``: ship the
    oversample pool back and run the ``mmr_host`` oracle).  ``total_ms``
    — the gated number — sums the fused path across the sweep, and every
    fused ranking is checked BIT-IDENTICAL to the host oracle before a
    time is recorded (``oracle_match``).  Backends without device MMR
    are recorded as skipped so the trajectory stays diffable.
    """
    import dataclasses as _dc

    import jax

    conn, cache, chunks, emb = production_db()
    base_plan = parse(TOKENS, emb, cache.embeddings_for_ids)
    n = cache.matrix.shape[0]
    days = np.maximum((NOW - cache.timestamps) / 86400.0, 0.0).astype(np.float32)

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_diverse_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        if not backend.device_mmr:
            rows[name] = {"skipped": "no device MMR (host oracle IS the "
                                     "fused path here)"}
            emit(f"pem/skip_diverse_{name}", 0.0, "host backend")
            continue
        sweep = {}
        total_s = 0.0
        oracle_match = True
        for lam in DIVERSE_LAMBDAS:
            plan = _dc.replace(
                base_plan, diverse=_dc.replace(base_plan.diverse, lam=lam))
            k = plan.pool

            def fused():
                (idx, vals), = backend.score_select(
                    cache.matrix, days, [plan], [k])
                return idx, vals

            def host():
                (idx, vals), = backend.score_select(
                    cache.matrix, days, [plan], [k], fused_mmr=False)
                return finalize_candidates(cache.matrix, idx, vals, k, plan)

            fi, fv = fused()
            hi, hv = host()
            if list(fi) != list(hi):
                oracle_match = False
            t_fused = timed(fused)
            t_host = timed(host)
            total_s += t_fused
            sweep[str(lam)] = {
                "fused_ms": round(t_fused * 1e3, 3),
                "host_ms": round(t_host * 1e3, 3),
                "speedup": round(t_host / max(t_fused, 1e-9), 2),
            }
            emit(f"pem/diverse_{name}_lam{lam}", t_fused,
                 f"n={n} pool={k} host={t_host*1e3:.2f}ms "
                 f"match={list(fi) == list(hi)}")
        rows[name] = {
            "total_ms": round(total_s * 1e3, 3),
            "oracle_match": oracle_match,
            "sweep": sweep,
        }
    return rows


PANEL_BATCH_SIZES = (4, 16)
PANEL_SELECTIVITY = 0.3


def _bench_filter_panel():
    """Heterogeneous-filter batches: one (N, B) panel pass vs B serial
    per-filter dispatches.

    For B in ``PANEL_BATCH_SIZES``, draws B DIFFERENT ~30%-selectivity
    candidate sets (the weak-filter regime where each group would cost a
    full-corpus masked pass anyway) and times ``score_select_filter_panel``
    — ONE batched matmul + masked selection for the whole cohort —
    against the serial comparator: one ``score_select_prefiltered``
    masked pass per filter.  ``total_ms`` — the gated number — sums the
    panel path across batch sizes; every panel ranking is checked
    BIT-IDENTICAL to its serial counterpart first (``serial_match``).
    """
    import jax

    from repro.core.backends import (PrefilterRouter,
                                     score_select_filter_panel,
                                     score_select_prefiltered)

    conn, cache, chunks, emb = production_db()
    plan = parse(PREFILTER_TOKENS, emb, cache.embeddings_for_ids)
    store = cache.store
    segments = store.segments
    ids = cache.ids
    n = ids.shape[0]
    rng = np.random.default_rng(11)
    size = max(1, int(round(n * PANEL_SELECTIVITY)))
    all_sets = [np.sort(rng.choice(ids, size=size, replace=False))
                for _ in range(max(PANEL_BATCH_SIZES))]

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_panel_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        cache.search_plan(plan, now=NOW, engine=backend)  # warm segments
        sweep = {}
        total_s = 0.0
        serial_match = True
        for b in PANEL_BATCH_SIZES:
            sets = all_sets[:b]
            plans = [plan] * b
            ks = [plan.pool] * b

            def panel():
                return score_select_filter_panel(
                    backend, store, segments, plans, ks, sets, now=NOW)

            def serial():
                router = PrefilterRouter(mask_threshold=0.0)  # force masked
                return [score_select_prefiltered(
                            backend, store, segments, [plan], [plan.pool],
                            s, now=NOW, router=router)[0]
                        for s in sets]

            for (pi, pv), (si, sv) in zip(panel(), serial()):
                if list(pi) != list(si):
                    serial_match = False
            t_panel = timed(panel)
            t_serial = timed(serial)
            total_s += t_panel
            sweep[str(b)] = {
                "candidates_per_filter": size,
                "panel_ms": round(t_panel * 1e3, 3),
                "serial_ms": round(t_serial * 1e3, 3),
                "speedup": round(t_serial / max(t_panel, 1e-9), 2),
            }
            emit(f"pem/panel_{name}_b{b}", t_panel,
                 f"n={n} B={b} serial={t_serial*1e3:.2f}ms")
        rows[name] = {
            "total_ms": round(total_s * 1e3, 3),
            "serial_match": serial_match,
            "sweep": sweep,
        }
    return rows


HYBRID_SIM = "how the server system works"   # semantic leg (vector)
HYBRID_KW = "server restart"                 # lexical leg (FTS5 BM25)
HYBRID_WEIGHT = 0.8
HYBRID_DECAY_DAYS = 28                       # recency window = gold window
HYBRID_GOLD_TOPIC = "server"
HYBRID_POOL = 500


def _bench_hybrid():
    """Hybrid lexical+vector fusion: latency AND ranking quality.

    One query, three modalities over the production corpus: the HYBRID
    plan (``similar:`` + ``decay:`` + a ``keyword:`` lexical leg fused
    as ``w*vector + (1-w)*minmax(bm25)`` on device), the PURE-VECTOR plan
    (same tokens minus the lexical leg) and PURE FTS5/BM25.  The
    information need is topical AND fresh — gold is every chunk of the
    ``server`` implementation topic inside the ``decay:`` recency
    window — so nDCG@10/@100 measure each modality's blind spot at ANY
    corpus scale: BM25 cannot rank recency at all, and the decay-scoped
    vector leg fights the overlap vocabulary the dominant descriptive
    cluster floods into the same window.  Fusion should beat BOTH on at
    least one metric, with hybrid latency within 1.5x of
    pure-vector (the bias rides the same fused device pass as a sparse
    additive panel, it is not a second retrieval).

    ``total_ms`` — the gated number — is the hybrid path end to end per
    backend; ``vector_ms`` / ``fts_ms`` are the comparators and
    ``latency_ratio`` = hybrid/vector.  Quality metrics are
    backend-independent (computed once on the reference ranking) and
    recorded on every measured row.
    """
    import jax

    from repro.core.materializer import fts_query
    from repro.core import modulations as M_
    from repro.metrics.ranking import ndcg_at_k

    conn, cache, chunks, emb = production_db()
    cutoff = NOW - HYBRID_DECAY_DAYS * 86400.0
    qrels = {c.id: 1 for c in chunks
             if c.topic == HYBRID_GOLD_TOPIC and c.created_at >= cutoff}

    def lexical_fn(text, limit):
        fts = fts_query(conn, text, limit=limit)
        if not fts:
            return (np.empty(0, np.int64), np.empty(0, np.float32))
        lex_ids = np.asarray([r[0] for r in fts], np.int64)
        return lex_ids, M_.minmax_normalize(
            np.asarray([r[1] for r in fts], np.float32))

    hybrid_plan = parse(
        f"similar:{HYBRID_SIM} keyword:{HYBRID_KW} "
        f"fuse:weighted,{HYBRID_WEIGHT} "
        f"decay:{HYBRID_DECAY_DAYS} pool:{HYBRID_POOL}",
        emb, cache.embeddings_for_ids, lexical_fn)
    vector_plan = parse(
        f"similar:{HYBRID_SIM} decay:{HYBRID_DECAY_DAYS} pool:{HYBRID_POOL}",
        emb, cache.embeddings_for_ids)

    # quality is a property of the ranking, not the backend: compute once
    # on the (oracle) reference engine
    hyb_rank = [i for i, _ in cache.search_plan(
        hybrid_plan, now=NOW, engine="reference")]
    vec_rank = [i for i, _ in cache.search_plan(
        vector_plan, now=NOW, engine="reference")]
    fts_rank = [r[0] for r in fts_query(conn, HYBRID_KW, limit=HYBRID_POOL)]
    quality = {}
    for k in (10, 100):
        quality[f"ndcg@{k}"] = {
            "hybrid": round(ndcg_at_k(hyb_rank, qrels, k), 4),
            "vector": round(ndcg_at_k(vec_rank, qrels, k), 4),
            "fts": round(ndcg_at_k(fts_rank, qrels, k), 4),
        }
    wins = [m for m, q in quality.items()
            if q["hybrid"] > q["vector"] and q["hybrid"] > q["fts"]]
    emit("pem/hybrid_quality", 0.0,
         f"gold={len(qrels)} wins={','.join(wins) or 'NONE'} "
         + " ".join(f"{m}:h={q['hybrid']}/v={q['vector']}/f={q['fts']}"
                    for m, q in quality.items()))

    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    for name in list_backends():
        if name == "pallas" and not on_tpu:
            rows[name] = {"skipped": "requires TPU (interpret mode measures "
                                     "the emulator, not the kernel)"}
            emit(f"pem/skip_hybrid_{name}", 0.0, "off-TPU")
            continue
        backend = get_backend(name)
        # warm both plan structures (bias=True traces its own executable)
        cache.search_plan(hybrid_plan, now=NOW, engine=backend)
        cache.search_plan(vector_plan, now=NOW, engine=backend)
        t_hybrid = timed(lambda: cache.search_plan(
            hybrid_plan, now=NOW, engine=backend))
        t_vector = timed(lambda: cache.search_plan(
            vector_plan, now=NOW, engine=backend))
        t_fts = timed(lambda: fts_query(conn, HYBRID_KW, limit=HYBRID_POOL))
        ratio = round(t_hybrid / max(t_vector, 1e-9), 3)
        emit(f"pem/hybrid_{name}", t_hybrid,
             f"vector={t_vector*1e3:.2f}ms fts={t_fts*1e3:.2f}ms "
             f"ratio={ratio}x")
        rows[name] = {
            "total_ms": round(t_hybrid * 1e3, 3),
            "vector_ms": round(t_vector * 1e3, 3),
            "fts_ms": round(t_fts * 1e3, 3),
            "latency_ratio": ratio,
            "quality_wins": wins,
            "quality": quality,
        }
    return rows


SCALE1M_TOKENS = (
    # three composed modulations, no MMR tail: the scenario times the
    # corpus PASS (the part that scales with n), not the host finish
    "similar:how the system works architecture "
    "suppress:website landing page design "
    "decay:30 pool:500"
)
SCALE1M_DIVERSE_TOKENS = SCALE1M_TOKENS + " diverse"
SCALE1M_TARGET_MS = 82.0      # paper parity: 1M chunks, 3 composed mods
SCALE1M_SHARDS = 4
SCALE1M_FULL_N = 1_000_448    # 1M+, divisible by shards*32 (parity floor)
SCALE1M_SWEEP = (240_000, 480_000, 720_000, 1_000_448)


def _scale1m_corpus(n_target):
    """Tile the production corpus to ``n_target`` rows (the paper builds
    larger corpora by combining embedding matrices the same way)."""
    conn, cache, chunks, emb = production_db()
    base, ts = cache.matrix, cache.timestamps
    rng = np.random.default_rng(0)
    mats, tss = [], []
    for r in range(int(np.ceil(n_target / base.shape[0]))):
        m = base if r == 0 else base + rng.normal(
            0, 0.05, base.shape).astype(np.float32)
        mats.append(m / np.linalg.norm(m, axis=1, keepdims=True))
        tss.append(ts)
    matrix = np.ascontiguousarray(np.concatenate(mats)[:n_target])
    stamps = np.concatenate(tss)[:n_target]
    return np.arange(n_target), matrix, stamps, emb


def _scale1m_transport() -> str:
    """Thread fan-out where cores can actually overlap, serial inline on
    a one-core quota (four concurrent BLAS streams on one core just
    thrash its cache — measured slower than the serial pass)."""
    return "thread" if (os.cpu_count() or 1) > 1 else "inline"


def _bench_scale1m():
    """Million-chunk paper parity: the cross-process shard group.

    The corpus is dealt round-robin across ``SCALE1M_SHARDS`` per-shard
    ``SegmentedCorpusStore`` workers behind a ``ProcessGroup`` router
    (thread transport on multi-core hosts — workers score through
    GIL-releasing BLAS — serial ``inline`` fan-out on a one-core quota;
    either way the per-shard arithmetic matches separate processes
    without pickling the corpus into CI's memory budget).  Rows, each
    gated on ``total_ms``:

    * ``sharded_f32b`` — the HEADLINE: per-shard blocked single-stream
      panel pass (``dtype="f32b"``: every plan direction shares one
      L2-resident row block, so the corpus streams from RAM once per
      query instead of once per direction) for the
      three-composed-modulations plan.  At the full scale
      (``FLEX_SCALE_1M=1``, ``SCALE1M_FULL_N`` rows) this is the number
      the paper's 82 ms budget judges; ``target_ms``/``target_met``
      record the verdict, ``sweep`` the 240k -> 1M scaling curve, and
      ``top100_overlap_vs_f32`` pins ranking agreement with the exact
      pass (the blocked GEMM differs from the monolithic call only in
      final-ulp accumulation order).
    * ``sharded_f32`` — the exact-arithmetic group pass, checked
      BIT-IDENTICAL to the monolithic oracle before timing
      (``oracle_match``).
    * ``sharded_f32_diverse`` — adds the MMR tail: shard-local pool
      gather + coordinator ``mmr_host`` over the exact-union pool,
      pinned bit-identical to the monolithic ``mmr_host`` oracle.
    * ``sharded_bf16`` — the packed-codes comparator: HALF the
      scoring-resident bytes per shard (``codes_bytes`` in the ledger).
      On bandwidth-bound hosts the byte halving is a latency win too;
      on a compute-starved one-core quota the elementwise decode costs
      more than the saved stream, so this row gates memory + ranking
      overlap, not the 82 ms target.
    * ``monolithic_fused`` — the single-store comparator.

    Always measured (scaled to ``FLEX_BENCH_SCALE`` when the env flag is
    off) so the gate section exists at smoke scale: dropping the sharded
    path or regressing it past tolerance fails CI even where the full
    million-chunk corpus cannot fit the runner's memory budget.
    ``per_shard`` records each worker's memory/latency ledger
    (``stats()``): per-shard scoring-resident bytes are the binding
    constraint the topology exists to bound.
    """
    from repro.core.vectorcache import VectorCache
    from repro.dist.procgroup import ProcessGroup

    full = os.environ.get("FLEX_SCALE_1M", "") not in ("", "0")
    transport = _scale1m_transport()
    if full:
        n_target = SCALE1M_FULL_N
    else:
        # smoke scale: keep every per-shard slice block-aligned so the
        # f32 oracle check stays bit-exact (see procgroup docstring)
        n_target = max(16_000, int(SCALE1M_FULL_N * SCALE))
        n_target -= n_target % (SCALE1M_SHARDS * 32)
    ids, matrix, stamps, emb = _scale1m_corpus(n_target)

    mono = VectorCache(ids, matrix, stamps, emb, normalized=True)
    plan = parse(SCALE1M_TOKENS, emb, mono.embeddings_for_ids)
    plan_div = parse(SCALE1M_DIVERSE_TOKENS, emb, mono.embeddings_for_ids)

    rows = {}
    t_mono = timed(lambda: mono.search_plan(plan, now=NOW,
                                            engine="fused-numpy"), repeats=3)
    emit("pem/scale1m_monolithic", t_mono, f"n={n_target}")
    rows["monolithic_fused"] = {"n": n_target,
                                "total_ms": round(t_mono * 1e3, 3)}
    want = mono.search_plan(plan, now=NOW, engine="fused-numpy")
    want_div = mono.search_plan(plan_div, now=NOW, engine="fused-numpy")
    top100 = {i for i, _ in want[:100]}

    with ProcessGroup.build(ids, matrix, stamps, normalized=True,
                            n_shards=SCALE1M_SHARDS,
                            transport=transport) as g32:
        oracle_match = (g32.search_plan(plan, now=NOW) == want)
        t_f32 = timed(lambda: g32.search_plan(plan, now=NOW), repeats=3)
        emit("pem/scale1m_sharded_f32", t_f32,
             f"n={n_target} shards={SCALE1M_SHARDS} match={oracle_match}")
        rows["sharded_f32"] = {
            "n": n_target,
            "total_ms": round(t_f32 * 1e3, 3),
            "transport": transport,
            "oracle_match": oracle_match,
        }
        div_match = (g32.search_plan(plan_div, now=NOW) == want_div)
        t_div = timed(lambda: g32.search_plan(plan_div, now=NOW), repeats=3)
        emit("pem/scale1m_sharded_f32_diverse", t_div,
             f"mmr_host oracle match={div_match}")
        rows["sharded_f32_diverse"] = {
            "n": n_target,
            "total_ms": round(t_div * 1e3, 3),
            "oracle_match": div_match,
        }

    with ProcessGroup.build(ids, matrix, stamps, normalized=True,
                            n_shards=SCALE1M_SHARDS, transport=transport,
                            dtype="f32b") as gb:
        got_b = [i for i, _ in gb.search_plan(plan, now=NOW, k=100)]
        overlap_b = len(set(got_b) & top100) / 100.0
        t_f32b = timed(lambda: gb.search_plan(plan, now=NOW), repeats=3)
        st = gb.stats()
        per_shard = [{k_: s[k_] for k_ in
                      ("shard", "rows", "live", "matrix_bytes",
                       "codes_bytes", "scoring_bytes", "last_pass_ms")}
                     for s in st["shards"]]
        row = {
            "n": n_target,
            "total_ms": round(t_f32b * 1e3, 3),
            "transport": transport,
            "target_ms": SCALE1M_TARGET_MS,
            "target_met": bool(t_f32b * 1e3 <= SCALE1M_TARGET_MS)
                          if full else None,
            "top100_overlap_vs_f32": overlap_b,
            "shards": SCALE1M_SHARDS,
            "per_shard": per_shard,
        }
        emit("pem/scale1m_sharded_f32b", t_f32b,
             f"n={n_target} target<= {SCALE1M_TARGET_MS}ms "
             f"overlap@100={overlap_b:.2f}")
        if full:
            sweep = {}
            for n_s in SCALE1M_SWEEP:
                if n_s == n_target:
                    sweep[str(n_s)] = {"total_ms": round(t_f32b * 1e3, 3)}
                    continue
                s_ids, s_mat, s_ts, _ = _scale1m_corpus(
                    n_s - n_s % (SCALE1M_SHARDS * 32))
                with ProcessGroup.build(
                        s_ids, s_mat, s_ts, normalized=True,
                        n_shards=SCALE1M_SHARDS, transport=transport,
                        dtype="f32b") as gs:
                    t_s = timed(lambda: gs.search_plan(plan, now=NOW),
                                repeats=3)
                sweep[str(n_s)] = {"total_ms": round(t_s * 1e3, 3)}
                emit(f"pem/scale1m_sweep_{n_s}", t_s, f"n={n_s}")
            row["sweep"] = sweep
        rows["sharded_f32b"] = row

    with ProcessGroup.build(ids, matrix, stamps, normalized=True,
                            n_shards=SCALE1M_SHARDS, transport=transport,
                            dtype="bf16") as g16:
        got16 = [i for i, _ in g16.search_plan(plan, now=NOW, k=100)]
        overlap = len(set(got16) & top100) / 100.0
        t_bf16 = timed(lambda: g16.search_plan(plan, now=NOW), repeats=3)
        st = g16.stats()
        codes = sum(s["codes_bytes"] for s in st["shards"])
        mat_b = sum(s["matrix_bytes"] for s in st["shards"])
        emit("pem/scale1m_sharded_bf16", t_bf16,
             f"n={n_target} codes={codes / 1e6:.0f}MB "
             f"(f32 {mat_b / 1e6:.0f}MB) overlap@100={overlap:.2f}")
        rows["sharded_bf16"] = {
            "n": n_target,
            "total_ms": round(t_bf16 * 1e3, 3),
            "top100_overlap_vs_f32": overlap,
            "codes_bytes": codes,
            "matrix_bytes": mat_b,
        }
    return n_target, rows


COHORT_QS = (4, 16)
COHORT_K = 50
COHORT_SERVE_REQUESTS = 32


def _cohort_query_tokens(i: int) -> str:
    """Composed three-modulation query i of the cohort (similar +
    suppress + decay — the scale_1m headline shape, distinct per slot)."""
    return (f"similar:how the system works architecture variant {i} "
            "suppress:website landing page design decay:30 pool:500")


def _best(fn, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of wall seconds: the cohort/serial RATIO is the claim here,
    and closed-loop noise on a quota-throttled runner is one-sided (a
    contended run only reads slow), so min — not median — estimates the
    uncontended pass both sides of the ratio deserve."""
    import time as _time

    for _ in range(warmup):
        fn()
    best = None
    for _ in range(repeats):
        t0 = _time.perf_counter()
        fn()
        dt = _time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def _bench_cohort_throughput():
    """Cohort-streamed scoring: amortize the corpus stream across Q.

    The shard group scores a Q-query cohort (``search_plan_batch``) in
    ONE blocked pass per shard — every L2-resident corpus block is
    scored for all Q plans before the next block loads, so the corpus
    streams from RAM once per cohort instead of once per query.  The
    pass is a LOOP REORDERING of the serial one (identical per-plan
    GEMMs on identical blocks), so rankings AND scores are
    bit-identical — checked before timing (``bit_identical``), with the
    bandwidth claim counter-pinned (``corpus_streams_per_cohort`` == 1).

    Rows, each gated on ``total_ms``:

    * ``serial_f32b`` — the comparator: ``COHORT_QS[-1]`` composed
      three-modulation queries through ``search_plan`` one at a time.
    * ``cohort_f32b_q4`` / ``cohort_f32b_q16`` — the same queries as
      one cohort; the q16 row records ``speedup_vs_serial`` (the >=3x
      headline at ``FLEX_SCALE_1M=1`` scale, where the corpus is
      RAM-resident; at smoke scale it only pins the trajectory).
    * ``serve_serial`` / ``serve_cohort`` — the continuous-batching
      engine under closed-loop load with cohorts disabled
      (``max_batch=1``) vs enabled (``max_batch=16``): the adaptive
      window + async dispatch turn concurrent arrivals into device
      cohorts, so the QPS gap is the end-to-end serving win.
    """
    from repro.core.vectorcache import VectorCache
    from repro.dist.procgroup import ProcessGroup
    from repro.serve.engine import BatchedRetrievalEngine

    full = os.environ.get("FLEX_SCALE_1M", "") not in ("", "0")
    transport = _scale1m_transport()
    if full:
        n_target = SCALE1M_FULL_N
    else:
        n_target = max(16_000, int(SCALE1M_FULL_N * SCALE))
        n_target -= n_target % (SCALE1M_SHARDS * 32)
    ids, matrix, stamps, emb = _scale1m_corpus(n_target)
    vc = VectorCache(ids, matrix, stamps, emb, normalized=True)
    q_max = max(COHORT_QS)
    plans = [parse(_cohort_query_tokens(i), emb, vc.embeddings_for_ids)
             for i in range(q_max)]

    rows = {}
    with ProcessGroup.build(ids, matrix, stamps, normalized=True,
                            n_shards=SCALE1M_SHARDS, transport=transport,
                            dtype="f32b") as g:
        serial_out = [g.search_plan(p, now=NOW, k=COHORT_K) for p in plans]
        t_serial = _best(lambda: [g.search_plan(p, now=NOW, k=COHORT_K)
                                  for p in plans])
        rows["serial_f32b"] = {
            "n": n_target,
            "queries": q_max,
            "transport": transport,
            "total_ms": round(t_serial * 1e3, 3),
            "per_query_ms": round(t_serial * 1e3 / q_max, 3),
            "qps": round(q_max / t_serial, 1),
        }
        emit("pem/cohort_serial_f32b", t_serial,
             f"n={n_target} {q_max} queries one at a time")

        for q in COHORT_QS:
            sub = plans[:q]
            cohort_out = g.search_plan_batch(sub, [None] * q, now=NOW,
                                             ks=[COHORT_K] * q)
            identical = (cohort_out == serial_out[:q])
            before = {s["shard"]: s["corpus_streams"]
                      for s in g.stats()["shards"]}
            g.search_plan_batch(sub, [None] * q, now=NOW, ks=[COHORT_K] * q)
            streams = max(s["corpus_streams"] - before[s["shard"]]
                          for s in g.stats()["shards"])
            t_cohort = _best(lambda: g.search_plan_batch(
                sub, [None] * q, now=NOW, ks=[COHORT_K] * q))
            row = {
                "n": n_target,
                "q": q,
                "total_ms": round(t_cohort * 1e3, 3),
                "per_query_ms": round(t_cohort * 1e3 / q, 3),
                "qps": round(q / t_cohort, 1),
                "bit_identical": identical,
                "corpus_streams_per_cohort": streams,
            }
            if q == q_max:
                row["speedup_vs_serial"] = round(t_serial / t_cohort, 2)
            rows[f"cohort_f32b_q{q}"] = row
            emit(f"pem/cohort_f32b_q{q}", t_cohort,
                 f"n={n_target} streams/cohort={streams} "
                 f"identical={identical} "
                 f"speedup={t_serial / t_cohort:.2f}x")

    queries = [_cohort_query_tokens(i).replace("pool:500", "pool:200")
               for i in range(COHORT_SERVE_REQUESTS)]
    for mode, max_batch in (("serve_serial", 1), ("serve_cohort", 16)):
        engine = BatchedRetrievalEngine(
            vc, max_batch=max_batch, max_wait_ms=2.0, now=NOW,
            engine="fused", pipeline=True)
        try:
            engine.search(queries[0], 10)  # warm plan/device caches
            wall, lat_ms = _closed_loop(engine, queries, load=16, k=10)
            st = engine.stats()
            rows[mode] = {
                "total_ms": round(wall * 1e3, 3),
                "requests": COHORT_SERVE_REQUESTS,
                "max_batch": max_batch,
                "qps": round(COHORT_SERVE_REQUESTS / wall, 1),
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "batches_served": engine.batches_served,
                "overlapped_collects": engine.overlapped_collects,
                "window_ms": st["window_ms"],
            }
            emit(f"pem/cohort_{mode}", wall,
                 f"{COHORT_SERVE_REQUESTS} reqs "
                 f"qps={rows[mode]['qps']} batches={engine.batches_served}")
        finally:
            engine.close()
    return rows


SERVE_LOADS = (4, 16, 48)     # concurrent closed-loop clients per level
SERVE_REQUESTS = 64           # requests per load level
SERVE_TOPICS = (
    "server lifecycle and restart policy",
    "identity provenance chain",
    "rendering pipeline cache",
    "auth token refresh flow",
    "database schema migration",
)
EMUDEV_DEVICE_MS = 40.0       # emulated accelerator pass per batch
EMUDEV_TAIL_MS = 30.0         # emulated host finishing stage per batch
EMUDEV_REQUESTS = 32
EMUDEV_BATCH = 8


def _measure_parallel_efficiency() -> float:
    """Calibrate the host: 2-thread speedup on cache-resident matmuls.

    ~2.0 means two genuinely usable cores (the pipelined real-workload
    rows can beat sync); ~1.0 means a one-core CPU quota (overlapping
    two CPU-bound stages cannot beat serial execution, and only the
    emulated-device rows can show the pipeline win)."""
    import threading
    import time as _time

    a = np.random.default_rng(0).standard_normal((256, 256)).astype(np.float32)

    def burn():
        x = a
        for _ in range(400):
            x = a @ a
        return x

    burn()
    t0 = _time.perf_counter()
    burn()
    single = _time.perf_counter() - t0
    threads = [threading.Thread(target=burn) for _ in range(2)]
    t0 = _time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dual = _time.perf_counter() - t0
    return round(2.0 * single / dual, 2)


def _closed_loop(engine, queries, load, k, repeats=2):
    """Serve ``queries`` with ``load`` closed-loop clients; returns
    (best_wall_s, latencies_of_best_run_ms)."""
    import concurrent.futures as cf
    import time as _time

    lats: list = []

    def client(q):
        t0 = _time.perf_counter()
        out = engine.search(q, k)
        lats.append(_time.perf_counter() - t0)
        return out

    best_wall, best_lats = None, None
    for _ in range(repeats):  # min: one-sided runner noise
        lats.clear()
        t0 = _time.perf_counter()
        with cf.ThreadPoolExecutor(load) as ex:
            list(ex.map(client, queries))
        wall = _time.perf_counter() - t0
        if best_wall is None or wall < best_wall:
            best_wall = wall
            best_lats = np.sort(np.asarray(lats)) * 1e3
    return best_wall, best_lats


def _bench_serve():
    """Offered-load sweep: sync-core vs pipelined continuous batching.

    Closed-loop clients (each issues its next request as soon as the
    previous answers) so the offered load is the concurrency level; the
    query mix is diverse/MMR-heavy so the host tail has real work for the
    pipeline to overlap with the next device pass.
    """
    from repro.serve.engine import BatchedRetrievalEngine

    conn, cache, chunks, emb = production_db()
    queries = [
        f"similar:{SERVE_TOPICS[i % len(SERVE_TOPICS)]} variant {i} "
        f"suppress:website landing page decay:30 diverse pool:200"
        for i in range(SERVE_REQUESTS)
    ]

    rows = {}
    for mode, pipelined in (("sync_core", False), ("pipelined", True)):
        engine = BatchedRetrievalEngine(
            cache, max_batch=16, max_wait_ms=1.0, now=NOW, engine="fused",
            pipeline=pipelined)
        try:
            engine.search(queries[0], 10)  # warm the plan/device caches
            total_s = 0.0
            sweep = {}
            for load in SERVE_LOADS:
                wall, lat_ms = _closed_loop(engine, queries, load, k=10)
                total_s += wall
                sweep[str(load)] = {
                    "qps": round(SERVE_REQUESTS / wall, 1),
                    "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                    "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                }
                emit(f"pem/serve_{mode}_load{load}", wall,
                     f"{SERVE_REQUESTS} reqs qps={sweep[str(load)]['qps']}")
            rows[mode] = {
                "total_ms": round(total_s * 1e3, 3),
                "requests": SERVE_REQUESTS,
                "loads": list(SERVE_LOADS),
                "overlapped_batches": engine.overlapped_batches,
                "batches_served": engine.batches_served,
                "sweep": sweep,
            }
        finally:
            engine.close()
    rows.update(_bench_serve_emudev())
    return rows


def _bench_serve_emudev():
    """The pinned overlap win: deterministic two-stage pipeline probe.

    The scoring pass models an accelerator busy for ``EMUDEV_DEVICE_MS``
    (wall time, zero host CPU); the host tail models ``EMUDEV_TAIL_MS``
    of finishing work on a dedicated core.  Fixed stage durations make
    the walls pure functions of the SCHEDULER — sync pays
    ``device + tail`` per batch, pipelined ``max(device, tail)`` — so
    breaking the pipeline shows up as a >1.5x regression of
    ``pipelined_emudev`` on any host, CPU quota or not.
    """
    import time as _time

    from repro.core.backends import FusedNumpyBackend
    from repro.core.vectorcache import VectorCache
    from repro.embed import HashEmbedder
    from repro.serve.engine import BatchedRetrievalEngine

    class EmulatedDeviceBackend(FusedNumpyBackend):
        name = "emulated-device"

        def score_select(self, *args, **kwargs):
            out = super().score_select(*args, **kwargs)  # tiny corpus: ~free
            _time.sleep(EMUDEV_DEVICE_MS / 1e3)          # device busy, host free
            return out

    class EmulatedTailEngine(BatchedRetrievalEngine):
        def _host_tail(self, work):
            _time.sleep(EMUDEV_TAIL_MS / 1e3)  # host finishing stage
            super()._host_tail(work)

    emb = HashEmbedder(DIM)
    rng = np.random.default_rng(3)
    n = 2048
    cache = VectorCache(np.arange(n),
                        rng.standard_normal((n, DIM)).astype(np.float32),
                        np.full(n, NOW - 86400.0), emb)
    queries = [
        f"similar:{SERVE_TOPICS[i % len(SERVE_TOPICS)]} variant {i}"
        for i in range(EMUDEV_REQUESTS)
    ]

    rows = {}
    # async_emudev pins the HOST-FREE overlap: with async dispatch the
    # serve loop itself stays live during the 40 ms device sleep, so the
    # next batch's admission window fills while the device is busy
    # (overlapped_collects) and the tail still overlaps the next pass
    # (overlapped_batches) — same max(device, tail) wall, counted holds.
    for mode, kw in (("sync_core_emudev", dict(pipeline=False)),
                     ("pipelined_emudev", dict(pipeline=True,
                                               async_dispatch=False)),
                     ("async_emudev", dict(pipeline=True,
                                           async_dispatch=True))):
        engine = EmulatedTailEngine(
            cache, max_batch=EMUDEV_BATCH, max_wait_ms=4.0, now=NOW,
            engine=EmulatedDeviceBackend(), **kw)
        try:
            engine.search(queries[0], 10)
            wall, lat_ms = _closed_loop(engine, queries, EMUDEV_REQUESTS,
                                        k=10)
            qps = round(EMUDEV_REQUESTS / wall, 1)
            emit(f"pem/serve_{mode}", wall,
                 f"{EMUDEV_REQUESTS} reqs qps={qps} "
                 f"overlap={engine.overlapped_batches}")
            rows[mode] = {
                "total_ms": round(wall * 1e3, 3),
                "qps": qps,
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "device_ms_per_batch": EMUDEV_DEVICE_MS,
                "tail_ms_per_batch": EMUDEV_TAIL_MS,
                "overlapped_batches": engine.overlapped_batches,
                "overlapped_collects": engine.overlapped_collects,
                "batches_served": engine.batches_served,
            }
        finally:
            engine.close()
    return rows


def _bench_ingest_durability():
    """Durable ingest: journaled INSERT latency + journal recovery time.

    Three claims, each a gated row:

    * ``insert_inline`` — ``INSERT INTO chunks`` with the embedder inline
      on the write path (no serving engine attached), journal fsync per
      mutation.  ``total_ms`` is the full cycle (all inserts + close
      checkpoint), so the journaling overhead itself gates.
    * ``insert_queued`` — the same inserts through the background
      vectorizer: the INSERT returns after enqueue + journal, embedding
      happens in the scheduler's idle gaps / the close flush.  The
      per-insert p50/p99 is the decoupling win (no embedder round-trip on
      the write path); ``total_ms`` again covers inserts + close, so
      deferring work can't game the gate.
    * ``recovery_snapshot`` / ``recovery_delta`` — ``SegmentedCorpusStore
      .open`` wall time right after a checkpoint (replay = 0 records) and
      after ``delta`` post-snapshot mutations.  An O(corpus) recovery —
      the exact failure snapshots exist to prevent — blows
      ``recovery_delta`` past tolerance immediately.
    """
    import shutil
    import tempfile
    import time as _time

    from repro.core.segments import SegmentedCorpusStore
    from repro.serve.retrieval import RetrievalService

    n_inserts = max(12, int(round(300 * SCALE)))
    rows = {}

    def insert_cycle(queued: bool):
        # production_db() is process-cached, so both cycles share one
        # sqlite db: each needs its own id range.
        base_id = 11_000_000 if queued else 10_000_000
        conn, _cache, _chunks, emb = production_db()
        tmp = tempfile.mkdtemp(prefix="flexvec-bench-ingest-")
        svc = RetrievalService(conn, dim=DIM, embedder=emb,
                               store_path=Path(tmp) / "store")
        lat = []
        try:
            if queued:
                svc.serving(max_wait_ms=1.0,
                            ingest_queue=max(1024, 2 * n_inserts))
            t_all = _time.perf_counter()
            for i in range(n_inserts):
                sql = ("INSERT INTO chunks (id, session_id, type, content,"
                       " created_at) VALUES "
                       f"({base_id + i}, 'bench-ingest', 'assistant', "
                       f"'durable ingest payload row {i} with enough text "
                       f"to embed', {float(NOW - i)})")
                t0 = _time.perf_counter()
                res = svc.flex_search(sql)
                lat.append((_time.perf_counter() - t0) * 1e3)
                assert res.ok, res.error
            embedded_async = (svc.stats()["ingest"]["embedded"]
                              if queued else 0)
            svc.close()  # queued: flushes the vectorizer, then checkpoints
            total_ms = (_time.perf_counter() - t_all) * 1e3
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        name = "insert_queued" if queued else "insert_inline"
        row = {
            "total_ms": round(total_ms, 3),
            "inserts": n_inserts,
            "p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3),
        }
        if queued:
            row["embedded_in_idle_gaps"] = int(embedded_async)
        emit(f"pem/ingest_{name}", total_ms / 1e3,
             f"{n_inserts} inserts p50={row['p50_ms']}ms "
             f"p99={row['p99_ms']}ms")
        rows[name] = row

    insert_cycle(queued=False)
    insert_cycle(queued=True)

    # recovery time: snapshot-only vs snapshot + delta replay
    base_rows = max(64, int(round(20_000 * SCALE)))
    delta = max(8, int(round(200 * SCALE)))
    rng = np.random.default_rng(17)
    tmp = tempfile.mkdtemp(prefix="flexvec-bench-recover-")
    try:
        path = Path(tmp) / "store"
        store = SegmentedCorpusStore.open(path, dim=DIM)
        store.append(np.arange(base_rows, dtype=np.int64),
                     rng.standard_normal((base_rows, DIM)).astype(np.float32),
                     np.full(base_rows, NOW))
        store.checkpoint()
        store.journal.close()

        def reopen():
            s = SegmentedCorpusStore.open(path, dim=DIM)
            s.journal.close()
            return s

        t_snap = _best(reopen)
        assert reopen().recovered_records == 0
        rows["recovery_snapshot"] = {
            "total_ms": round(t_snap * 1e3, 3),
            "rows": base_rows,
            "replayed_records": 0,
        }
        emit("pem/ingest_recovery_snapshot", t_snap,
             f"{base_rows} rows, 0 records replayed")

        store = SegmentedCorpusStore.open(path, dim=DIM)
        for j in range(delta):
            store.append(
                np.asarray([1_000_000 + j], dtype=np.int64),
                rng.standard_normal((1, DIM)).astype(np.float32),
                np.asarray([NOW]))
        store.journal.close()
        t_delta = _best(reopen)
        assert reopen().recovered_records == delta
        rows["recovery_delta"] = {
            "total_ms": round(t_delta * 1e3, 3),
            "rows": base_rows + delta,
            "replayed_records": delta,
        }
        emit("pem/ingest_recovery_delta", t_delta,
             f"{base_rows + delta} rows, {delta} records replayed")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def run_prefilter() -> None:
    """Standalone filtered-retrieval sweep (the old ``table3`` suite,
    folded into the snapshot's gated ``prefilter_backends`` scenario)."""
    _bench_prefilter()


def run() -> None:
    n, rows = _bench_backends()
    delta_rows = _bench_delta()
    prefilter_rows = _bench_prefilter()
    diverse_rows = _bench_diverse()
    panel_rows = _bench_filter_panel()
    hybrid_rows = _bench_hybrid()
    serve_rows = _bench_serve()
    scale1m_n, scale1m_rows = _bench_scale1m()
    cohort_rows = _bench_cohort_throughput()
    ingest_rows = _bench_ingest_durability()
    snapshot = {
        "bench": "pem_phase2_composed",
        "tokens": TOKENS,
        "corpus_chunks": n,
        "scale": SCALE,
        "dim": DIM,
        "platform": platform.machine(),
        "host": {"parallel_efficiency": _measure_parallel_efficiency()},
        "backends": rows,
        "delta_backends": delta_rows,
        "prefilter_backends": prefilter_rows,
        "diverse_backends": diverse_rows,
        "filter_panel": panel_rows,
        "hybrid_backends": hybrid_rows,
        "serve_throughput": serve_rows,
        "scale_1m": scale1m_rows,
        "scale_1m_chunks": scale1m_n,
        "cohort_throughput": cohort_rows,
        "ingest_durability": ingest_rows,
    }
    SNAPSHOT_PATH.write_text(json.dumps(snapshot, indent=2) + "\n")
    print(f"# wrote {SNAPSHOT_PATH}", flush=True)
