"""Table 3 — SQL pre-filtering effect on Phase-2 latency (paper §4.2).

Five filter configurations; timing is Phase 2 only (scoring + 3 modulations
+ MMR on the filtered candidate set), matching the paper's scope note.
"""

from __future__ import annotations

from benchmarks.common import NOW, emit, production_db, timed
from repro.core.grammar import parse
from benchmarks.latency import TOKENS_3MODS

FILTERS = {
    "full_corpus": None,
    "non_tool_30d": ("SELECT id FROM chunks WHERE type != 'tool_call' "
                     f"AND created_at > {NOW} - 30*86400"),
    "non_tool_7d": ("SELECT id FROM chunks WHERE type != 'tool_call' "
                    f"AND created_at > {NOW} - 7*86400"),
    "non_tool_24h": ("SELECT id FROM chunks WHERE type != 'tool_call' "
                     f"AND created_at > {NOW} - 86400"),
    "one_project_30d": ("SELECT id FROM chunks WHERE project = 'core' "
                        f"AND created_at > {NOW} - 30*86400"),
}


def run() -> None:
    conn, cache, chunks, emb = production_db()
    plan = parse(TOKENS_3MODS, emb, cache.embeddings_for_ids)
    for name, sql in FILTERS.items():
        candidate_ids = None
        n = cache.matrix.shape[0]
        if sql is not None:
            candidate_ids = [r[0] for r in conn.execute(sql).fetchall()]
            n = len(candidate_ids)
        if n == 0:
            emit(f"table3/{name}", 0.0, "candidates=0 (skipped)")
            continue
        t = timed(lambda: cache.search_plan(plan, candidate_ids, now=NOW))
        emit(f"table3/{name}", t, f"candidates={n}")
