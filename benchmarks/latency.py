"""Table 2 — latency breakdown on the production corpus (paper §4.1).

Rows: base matmul / scoring+3 mods+MMR (Phase 2 only) / full pipeline /
FTS5 keyword / hybrid JOIN. Both engines are reported: `reference`
(paper-faithful, one matvec per direction) and `fused` (folded two-matvec,
the beyond-paper formulation).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import NOW, emit, production_db, timed
from repro.core.grammar import parse
from repro.core import modulations as M
from repro.core.materializer import Materializer

TOKENS_3MODS = (
    "similar:how the system works architecture diverse "
    "suppress:website landing page design tagline "
    "suppress:documentation readme community post"
)

FULL_SQL = (
    "SELECT v.id, v.score, m.content FROM vec_ops("
    f"'{TOKENS_3MODS}',"
    "'SELECT id FROM messages WHERE type = ''assistant'' "
    "AND length(content) > 300') v "
    "JOIN messages m ON v.id = m.id ORDER BY v.score DESC LIMIT 5"
)

HYBRID_SQL = (
    "SELECT k.id, k.score, v.score, m.content FROM keyword('server') k "
    "JOIN vec_ops('similar:server lifecycle debugging diverse') v ON k.id = v.id "
    "JOIN messages m ON k.id = m.id ORDER BY v.score DESC LIMIT 10"
)


def run() -> None:
    conn, cache, chunks, emb = production_db()
    n = cache.matrix.shape[0]
    q = cache.matrix[0]

    t = timed(lambda: cache.matrix @ q)
    emit("table2/base_matmul", t, f"n={n} d={cache.dim}")

    plan = parse(TOKENS_3MODS, emb, cache.embeddings_for_ids)
    for engine in ("reference", "fused"):
        t = timed(lambda: cache.search_plan(plan, now=NOW, engine=engine))
        emit(f"table2/phase2_3mods_mmr_{engine}", t, "phase2-only")

    for engine in ("reference", "fused"):
        mz = Materializer(conn, cache, now=NOW, engine=engine)
        t = timed(lambda: mz.execute(FULL_SQL))
        emit(f"table2/full_pipeline_{engine}", t, "all-phases")

    mz = Materializer(conn, cache, now=NOW)
    t = timed(lambda: mz.execute("SELECT k.id, k.score FROM keyword('server') k "
                                 "ORDER BY k.score DESC LIMIT 10"))
    emit("table2/fts5_keyword", t)

    t = timed(lambda: mz.execute(HYBRID_SQL))
    emit("table2/hybrid_join", t, "all-phases")
