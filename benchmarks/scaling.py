"""Table 4 — corpus scaling 250K -> 1M chunks (paper §4.3).

Larger corpora are constructed by combining embedding matrices (the paper
does exactly this: 'constructed larger corpora by combining embeddings from
multiple production datasets'). Reports base matmul, full Phase-2 pipeline
(scoring + 3 mods + MMR), and the matrix's memory footprint — plus, per
size, the cross-process shard-group pass (``table4/sharded_*``: the
``ProcessGroup`` blocked single-stream ``f32b`` fan-out the ``scale_1m``
snapshot scenario gates), so the monolith-vs-sharded crossover is
readable off one sweep.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import NOW, SCALE, emit, production_db, timed
from benchmarks.latency import TOKENS_3MODS
from repro.core.grammar import parse
from repro.core.vectorcache import VectorCache

SIZES = [int(s * SCALE) for s in (250_000, 500_000, 750_000, 1_000_000)]


def run() -> None:
    conn, cache, chunks, emb = production_db()
    base = cache.matrix
    ts = cache.timestamps
    rng = np.random.default_rng(0)
    for target in SIZES:
        target = max(target, 1000)
        reps = int(np.ceil(target / base.shape[0]))
        mats, tss = [], []
        for r in range(reps):
            m = base if r == 0 else base + rng.normal(
                0, 0.05, base.shape).astype(np.float32)
            m = m / np.linalg.norm(m, axis=1, keepdims=True)
            mats.append(m)
            tss.append(ts)
        matrix = np.concatenate(mats)[:target]
        big = VectorCache(np.arange(target), matrix,
                          np.concatenate(tss)[:target], emb, normalized=True)
        plan = parse(TOKENS_3MODS, emb, big.embeddings_for_ids)
        q = matrix[0]
        t_mm = timed(lambda: matrix @ q, repeats=3)
        t_full = timed(lambda: big.search_plan(plan, now=NOW), repeats=3)
        mem_mb = matrix.nbytes / 1e6
        emit(f"table4/matmul_{target}", t_mm, f"n={target}")
        emit(f"table4/full_{target}", t_full, f"n={target} mem={mem_mb:.0f}MB")

        # the sharded comparator: same rows dealt across a 4-shard
        # ProcessGroup, blocked single-stream f32b per-shard scoring
        # (the scale_1m headline path), timed on the same plan
        from benchmarks.pem_snapshot import _scale1m_transport
        from repro.dist.procgroup import ProcessGroup

        n_aligned = target - target % (4 * 32)
        with ProcessGroup.build(np.arange(n_aligned), matrix[:n_aligned],
                                np.concatenate(tss)[:n_aligned],
                                normalized=True, n_shards=4,
                                transport=_scale1m_transport(),
                                dtype="f32b") as group:
            t_shard = timed(lambda: group.search_plan(plan, now=NOW),
                            repeats=3)
        emit(f"table4/sharded_{target}", t_shard,
             f"n={n_aligned} shards=4 f32b vs mono={t_full*1e3:.1f}ms")
