"""Tables 5 & 6 — behavioral validation on four BEIR-like corpora (§4.4).

Per modulation, the paper's diagnostic metric:
    diverse      ILS reduction (10-40% band) + nDCG@10 retention (Table 6)
    suppress:X   RBO vs baseline well below 1 (band 0.19-0.41)
    decay:7      mean result age shift (tens of days on 90-day spread)
    centroid:ids centroid similarity gain (+0.05..+0.12)
    from:/to:    RBO vs baseline (band 0.08-0.25)

Synthetic stand-ins preserve structure (DESIGN.md §7): direction/band is the
validation target, not the paper's exact decimals. 30 queries per dataset,
by insertion order (paper Appendix A).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import DIM, emit
from repro.core import modulations as M
from repro.core.vectorcache import VectorCache
from repro.data.beir import DATASET_SPECS, make_dataset
from repro.embed import HashEmbedder
from repro.metrics import centroid_similarity, ils, ndcg_at_k, rbo

N_QUERIES = 30
K = 10


def _setup(name: str):
    emb = HashEmbedder(DIM)
    ds = make_dataset(name)
    matrix = emb.embed_batch(ds.doc_texts)
    cache = VectorCache(np.arange(len(ds.doc_texts)), matrix, ds.timestamps, emb)
    return emb, ds, cache


def _rank(cache: VectorCache, plan: M.ModulationPlan, now: float) -> List[int]:
    return [i for i, _ in cache.search_plan(plan, now=now)][:K]


def run() -> None:
    t6_rows = []
    for name in DATASET_SPECS:
        emb, ds, cache = _setup(name)
        base_ndcg, div_ndcg = [], []
        base_ils, div_ils = [], []
        rbo_sup, rbo_traj = [], []
        age_shift, cent_gain = [], []
        for qi in range(min(N_QUERIES, len(ds.queries))):
            q = M.l2_normalize(emb(ds.queries[qi]))
            qrels = ds.qrels[qi]
            base_plan = M.ModulationPlan(query=np.asarray(q))
            base = _rank(cache, base_plan, ds.now)
            base_ndcg.append(ndcg_at_k(base, qrels, K))
            base_ils.append(ils(cache.matrix[base]))

            # diverse
            div = _rank(cache, M.ModulationPlan(
                query=np.asarray(q), diverse=M.DiverseSpec()), ds.now)
            div_ndcg.append(ndcg_at_k(div, qrels, K))
            div_ils.append(ils(cache.matrix[div]))

            # suppress: the dominant-cluster direction = centroid of the
            # baseline top-3 (the paper's 'named concept' use case)
            sup_dir = M.l2_normalize(cache.matrix[base[:3]].mean(axis=0))
            sup = _rank(cache, M.ModulationPlan(
                query=np.asarray(q),
                suppress=(M.SuppressSpec(direction=np.asarray(sup_dir)),)), ds.now)
            rbo_sup.append(rbo(base, sup))

            # decay:7
            dec = _rank(cache, M.ModulationPlan(
                query=np.asarray(q), decay=M.DecaySpec(7.0)), ds.now)
            age = lambda rows: float(np.mean(
                (ds.now - ds.timestamps[rows]) / 86400.0))
            age_shift.append(age(base) - age(dec))

            # centroid from relevant seeds the words did NOT surface (the
            # paper's use case: anchor to a facet the text query missed)
            deep = [r for r in qrels if r not in base][:5]
            seeds = deep or base[:3]
            cent = _rank(cache, M.ModulationPlan(
                query=np.asarray(q),
                centroid=M.CentroidSpec(examples=cache.matrix[seeds])), ds.now)
            cent_gain.append(
                centroid_similarity(cache.matrix[cent], cache.matrix[seeds])
                - centroid_similarity(cache.matrix[base], cache.matrix[seeds]))

            # trajectory between two random docs' directions
            a, b = cache.matrix[(qi * 7) % len(ds.doc_texts)], \
                cache.matrix[(qi * 13 + 5) % len(ds.doc_texts)]
            traj = _rank(cache, M.ModulationPlan(
                query=np.asarray(q),
                trajectory=M.TrajectorySpec(direction=b - a)), ds.now)
            rbo_traj.append(rbo(base, traj))

        b_ndcg = float(np.mean(base_ndcg))
        d_ndcg = float(np.mean(div_ndcg))
        ils_red = 1.0 - float(np.mean(div_ils)) / max(float(np.mean(base_ils)), 1e-9)
        retention = d_ndcg / max(b_ndcg, 1e-9)
        emit(f"table5/{name}/diverse_ils_reduction", 0.0, f"{ils_red:.3f}")
        emit(f"table5/{name}/suppress_rbo", 0.0, f"{float(np.mean(rbo_sup)):.3f}")
        emit(f"table5/{name}/decay7_age_shift_days", 0.0,
             f"{float(np.mean(age_shift)):.1f}")
        emit(f"table5/{name}/centroid_sim_gain", 0.0,
             f"{float(np.mean(cent_gain)):+.3f}")
        emit(f"table5/{name}/trajectory_rbo", 0.0, f"{float(np.mean(rbo_traj)):.3f}")
        t6_rows.append((name, b_ndcg, d_ndcg, retention, ils_red))

    for name, b, d, r, i_red in t6_rows:
        emit(f"table6/{name}", 0.0,
             f"baseline_ndcg={b:.3f} diverse_ndcg={d:.3f} "
             f"retention={r:.2f} ils_reduction={i_red:.2f}")
