"""CI bench-regression gate: diff fresh PEM snapshot(s) against a baseline.

    FLEX_BENCH_SCALE=0.02 FLEX_BENCH_OUT=/tmp/BENCH_pem.new.json \
        PYTHONPATH=src python -m benchmarks.run pem
    PYTHONPATH=src python -m benchmarks.check_regression \
        /tmp/BENCH_pem.new.json BENCH_pem.smoke.json

Several fresh snapshots may be passed (baseline last); the gate takes the
per-backend MINIMUM ``total_ms`` across them.  Latency noise on shared CI
runners is one-sided — a contended run only ever reads slow — so CI runs
the smoke bench twice and a single noisy window cannot fail the gate,
while a real regression shows up in every run.

Per-row ``total_ms`` — the ``backends`` section (fused score->select
latency), the ``delta_backends`` section (the append+query / delete+query
liveness cycle over the segmented store), the ``serve_throughput``
section (the offered-load sweep through the continuous-batching engine,
one row per scheduler mode: ``sync_core`` / ``pipelined``) and the
``prefilter_backends`` section (the Phase-1 filtered-retrieval
selectivity sweep; ``total_ms`` sums the ROUTED path across
selectivities, so a mis-tuned router or a slowed masked path both
gate), the ``diverse_backends`` section (the fully-fused in-graph
device-MMR lambda sweep), the ``filter_panel`` section (the
heterogeneous-filter (N, B) mask-panel cohort vs per-filter serial
dispatch), the ``hybrid_backends`` section (the dual-leg
lexical+vector fusion query; ``total_ms`` is the hybrid device path, so
a fusion bias that stops riding the fused pass and falls back to a
second retrieval gates) and the ``scale_1m`` section (the cross-process
shard-group corpus pass — rows keyed by scoring mode, always present at
the smoke scale so dropping or regressing the sharded path gates even
when CI cannot afford the full million-chunk corpus) and the
``cohort_throughput`` section (cohort-streamed scoring: the Q-query
shard-group panel pass vs the serial per-query comparator plus the
closed-loop serving rows, so both an un-amortized corpus stream and a
broken batch window gate) and the ``ingest_durability`` section (the
WAL-journaled ingest cycle: sync-inline vs queued-worker INSERT
latency plus snapshot/delta recovery time, so a slowed journal fsync
path, a broken idle-gap drain or an O(corpus) recovery all gate) — is
compared against the committed ``BENCH_pem.smoke.json`` baseline; the gate
fails on a > ``FLEX_BENCH_TOL`` (default 1.5) ratio for ANY backend that
is not recorded as skipped in the baseline.  A backend present in the
baseline but MISSING from the new snapshot fails too — silent omission is
exactly the failure mode ``{"skipped": ...}`` recording exists to prevent
— and so does a baseline-measured backend that starts reporting
``skipped`` (its perf trajectory would otherwise end without a signal;
regenerate the baseline if the skip is intentional).

The gate compares ABSOLUTE milliseconds, so the committed baseline must
come from the same platform class CI runs on (x86 CPU); the tolerance is
deliberately loose to absorb runner jitter, and the ``FLEX_BENCH_TOL``
env var overrides it when a PR intentionally trades latency or a runner
generation shifts the floor.
"""

from __future__ import annotations

import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Tuple

DEFAULT_TOL = 1.5


def compare(
    new: Dict, baseline: Dict, tol: float, section: str = "backends"
) -> Tuple[List[str], List[str]]:
    """Diff one per-backend section of two snapshot dicts.

    ``section`` is ``"backends"`` (the fused query path),
    ``"delta_backends"`` (the append+query/delete+query liveness cycle),
    ``"serve_throughput"`` (the offered-load serving sweep, rows keyed
    by scheduler mode), ``"prefilter_backends"`` (the filtered-
    retrieval selectivity sweep), ``"diverse_backends"`` (the fused
    device-MMR sweep) or ``"filter_panel"`` (the (N, B) mask-panel
    cohort sweep); all gate under the same tolerance and skipped-row
    rules.  Returns (failures, notes)."""
    failures: List[str] = []
    notes: List[str] = []
    tag = "" if section == "backends" else f"{section}/"
    new_backends = new.get(section, {})
    for name, base_row in sorted(baseline.get(section, {}).items()):
        new_row = new_backends.get(name)
        name = tag + name  # message label only; lookups use the bare name
        if new_row is None:
            failures.append(
                f"{name}: present in baseline but MISSING from the new "
                f"snapshot (skipped backends must be recorded as "
                f'{{"skipped": "<reason>"}})'
            )
            continue
        if "skipped" in new_row:
            if "skipped" in base_row:
                notes.append(f"{name}: skipped on this platform "
                             f"({new_row['skipped']})")
            else:
                # the baseline measured this backend on the same platform
                # class: a skip here silently ENDS its perf trajectory
                failures.append(
                    f"{name}: measured in baseline "
                    f"({float(base_row['total_ms']):.3f} ms) but skipped in "
                    f"the new snapshot ({new_row['skipped']}) — regenerate "
                    f"the baseline if the skip is intentional")
            continue
        if "skipped" in base_row:
            notes.append(f"{name}: no baseline (baseline skipped: "
                         f"{base_row['skipped']}); measured "
                         f"{new_row['total_ms']:.3f} ms")
            continue
        base_ms = float(base_row["total_ms"])
        new_ms = float(new_row["total_ms"])
        ratio = new_ms / base_ms if base_ms > 0 else float("inf")
        line = (f"{name}: {base_ms:.3f} ms -> {new_ms:.3f} ms "
                f"({ratio:.2f}x, tol {tol:.2f}x)")
        if ratio > tol:
            failures.append("REGRESSION " + line)
        else:
            notes.append(line)
    for name in sorted(set(new_backends) - set(baseline.get(section, {}))):
        notes.append(f"{tag}{name}: new backend, no baseline yet")
    return failures, notes


def compare_all(
    new: Dict, baseline: Dict, tol: float
) -> Tuple[List[str], List[str]]:
    """Gate every per-backend section the baseline carries.

    A baseline without ``delta_backends`` / ``serve_throughput``
    (pre-liveness / pre-async snapshots) just skips that section; a
    baseline WITH it and a new snapshot missing the whole section
    entirely fails — dropping the scenario is the section-level flavor
    of silent omission."""
    failures: List[str] = []
    notes: List[str] = []
    for section in ("backends", "delta_backends", "serve_throughput",
                    "prefilter_backends", "diverse_backends",
                    "filter_panel", "hybrid_backends", "scale_1m",
                    "cohort_throughput", "ingest_durability"):
        if section not in baseline:
            continue
        if section != "backends" and section not in new:
            failures.append(
                f"{section}: section present in baseline but missing from "
                f"the new snapshot (the scenario was dropped)")
            continue
        f, n = compare(new, baseline, tol, section)
        failures += f
        notes += n
    return failures, notes


def merge_min(snapshots: List[Dict]) -> Dict:
    """Fold several fresh snapshots into one: per backend (and section),
    the fastest measured row wins (one-sided noise); skips survive only
    if a backend never measured."""
    merged: Dict = dict(snapshots[0])
    for section in ("backends", "delta_backends", "serve_throughput",
                    "prefilter_backends", "diverse_backends",
                    "filter_panel", "hybrid_backends", "scale_1m",
                    "cohort_throughput", "ingest_durability"):
        backends: Dict[str, Dict] = {}
        for snap in snapshots:
            for name, row in snap.get(section, {}).items():
                best = backends.get(name)
                if "skipped" in row:
                    backends.setdefault(name, row)
                elif (best is None or "skipped" in best
                      or float(row["total_ms"]) < float(best["total_ms"])):
                    backends[name] = row
        if backends or section in merged:
            merged[section] = backends
    return merged


def main(argv: List[str]) -> int:
    if len(argv) < 2:
        print("usage: python -m benchmarks.check_regression "
              "<new_snapshot.json> [<more_new.json> ...] <baseline.json>",
              file=sys.stderr)
        return 2
    new = merge_min([json.loads(Path(p).read_text()) for p in argv[:-1]])
    baseline = json.loads(Path(argv[-1]).read_text())
    tol = float(os.environ.get("FLEX_BENCH_TOL", DEFAULT_TOL))
    failures, notes = compare_all(new, baseline, tol)
    for line in notes:
        print(f"  ok  {line}")
    for line in failures:
        print(f"FAIL  {line}")
    if failures:
        print(f"\nbench gate: {len(failures)} failure(s) "
              f"(tolerance {tol}x; override with FLEX_BENCH_TOL)")
        return 1
    print(f"\nbench gate: green ({len(notes)} backend(s) checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
