"""Shared benchmark fixtures: the production-like corpus + database.

Built once per process (module cache). ``FLEX_BENCH_SCALE`` < 1.0 shrinks
everything for smoke runs (tests set 0.02).
"""

from __future__ import annotations

import os
import sqlite3
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.vectorcache import VectorCache
from repro.data.corpus import Chunk, build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.sqlio.schema import load_embedding_matrix

SCALE = float(os.environ.get("FLEX_BENCH_SCALE", "1.0"))
N_CHUNKS = max(2000, int(240_000 * SCALE))
N_SESSIONS = max(50, int(4_000 * SCALE))
NOW = 1_770_000_000.0
DIM = 128

_cache: Dict[str, object] = {}


def production_db() -> Tuple[sqlite3.Connection, VectorCache, list, HashEmbedder]:
    if "db" not in _cache:
        emb = HashEmbedder(DIM)
        t0 = time.time()
        chunks = generate_corpus(n_chunks=N_CHUNKS, n_sessions=N_SESSIONS,
                                 seed=0, now=NOW)
        conn = sqlite3.connect(":memory:", check_same_thread=False)
        build_database(conn, chunks, emb)
        ids, matrix, ts = load_embedding_matrix(conn, DIM)
        cache = VectorCache(ids, matrix, ts, emb)
        print(f"# built corpus n={N_CHUNKS} in {time.time()-t0:.1f}s", flush=True)
        _cache["db"] = (conn, cache, chunks, emb)
    return _cache["db"]  # type: ignore[return-value]


def timed(fn, *, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall seconds, warm cache (paper methodology)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived (harness contract)."""
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
