"""ExecutionBackend equivalence: every registered backend vs the oracle.

The registry (repro/core/backends.py) is the single engine-dispatch seam;
these tests pin each backend to the paper-faithful reference pipeline on
fully composed plans (suppress + decay + trajectory + centroid + diverse),
including the empty-candidate and no-timestamps edge cases, and assert the
batched engine and the direct VectorCache path rank identically through
the shared selection helper.
"""

import concurrent.futures as cf

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.backends import get_backend, list_backends, select_candidates
from repro.core.grammar import GrammarError
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder

BACKENDS = list_backends()
NOW = 90 * 86400.0

EMB = HashEmbedder(32)


def _corpus(n=160, d=32, seed=3):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    return mat, days


def _composed_plan(mat, *, diverse=True, decay=True):
    """suppress + decay + trajectory + centroid (+ diverse): every modulation."""
    q = M.l2_normalize(EMB("how the retrieval system works"))
    a = M.l2_normalize(EMB("prototype sketch"))
    b = M.l2_normalize(EMB("production deployment"))
    x1 = M.l2_normalize(EMB("website landing page"))
    x2 = M.l2_normalize(EMB("marketing tagline"))
    return M.ModulationPlan(
        query=q,
        centroid=M.CentroidSpec(examples=mat[:4]),
        trajectory=M.TrajectorySpec(direction=b - a),
        decay=M.DecaySpec(half_life_days=14.0) if decay else None,
        suppress=(M.SuppressSpec(direction=x1),
                  M.SuppressSpec(direction=x2, weight=0.3)),
        diverse=M.DiverseSpec() if diverse else None,
        pool=25,
    )


def test_registry_contains_all_five():
    assert {"reference-numpy", "fused-numpy", "jit-jax", "pallas",
            "sharded"} <= set(BACKENDS)
    # seed aliases resolve to the same instances
    assert get_backend("reference") is get_backend("reference-numpy")
    assert get_backend("fused") is get_backend("fused-numpy")
    with pytest.raises(ValueError):
        get_backend("no-such-engine")


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_scores_match_oracle_composed(backend):
    mat, days = _corpus()
    plan = _composed_plan(mat)
    oracle = np.asarray(M.modulate_scores(mat, days, plan))
    got = get_backend(backend).score(mat, days, plan)
    np.testing.assert_allclose(got, oracle, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_panel_matches_oracle_mixed_batch(backend):
    """A micro-batch mixing decay half-lives and no-decay plans."""
    mat, days = _corpus(seed=5)
    plans = [
        _composed_plan(mat, diverse=False),
        _composed_plan(mat, diverse=False, decay=False),
        M.ModulationPlan(query=M.l2_normalize(EMB("plain query")),
                         decay=M.DecaySpec(half_life_days=30.0)),
    ]
    panel = get_backend(backend).score_panel(mat, days, plans)
    assert panel.shape == (mat.shape[0], len(plans))
    for j, plan in enumerate(plans):
        oracle = np.asarray(M.modulate_scores(mat, days, plan))
        np.testing.assert_allclose(panel[:, j], oracle, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_ranking_matches_reference_through_vectorcache(backend):
    """End-to-end search_plan: identical candidate ids for every backend,
    on a composed plan INCLUDING diverse (MMR runs on the shared helper)."""
    mat, days = _corpus(seed=7)
    ts = NOW - days.astype(np.float64) * 86400.0
    vc = VectorCache(np.arange(mat.shape[0]), mat, ts, EMB, normalized=True)
    plan = _composed_plan(vc.matrix)
    ref = vc.search_plan(plan, now=NOW, engine="reference-numpy")
    got = vc.search_plan(plan, now=NOW, engine=backend)
    assert [i for i, _ in got] == [i for i, _ in ref]
    np.testing.assert_allclose([s for _, s in got], [s for _, s in ref],
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_empty_candidates(backend):
    """Phase-1 pre-filters that match nothing yield an empty result."""
    mat, days = _corpus()
    ts = NOW - days.astype(np.float64) * 86400.0
    vc = VectorCache(np.arange(mat.shape[0]), mat, ts, EMB, normalized=True)
    plan = _composed_plan(vc.matrix)
    assert vc.search_plan(plan, candidate_ids=[99999], now=NOW,
                          engine=backend) == []


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_no_timestamps(backend):
    """Without timestamps: non-decay plans work, decay plans raise."""
    mat, _ = _corpus()
    vc = VectorCache(np.arange(mat.shape[0]), mat, None, EMB,
                     normalized=True)
    ok_plan = _composed_plan(vc.matrix, decay=False)
    res = vc.search_plan(ok_plan, now=NOW, engine=backend)
    assert len(res) == min(ok_plan.pool, mat.shape[0])
    bad_plan = _composed_plan(vc.matrix, decay=True)
    with pytest.raises(ValueError, match="decay"):
        vc.search_plan(bad_plan, now=NOW, engine=backend)
    # panel path enforces the same contract per-plan
    with pytest.raises(ValueError, match="decay"):
        get_backend(backend).score_panel(mat, None, [bad_plan])


def test_selection_oversample_alignment():
    """Direct (k=pool) and batched (small k) draw from the same MMR pool, so
    the batched ranking is a prefix of the direct one (satellite: the
    engine.py / vectorcache.py oversample semantics are now shared)."""
    mat, days = _corpus(seed=11)
    plan = _composed_plan(mat)
    scores = np.asarray(M.modulate_scores(mat, days, plan))
    direct = select_candidates(mat, scores, min(plan.pool, len(scores)), plan)
    batched = select_candidates(mat, scores, 5, plan)
    assert list(batched) == list(direct[:5])


def test_batched_engine_isolates_bad_request():
    """A GrammarError in one request fails ONLY that request; the rest of
    the batch is served (no batch-wide timeout)."""
    emb = HashEmbedder(64)
    texts = [f"item group {i % 7} tail {i}" for i in range(200)]
    vc = VectorCache(np.arange(200), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 200), emb)
    from repro.serve.engine import BatchedRetrievalEngine

    eng = BatchedRetrievalEngine(vc, max_batch=8, now=NOW)
    try:
        tokens = ["similar:group 1 tail decay:7",
                  "similar:group 2 tail decay:not_a_number",   # bad
                  "similar:group 3 tail"]
        with cf.ThreadPoolExecutor(3) as ex:
            futs = [ex.submit(eng.search, t, 5, 10.0) for t in tokens]
            results = []
            for f in futs:
                try:
                    results.append(f.result())
                except GrammarError as e:
                    results.append(e)
        assert isinstance(results[1], GrammarError)
        assert len(results[0]) == 5 and len(results[2]) == 5
        direct = vc.search(tokens[0], now=NOW)[:5]
        assert [i for i, _ in results[0]] == [i for i, _ in direct]
    finally:
        eng.close()


def test_batched_engine_isolates_decay_without_timestamps():
    """decay on a timestamp-less cache fails that request, not the batch."""
    emb = HashEmbedder(64)
    texts = [f"item group {i % 7} tail {i}" for i in range(100)]
    vc = VectorCache(np.arange(100), emb.embed_batch(texts), None, emb)
    from repro.serve.engine import BatchedRetrievalEngine

    eng = BatchedRetrievalEngine(vc, max_batch=4, now=NOW)
    try:
        with cf.ThreadPoolExecutor(2) as ex:
            good = ex.submit(eng.search, "similar:group 1 tail", 5, 10.0)
            bad = ex.submit(eng.search, "similar:group 2 decay:7", 5, 10.0)
            assert len(good.result()) == 5
            with pytest.raises(ValueError, match="decay"):
                bad.result()
    finally:
        eng.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_engine_any_backend_matches_direct(backend):
    """The engine serves identically through every registered backend."""
    emb = HashEmbedder(64)
    texts = [f"item group {i % 5} tail {i}" for i in range(150)]
    vc = VectorCache(np.arange(150), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 150), emb)
    from repro.serve.engine import BatchedRetrievalEngine

    eng = BatchedRetrievalEngine(vc, max_batch=8, now=NOW, engine=backend)
    try:
        tokens = [f"similar:group {i % 5} tail decay:14" for i in range(6)]
        with cf.ThreadPoolExecutor(6) as ex:
            batched = list(ex.map(lambda t: eng.search(t, 5), tokens))
        for t, b in zip(tokens, batched):
            direct = vc.search(t, now=NOW)[:5]
            assert [i for i, _ in b] == [i for i, _ in direct]
    finally:
        eng.close()
