"""Fused on-device MMR + (N, B) mask-panel batching: the Phase-2 fusion
contracts.

1. **Device-MMR equivalence** — every backend that fuses MMR into the
   device score->select graph (``backend.device_mmr``) returns the FINAL
   diverse selection bit-identical to the :func:`mmr_host` oracle, for
   lam in {0, 0.3, 0.7, 1.0}, across segmentations, tombstones, and
   candidate filters that overlap the tombstones.  Host backends keep
   the oversample-pool contract and finish through the same oracle.
2. **Tie order** — duplicate-embedding ties resolve first-occurrence
   (smallest global row) on device exactly like the host argmax.
3. **Counters** — diverse queries on device_mmr backends pin
   ``device_mmr > 0`` and ``host_pool_transfers == 0``; numpy backends
   pin the reverse.  A B=16 heterogeneous-filter cohort pins EXACTLY ONE
   backend scoring pass through the (N, B) panel driver.
4. **Panel equivalence** — ``candidate_mask_panel`` column semantics
   (filtered / unfiltered / no-hit), and ``score_select_filter_panel``
   bit-identical to per-filter serial dispatch.
"""

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.backends import (FusedCounters, FusedNumpyBackend,
                                 PrefilterRouter, get_backend, list_backends,
                                 mmr_host, score_select_filter_panel,
                                 score_select_prefiltered,
                                 score_select_segments, selection_width,
                                 top_idx)
from repro.core.segments import SegmentedCorpusStore, gather_ids
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder

BACKENDS = list_backends()
DEVICE_BACKENDS = [b for b in BACKENDS if get_backend(b).device_mmr]
HOST_BACKENDS = [b for b in BACKENDS if not get_backend(b).device_mmr]
LAMBDAS = [0.0, 0.3, 0.7, 1.0]
NOW = 90 * 86400.0
EMB = HashEmbedder(32)


def _corpus(n=230, d=32, seed=7):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    ts = NOW - days.astype(np.float64) * 86400.0
    return mat, days, ts


def _diverse_plan(lam, *, pool=20, decay=True):
    return M.ModulationPlan(
        query=M.l2_normalize(EMB("how the retrieval system works")),
        decay=M.DecaySpec(half_life_days=21.0) if decay else None,
        suppress=(M.SuppressSpec(direction=M.l2_normalize(
            EMB("website landing page"))),),
        diverse=M.DiverseSpec(lam=lam),
        pool=pool,
    )


def _store_from_splits(mat, ts, splits, deleted=()):
    store = SegmentedCorpusStore(dim=mat.shape[1])
    start = 0
    for size in splits:
        store.append(np.arange(start, start + size), mat[start:start + size],
                     ts[start:start + size], normalized=True)
        start += size
    assert start == mat.shape[0]
    if len(deleted):
        store.delete(deleted)
    return store


def _host_oracle(mat, days, plan, k):
    """select_candidates spelled out: top-pool then mmr_host — THE answer
    every fused path must reproduce bit-for-bit."""
    scores = np.asarray(M.modulate_scores(mat, days, plan))
    w = selection_width(plan, k, scores.shape[0])
    pool = top_idx(scores, w)
    sel = mmr_host(mat[pool], scores[pool], min(k, w), plan.diverse.lam)
    return pool[sel], scores[pool[sel]]


# ---------------------------------------------------------------------------
# Device-MMR equivalence vs the host oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lam", LAMBDAS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_monolithic_device_mmr_matches_host_oracle(backend, lam):
    """score_select on a monolithic matrix: device_mmr backends return the
    final-k MMR selection bit-identical to the host oracle; host backends
    return the pool and finalize through the same oracle."""
    mat, days, _ = _corpus(seed=int(lam * 10) + 3)
    plan = _diverse_plan(lam)
    k = plan.pool
    oidx, ovals = _host_oracle(mat, days, plan, k)

    b = get_backend(backend)
    (idx, vals), = b.score_select(mat, days, [plan], [k])
    if b.device_mmr:
        assert idx.shape == (k,)
        assert list(idx) == list(oidx)
        np.testing.assert_allclose(vals, ovals, atol=5e-5, rtol=5e-5)
    else:
        w = selection_width(plan, k, mat.shape[0])
        assert idx.shape == (w,)
        sel = mmr_host(mat[idx], np.asarray(vals), k, lam)
        assert list(idx[sel]) == list(oidx)


SEGMENTATIONS = [
    ("one-segment", [230], ()),
    ("three-segments", [100, 60, 70], ()),
    ("tombstones", [150, 80], tuple(range(10, 60)) + (200, 229)),
    ("tombstones-seven", [40, 40, 40, 40, 40, 20, 10],
     tuple(range(0, 230, 3))),
]


@pytest.mark.parametrize(
    "splits,deleted", [(s, d) for _, s, d in SEGMENTATIONS],
    ids=[name for name, _, _ in SEGMENTATIONS])
@pytest.mark.parametrize("lam", [0.0, 0.7])
@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_device_mmr_matches_host_oracle(backend, lam, splits,
                                                  deleted):
    """Any segmentation, with tombstones: the segment driver's diverse
    results — device-finalized or host-finished — match the monolithic
    host oracle over the live rows bit-for-bit."""
    mat, days, ts = _corpus(seed=11)
    store = _store_from_splits(mat, ts, splits, deleted)
    live = np.setdiff1d(np.arange(mat.shape[0]), np.asarray(deleted, int))
    plan = _diverse_plan(lam, pool=15)
    k = plan.pool
    oidx, ovals = _host_oracle(mat[live], days[live], plan, k)

    b = get_backend(backend)
    counters = FusedCounters()
    (gidx, vals), = score_select_segments(b, store.segments, [plan], [k],
                                          now=NOW, counters=counters)
    if b.device_mmr:
        # device-finalized: final k, ids == oracle, no host pool transfer
        assert gidx.shape == (k,)
        assert list(gather_ids(store.segments, gidx)) == list(live[oidx])
        np.testing.assert_allclose(vals, ovals, atol=5e-5, rtol=5e-5)
        assert counters.device_mmr == 1
    else:
        ids = np.asarray(gather_ids(store.segments, gidx))
        finite = ~np.isneginf(np.asarray(vals))
        sel = mmr_host(mat[ids[finite]], np.asarray(vals)[finite], k, lam)
        assert list(ids[finite][sel]) == list(live[oidx])
        assert counters.device_mmr == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_filtered_diverse_overlapping_tombstones(backend):
    """Candidate filter ∩ tombstones + diverse: both router arms return
    the oracle over live∩candidates, device-finalized where fused."""
    mat, days, ts = _corpus(seed=19)
    deleted = tuple(range(40, 80))
    store = _store_from_splits(mat, ts, [120, 110], deleted)
    cand = np.arange(0, 230, 2)  # half of them tombstoned in [40, 80)
    eligible = np.setdiff1d(cand, np.asarray(deleted, int))
    plan = _diverse_plan(0.7, pool=12)
    k = plan.pool
    oidx, _ = _host_oracle(mat[eligible], days[eligible], plan, k)

    b = get_backend(backend)
    for threshold in (0.0, 2.0):  # force masked, then gather
        router = PrefilterRouter(mask_threshold=threshold)
        counters = FusedCounters()
        (gidx, vals), = score_select_prefiltered(
            b, store, store.segments, [plan], [k], cand, now=NOW,
            router=router, counters=counters)
        if b.device_mmr:
            assert list(gather_ids(store.segments, gidx)) \
                == list(eligible[oidx])
            assert counters.device_mmr == 1
        else:
            ids = np.asarray(gather_ids(store.segments, gidx))
            finite = ~np.isneginf(np.asarray(vals))
            sel = mmr_host(mat[ids[finite]], np.asarray(vals)[finite], k,
                           plan.diverse.lam)
            assert list(ids[finite][sel]) == list(eligible[oidx])


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_device_mmr_tie_order_first_occurrence(backend):
    """Duplicate embeddings (exact score ties): device MMR breaks ties
    first-occurrence — smallest pool position == smallest global row —
    exactly like np.argmax in the host oracle."""
    rng = np.random.default_rng(5)
    base = rng.standard_normal((8, 32)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    mat = np.concatenate([base, base, base])  # every row tied 3 ways
    days = np.zeros(mat.shape[0], np.float32)
    # pool=8 -> oversample width == n, keeping top_idx on its STABLE
    # argsort branch: the host pool is then in canonical ascending-row
    # tie order, the same order jax.lax.top_k guarantees on device
    plan = M.ModulationPlan(query=M.l2_normalize(EMB("tied query")),
                            diverse=M.DiverseSpec(lam=0.5), pool=8)
    k = plan.pool
    oidx, _ = _host_oracle(mat, days, plan, k)

    (idx, vals), = get_backend(backend).score_select(mat, days, [plan], [k])
    assert list(idx) == list(oidx)


# ---------------------------------------------------------------------------
# Counters: where did diversity finish?
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_counters_pin_finishing_location(backend):
    """Through the full VectorCache path: device backends finish diversity
    on device (device_mmr > 0, ZERO host pool transfers); numpy backends
    ship the pool home (host_pool_transfers > 0)."""
    mat, _, ts = _corpus(seed=23)
    vc = VectorCache(np.arange(mat.shape[0]), mat, ts, EMB, normalized=True)
    plan = _diverse_plan(0.7, pool=10)
    got = vc.search_plan(plan, now=NOW, engine=backend)
    assert len(got) == plan.pool
    if get_backend(backend).device_mmr:
        assert vc.fused.device_mmr > 0
        assert vc.fused.host_pool_transfers == 0
    else:
        assert vc.fused.host_pool_transfers > 0
        assert vc.fused.device_mmr == 0


# ---------------------------------------------------------------------------
# (N, B) candidate-mask panels
# ---------------------------------------------------------------------------


def test_candidate_mask_panel_columns():
    """Column semantics: filtered = isin ∧ live, unfiltered = live mask,
    unknown ids = all-False; hitless segments skip only when every column
    is filtered."""
    mat, _, ts = _corpus(n=60, seed=29)
    store = _store_from_splits(mat, ts, [40, 20], deleted=(0, 1, 45))
    segs = store.segments
    sets = [np.arange(0, 40),          # first segment only (ids 0..39)
            None,                      # unfiltered
            np.array([900, 901])]      # unknown ids -> no bits anywhere
    panels, matched = store.candidate_mask_panel(sets, segs)
    assert len(panels) == 2
    p0, p1 = panels
    assert p0.shape == (40, 3) and p1.shape == (20, 3)
    # filtered column: candidates minus tombstones
    np.testing.assert_array_equal(p0[:, 0], segs[0].live_mask)
    assert not p1[:, 0].any()
    # unfiltered column == live mask in every segment
    np.testing.assert_array_equal(p0[:, 1], segs[0].live_mask)
    np.testing.assert_array_equal(p1[:, 1], segs[1].live_mask)
    # unknown ids set no bits
    assert not p0[:, 2].any() and not p1[:, 2].any()
    assert matched == int(segs[0].live_mask.sum())

    # all-filtered sets with no hits in a segment -> that segment is None
    panels2, _ = store.candidate_mask_panel([np.arange(0, 40)], segs)
    assert panels2[0] is not None and panels2[1] is None
    # ...but an unfiltered column keeps every segment in play
    panels3, _ = store.candidate_mask_panel([np.arange(0, 40), None], segs)
    assert panels3[1] is not None


@pytest.mark.parametrize("backend", BACKENDS)
def test_panel_matches_per_filter_serial(backend):
    """One (N, B) panel pass == B serial per-filter dispatches,
    bit-identical ids and scores, on every backend — including diverse
    plans riding the panel."""
    mat, _, ts = _corpus(seed=31)
    deleted = tuple(range(100, 120))
    store = _store_from_splits(mat, ts, [150, 80], deleted)
    rng = np.random.default_rng(37)
    sets = [np.sort(rng.choice(230, size=90, replace=False)),
            np.sort(rng.choice(230, size=120, replace=False)),
            None,
            np.sort(rng.choice(230, size=75, replace=False))]
    plans = [_diverse_plan(0.7, pool=8),
             _diverse_plan(1.0, pool=10),
             M.ModulationPlan(query=M.l2_normalize(EMB("plain topic")),
                              pool=9),
             _diverse_plan(0.3, pool=7)]
    ks = [p.pool for p in plans]

    b = get_backend(backend)
    panel_sel = score_select_filter_panel(
        b, store, store.segments, plans, ks, sets, now=NOW)
    for j, (plan, k, cand) in enumerate(zip(plans, ks, sets)):
        if cand is None:
            (ref,) = score_select_segments(b, store.segments, [plan], [k],
                                           now=NOW)
        else:
            (ref,) = score_select_prefiltered(
                b, store, store.segments, [plan], [k], cand, now=NOW,
                router=PrefilterRouter(mask_threshold=0.0))
        gidx, vals = panel_sel[j]
        assert list(gidx) == list(ref[0]), f"plan {j}"
        np.testing.assert_allclose(vals, ref[1], atol=5e-5, rtol=5e-5)


def test_b16_heterogeneous_batch_single_scoring_pass():
    """A B=16 heterogeneous-filter cohort runs EXACTLY ONE backend scoring
    pass per segment through the panel driver (here: one segment -> one
    call), with panel_batches == 1."""

    class CountingBackend(FusedNumpyBackend):
        name = "counting-panel"

        def __init__(self):
            self.calls = 0

        def score_select(self, *args, **kwargs):
            self.calls += 1
            return super().score_select(*args, **kwargs)

    mat, _, ts = _corpus(n=200, seed=41)
    store = _store_from_splits(mat, ts, [200])
    rng = np.random.default_rng(43)
    sets = [np.sort(rng.choice(200, size=60 + i, replace=False))
            for i in range(16)]
    plans = [_diverse_plan(0.7, pool=5) if i % 2 else
             M.ModulationPlan(query=M.l2_normalize(EMB(f"q {i}")), pool=5)
             for i in range(16)]
    ks = [5] * 16

    b = CountingBackend()
    router = PrefilterRouter()
    counters = FusedCounters()
    assert router.use_panel([s.size for s in sets], store.n_live)
    sel = score_select_filter_panel(b, store, store.segments, plans, ks,
                                    sets, now=NOW, router=router,
                                    counters=counters)
    assert b.calls == 1
    assert counters.panel_batches == 1
    assert router.routed_panel == 16
    # host backend: plain plans come back final-k, diverse plans as pools
    assert len(sel) == 16 and all(g.size >= 5 for g, _ in sel)


def test_use_panel_routing_decision():
    """use_panel fires only when >= 2 groups are full-corpus cost; sharp
    filter cohorts and singleton groups stay on per-group dispatch."""
    r = PrefilterRouter(mask_threshold=0.2)
    n_live = 1000
    assert r.use_panel([None, 300], n_live)          # unfiltered + weak
    assert r.use_panel([250, 400, 10], n_live)       # two weak filters
    assert not r.use_panel([None], n_live)           # singleton group
    assert not r.use_panel([10, 20, 30], n_live)     # all sharp -> gather
    assert not r.use_panel([None, 10], n_live)       # only ONE full-cost
