"""Training loop + fault tolerance: loss goes down, resume is exact,
stragglers are flagged, elastic replanning works."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.loader import LMDataConfig, SyntheticLMStream
from repro.dist.sharding import default_rules
from repro.models import transformer as T
from repro.models.layers import LMConfig
from repro.train import checkpoint as C
from repro.train.elastic import ElasticPlan, StepWatchdog, replan_mesh
from repro.train.loop import TrainLoopConfig, Trainer
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def _setup(tmp_path=None, seed=0):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32,
                   q_chunk=16, remat=False)
    params = T.init_params(cfg, jax.random.key(seed))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg, rules)
        params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    step_fn = jax.jit(step_fn)
    stream = SyntheticLMStream(LMDataConfig(vocab=64, batch=8, seq_len=32))
    to_batch = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    lcfg = TrainLoopConfig(
        total_steps=40, ckpt_every=10, log_every=5,
        ckpt_dir=str(tmp_path) if tmp_path else None)
    trainer = Trainer(step_fn, params, opt, stream, lcfg, to_batch)
    return mesh, trainer


def test_loss_decreases():
    mesh, trainer = _setup()
    with mesh:
        out = trainer.run(40)
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.15, (first, last)


def test_resume_is_exact(tmp_path):
    # continuous reference: 30 uninterrupted steps
    mesh, ref_t = _setup(tmp_path / "ref")
    with mesh:
        ref = ref_t.run(30)

    # interrupted run: 20 steps, then "node failure"
    mesh, t1 = _setup(tmp_path / "a")
    with mesh:
        t1.run(20)
        t1.ckpt.wait()

    # restart: fresh trainer (DIFFERENT init seed) restores params, opt
    # state, and data-iterator state from the checkpoint
    mesh, t2 = _setup(tmp_path / "a", seed=123)
    assert t2.try_resume()
    assert t2.step == 20
    with mesh:
        out = t2.run(10)
    np.testing.assert_allclose(out["final_loss"], ref["final_loss"], rtol=1e-4)


def test_no_resume_without_ckpt(tmp_path):
    mesh, t = _setup(tmp_path / "empty")
    assert not t.try_resume()


def test_watchdog_flags_stragglers():
    w = StepWatchdog(warmup=3)
    for _ in range(10):
        w.observe(0.1)
    assert w.observe(1.5)                 # 15x slower -> straggler
    assert len(w.events) == 1
    assert not w.observe(0.1)


def test_elastic_replan():
    assert replan_mesh(512, 16) == (32, 16)
    assert replan_mesh(496, 16) == (31, 16)
    plan = ElasticPlan.on_failure(512, 16, model_parallel=16)
    assert plan.new_devices == 496 and plan.mesh_shape == (31, 16)
    with pytest.raises(ValueError):
        replan_mesh(8, 16)


def test_checkpoint_prune_and_latest(tmp_path):
    tree = {"x": np.ones(3)}
    for s in (1, 2, 3, 4, 5):
        C.save(tmp_path, s, tree)
    C.prune(tmp_path, keep=2)
    assert C.latest_step(tmp_path) == 5
    assert sorted(p.name for p in tmp_path.glob("ckpt_*.npz")) == [
        "ckpt_4.npz", "ckpt_5.npz"]


def test_checkpoint_shape_mismatch_is_loud(tmp_path):
    C.save(tmp_path, 1, {"x": np.ones(3)})
    with pytest.raises(ValueError):
        C.restore(tmp_path, {"x": np.ones(4)})


def test_data_stream_seekable():
    cfg = LMDataConfig(vocab=64, batch=4, seq_len=16, seed=3)
    a = SyntheticLMStream(cfg)
    b1 = [a.next_batch() for _ in range(5)]
    b = SyntheticLMStream(cfg)
    b.load_state_dict({"step": 3})
    np.testing.assert_array_equal(b.next_batch()["tokens"], b1[3]["tokens"])
