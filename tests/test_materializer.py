"""Query materializer: scanning, rewriting, dispatch, failure modes."""

import sqlite3

import numpy as np
import pytest

from repro.core.materializer import (
    MaterializeError,
    Materializer,
    _scan_calls,
    _split_args,
)
from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.sqlio.schema import load_embedding_matrix


@pytest.fixture(scope="module")
def db():
    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=600, n_sessions=30, seed=7)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    ids, matrix, ts = load_embedding_matrix(conn, 64)
    cache = VectorCache(ids, matrix, ts, emb)
    return conn, cache


def _mz(db):
    conn, cache = db
    return Materializer(conn, cache, now=1_770_000_000.0)


# -- scanner ----------------------------------------------------------------


def test_scan_finds_calls_with_quoted_sql():
    sql = ("SELECT * FROM vec_ops('similar:x', 'SELECT id FROM m "
           "WHERE t = ''assistant''') v JOIN keyword('term.x') k ON v.id=k.id")
    calls = _scan_calls(sql)
    assert [c.func for c in calls] == ["vec_ops", "keyword"]
    assert calls[0].args[1] == "SELECT id FROM m WHERE t = 'assistant'"
    assert calls[1].args == ["term.x"]


def test_scan_ignores_names_inside_strings():
    calls = _scan_calls("SELECT 'vec_ops(1)' AS lit FROM t")
    assert calls == []


def test_scan_word_boundary():
    assert _scan_calls("SELECT myvec_ops('x') FROM t") == []


def test_unbalanced_parens_explicit_error():
    with pytest.raises(MaterializeError):
        _scan_calls("SELECT * FROM vec_ops('x' ")


def test_split_args_rejects_non_literal():
    with pytest.raises(MaterializeError):
        _split_args("foo, 'bar'")


# -- execution ---------------------------------------------------------------


def test_three_phase_query(db):
    mz = _mz(db)
    cols, rows = mz.execute(
        "SELECT v.id, v.score, m.content FROM vec_ops("
        "'similar:server lifecycle debugging pool:20',"
        "'SELECT id FROM messages WHERE type = ''assistant''') v "
        "JOIN messages m ON v.id = m.id ORDER BY v.score DESC LIMIT 5"
    )
    assert cols == ["id", "score", "content"]
    assert 0 < len(rows) <= 5
    scores = [r[1] for r in rows]
    assert scores == sorted(scores, reverse=True)


def test_prefilter_restricts_candidates(db):
    conn, cache = db
    mz = _mz(db)
    _, rows = mz.execute(
        "SELECT v.id FROM vec_ops('similar:anything pool:500',"
        "'SELECT id FROM chunks WHERE type = ''file''') v"
    )
    types = {
        conn.execute("SELECT type FROM chunks WHERE id=?", (r[0],)).fetchone()[0]
        for r in rows
    }
    assert types == {"file"}


def test_empty_prefilter_returns_empty_not_crash(db):
    mz = _mz(db)
    _, rows = mz.execute(
        "SELECT v.id FROM vec_ops('similar:x', "
        "'SELECT id FROM chunks WHERE type = ''no_such_type''') v"
    )
    assert rows == []


def test_keyword_and_hybrid(db):
    mz = _mz(db)
    _, rows = mz.execute("SELECT k.id, k.score, k.snippet FROM keyword('server') k "
                         "ORDER BY k.score DESC LIMIT 5")
    # unified contract: min-max normalized scores, higher = better
    assert rows and all(0.0 <= r[1] <= 1.0 for r in rows)
    assert rows[0][1] == 1.0
    assert all(r[2] for r in rows)  # snippet populated
    _, hybrid = mz.execute(
        "SELECT k.id, k.score, v.score FROM keyword('server') k "
        "JOIN vec_ops('similar:server lifecycle') v ON k.id = v.id "
        "ORDER BY v.score DESC LIMIT 5"
    )
    assert hybrid


def test_keyword_fallback_quoting(db):
    mz = _mz(db)
    # dots/special chars break FTS5 syntax -> automatic fallback quoting
    _, rows = mz.execute("SELECT k.id FROM keyword('server.lifecycle') k")
    assert isinstance(rows, list)


def test_write_statements_rejected(db):
    mz = _mz(db)
    with pytest.raises(MaterializeError):
        mz.execute("DELETE FROM _raw_chunks")
    with pytest.raises(MaterializeError):
        mz.execute("SELECT v.id FROM vec_ops('similar:x', "
                   "'DELETE FROM _raw_chunks') v")


def test_grammar_error_is_explicit(db):
    mz = _mz(db)
    with pytest.raises(MaterializeError):
        mz.execute("SELECT v.id FROM vec_ops('decay:oops') v")


def test_engines_agree(db):
    conn, cache = db
    sql = ("SELECT v.id FROM vec_ops('similar:background worker failure "
           "suppress:website landing page decay:30 pool:50') v ORDER BY v.score DESC")
    ref = Materializer(conn, cache, now=1_770_000_000.0, engine="reference").execute(sql)[1]
    fus = Materializer(conn, cache, now=1_770_000_000.0, engine="fused").execute(sql)[1]
    assert [r[0] for r in ref] == [r[0] for r in fus]
