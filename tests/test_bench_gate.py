"""Unit tests for the CI bench-regression gate (benchmarks/check_regression).

The gate diffs per-backend ``total_ms`` against the committed smoke
baseline: regressions beyond the tolerance fail, skipped backends are
tolerated WHEN RECORDED, and silent omission (a backend dropped from the
snapshot without a ``{"skipped": ...}`` marker) is itself a failure.
"""

import json
import subprocess
import sys
from pathlib import Path

from benchmarks.check_regression import (DEFAULT_TOL, compare, compare_all,
                                         merge_min)

REPO = Path(__file__).resolve().parents[1]


def _snap(backends):
    return {"bench": "pem_phase2_composed", "backends": backends}


def _row(ms):
    return {"score_us": ms * 500, "select_us": ms * 500, "total_ms": ms}


def test_within_tolerance_is_green():
    base = _snap({"fused-numpy": _row(20.0), "jit-jax": _row(30.0)})
    new = _snap({"fused-numpy": _row(24.0), "jit-jax": _row(29.0)})
    failures, notes = compare(new, base, DEFAULT_TOL)
    assert failures == []
    assert len(notes) == 2


def test_regression_beyond_tolerance_fails():
    base = _snap({"fused-numpy": _row(20.0), "jit-jax": _row(30.0)})
    new = _snap({"fused-numpy": _row(20.0), "jit-jax": _row(46.0)})
    failures, _ = compare(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "jit-jax" in failures[0] and "REGRESSION" in failures[0]


def test_tolerance_is_overridable():
    base = _snap({"jit-jax": _row(10.0)})
    new = _snap({"jit-jax": _row(25.0)})
    assert compare(new, base, 1.5)[0]
    assert not compare(new, base, 3.0)[0]


def test_skip_recorded_on_both_sides_is_tolerated():
    base = _snap({"pallas": {"skipped": "requires TPU"},
                  "jit-jax": _row(30.0)})
    new = _snap({"pallas": {"skipped": "requires TPU"},
                 "jit-jax": _row(30.0)})
    failures, notes = compare(new, base, DEFAULT_TOL)
    assert failures == []
    assert any("pallas" in n and "skipped" in n for n in notes)


def test_baseline_measured_backend_going_skipped_fails():
    """A skip can't silently end a measured backend's perf trajectory."""
    base = _snap({"pallas": _row(5.0), "jit-jax": _row(30.0)})
    new = _snap({"pallas": {"skipped": "requires TPU"},
                 "jit-jax": _row(30.0)})
    failures, _ = compare(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "pallas" in failures[0] and "skipped" in failures[0]


def test_silent_omission_fails():
    """The exact failure mode the {"skipped": reason} recording prevents."""
    base = _snap({"pallas": _row(5.0), "jit-jax": _row(30.0)})
    new = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "pallas" in failures[0] and "MISSING" in failures[0]


def test_baseline_skip_and_new_backend_are_notes():
    base = _snap({"pallas": {"skipped": "requires TPU"}})
    new = _snap({"pallas": _row(4.0), "brand-new": _row(1.0)})
    failures, notes = compare(new, base, DEFAULT_TOL)
    assert failures == []
    assert any("no baseline" in n for n in notes)
    assert any("brand-new" in n for n in notes)


def test_merge_min_takes_fastest_row_per_backend():
    """One contended run can't fail the gate: the per-backend minimum
    across fresh snapshots wins, and a skip survives only if the backend
    never measured."""
    noisy = _snap({"jit-jax": _row(83.6), "fused-numpy": _row(16.0),
                   "pallas": {"skipped": "requires TPU"}})
    clean = _snap({"jit-jax": _row(17.8), "fused-numpy": _row(21.0),
                   "pallas": {"skipped": "requires TPU"}})
    merged = merge_min([noisy, clean])
    assert merged["backends"]["jit-jax"]["total_ms"] == 17.8
    assert merged["backends"]["fused-numpy"]["total_ms"] == 16.0
    assert "skipped" in merged["backends"]["pallas"]
    # a backend measured in ANY run counts as measured
    part = _snap({"sharded": {"skipped": "flaky platform"}})
    full = _snap({"sharded": _row(20.0)})
    assert merge_min([part, full])["backends"]["sharded"]["total_ms"] == 20.0


def test_delta_section_gated_same_rules():
    """The delta-ingest scenario gates under the same tolerance; its
    failure lines carry the section tag."""
    base = _snap({"jit-jax": _row(30.0)})
    base["delta_backends"] = {"jit-jax": _row(40.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["delta_backends"] = {"jit-jax": _row(45.0)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("delta_backends/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["delta_backends"] = {"jit-jax": _row(70.0)}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "delta_backends/jit-jax" in failures[0]


def test_delta_section_dropped_entirely_fails():
    """Removing the whole liveness scenario is section-level silent
    omission; a PRE-liveness baseline without the section gates nothing."""
    base = _snap({"jit-jax": _row(30.0)})
    base["delta_backends"] = {"jit-jax": _row(40.0)}
    new = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "delta_backends" in failures[0] and "dropped" in failures[0]
    old_base = _snap({"jit-jax": _row(30.0)})
    assert compare_all(new, old_base, DEFAULT_TOL)[0] == []


def test_serve_section_gated_and_drop_fails():
    """The serving scenario (rows keyed by scheduler mode) gates under
    the same rules: a pipelined-core regression past tolerance fails,
    and dropping the whole section is silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["serve_throughput"] = {"sync_core": _row(300.0),
                                "pipelined": _row(200.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["serve_throughput"] = {"sync_core": _row(310.0),
                              "pipelined": _row(210.0)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("serve_throughput/") for n in notes)
    # breaking the pipeline shows up as a gated regression of its row
    broken = _snap({"jit-jax": _row(30.0)})
    broken["serve_throughput"] = {"sync_core": _row(300.0),
                                  "pipelined": _row(320.0)}
    failures, _ = compare_all(broken, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "serve_throughput/pipelined" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1 and "serve_throughput" in failures[0]


def test_prefilter_section_gated_and_drop_fails():
    """The filtered-retrieval scenario gates under the same rules: a
    routed-path regression past tolerance fails, and dropping the whole
    section is section-level silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["prefilter_backends"] = {"jit-jax": _row(25.0),
                                  "pallas": {"skipped": "requires TPU"}}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["prefilter_backends"] = {"jit-jax": _row(28.0),
                                "pallas": {"skipped": "requires TPU"}}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("prefilter_backends/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["prefilter_backends"] = {"jit-jax": _row(60.0),
                                 "pallas": {"skipped": "requires TPU"}}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "prefilter_backends/jit-jax" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "prefilter_backends" in failures[0] and "dropped" in failures[0]


def test_diverse_and_panel_sections_gated_and_drop_fails():
    """The fused device-MMR and (N, B) mask-panel scenarios gate under
    the same rules: a fused-path regression past tolerance fails, host
    backends recorded as skipped are tolerated, and dropping either
    section entirely is section-level silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["diverse_backends"] = {"jit-jax": _row(18.0),
                                "fused-numpy": {"skipped": "no device MMR"}}
    base["filter_panel"] = {"jit-jax": _row(22.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["diverse_backends"] = {"jit-jax": _row(20.0),
                              "fused-numpy": {"skipped": "no device MMR"}}
    ok["filter_panel"] = {"jit-jax": _row(24.0)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("diverse_backends/") for n in notes)
    assert any(n.startswith("filter_panel/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["diverse_backends"] = {"jit-jax": _row(40.0),
                               "fused-numpy": {"skipped": "no device MMR"}}
    bad["filter_panel"] = {"jit-jax": _row(80.0)}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 2
    assert any("diverse_backends/jit-jax" in f for f in failures)
    assert any("filter_panel/jit-jax" in f for f in failures)
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 2
    assert all("dropped" in f for f in failures)


def test_hybrid_section_gated_and_drop_fails():
    """The hybrid lexical+vector fusion scenario gates under the same
    rules: a hybrid-path regression past tolerance fails, the off-TPU
    pallas skip is tolerated when recorded, and dropping the whole
    section is section-level silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["hybrid_backends"] = {"jit-jax": _row(12.0),
                               "pallas": {"skipped": "requires TPU"}}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["hybrid_backends"] = {"jit-jax": _row(14.0),
                             "pallas": {"skipped": "requires TPU"}}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("hybrid_backends/") for n in notes)
    # a fusion bias that stops riding the fused device pass gates
    bad = _snap({"jit-jax": _row(30.0)})
    bad["hybrid_backends"] = {"jit-jax": _row(40.0),
                              "pallas": {"skipped": "requires TPU"}}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "hybrid_backends/jit-jax" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "hybrid_backends" in failures[0] and "dropped" in failures[0]


def test_merge_min_folds_hybrid_section():
    a = _snap({"jit-jax": _row(30.0)})
    a["hybrid_backends"] = {"jit-jax": _row(13.0)}
    b = _snap({"jit-jax": _row(29.0)})
    b["hybrid_backends"] = {"jit-jax": _row(11.0)}
    merged = merge_min([a, b])
    assert merged["hybrid_backends"]["jit-jax"]["total_ms"] == 11.0


def test_merge_min_folds_diverse_and_panel_sections():
    a = _snap({"jit-jax": _row(30.0)})
    a["diverse_backends"] = {"jit-jax": _row(19.0)}
    a["filter_panel"] = {"jit-jax": _row(26.0)}
    b = _snap({"jit-jax": _row(31.0)})
    b["diverse_backends"] = {"jit-jax": _row(17.0)}
    b["filter_panel"] = {"jit-jax": _row(29.0)}
    merged = merge_min([a, b])
    assert merged["diverse_backends"]["jit-jax"]["total_ms"] == 17.0
    assert merged["filter_panel"]["jit-jax"]["total_ms"] == 26.0


def test_merge_min_folds_delta_section():
    a = _snap({"jit-jax": _row(30.0)})
    a["delta_backends"] = {"jit-jax": _row(50.0)}
    b = _snap({"jit-jax": _row(31.0)})
    b["delta_backends"] = {"jit-jax": _row(44.0)}
    merged = merge_min([a, b])
    assert merged["backends"]["jit-jax"]["total_ms"] == 30.0
    assert merged["delta_backends"]["jit-jax"]["total_ms"] == 44.0


def test_gate_cli_green_on_committed_baseline(tmp_path):
    """End-to-end: the CLI exits 0 when the snapshot equals the committed
    smoke baseline (what CI runs, minus the fresh bench)."""
    baseline = REPO / "BENCH_pem.smoke.json"
    assert baseline.exists(), "committed smoke baseline missing"
    snap = tmp_path / "new.json"
    snap.write_text(baseline.read_text())
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         str(snap), str(baseline)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "green" in proc.stdout


def test_gate_cli_fails_on_regression(tmp_path):
    baseline = REPO / "BENCH_pem.smoke.json"
    data = json.loads(baseline.read_text())
    for row in data["backends"].values():
        if "total_ms" in row:
            row["total_ms"] = round(row["total_ms"] * 10, 3)
    snap = tmp_path / "regressed.json"
    snap.write_text(json.dumps(data))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         str(snap), str(baseline)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout


def test_scale1m_section_gated_and_drop_fails():
    """The million-chunk shard-group scenario gates under the same rules:
    a sharded-path regression past tolerance fails, and dropping the
    whole section (e.g. the bench silently skipping the topology) is
    section-level silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["scale_1m"] = {"sharded_bf16": _row(55.0),
                       "sharded_f32": _row(95.0),
                       "monolithic_fused": _row(100.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["scale_1m"] = {"sharded_bf16": _row(60.0),
                     "sharded_f32": _row(100.0),
                     "monolithic_fused": _row(105.0)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("scale_1m/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["scale_1m"] = {"sharded_bf16": _row(120.0),
                      "sharded_f32": _row(100.0),
                      "monolithic_fused": _row(105.0)}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "scale_1m/sharded_bf16" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "scale_1m" in failures[0] and "dropped" in failures[0]


def test_scale1m_row_missing_fails():
    """Dropping ONE shard-group row (say the bf16 headline) while keeping
    the section is row-level silent omission."""
    base = _snap({})
    base["scale_1m"] = {"sharded_bf16": _row(55.0),
                       "monolithic_fused": _row(100.0)}
    new = _snap({})
    new["scale_1m"] = {"monolithic_fused": _row(100.0)}
    failures, _ = compare_all(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "scale_1m/sharded_bf16" in failures[0] and "MISSING" in failures[0]


def test_cohort_section_gated_and_drop_fails():
    """The cohort-streamed-scoring scenario gates under the same rules:
    a cohort-pass regression past tolerance fails (an un-amortized
    corpus stream reads as a slowdown of exactly the row that exists to
    pin it), and dropping the whole section is section-level silent
    omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["cohort_throughput"] = {"serial_f32b": _row(1100.0),
                                 "cohort_f32b_q16": _row(340.0),
                                 "serve_cohort": _row(900.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["cohort_throughput"] = {"serial_f32b": _row(1150.0),
                               "cohort_f32b_q16": _row(360.0),
                               "serve_cohort": _row(950.0)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("cohort_throughput/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["cohort_throughput"] = {"serial_f32b": _row(1100.0),
                                "cohort_f32b_q16": _row(900.0),
                                "serve_cohort": _row(900.0)}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "cohort_throughput/cohort_f32b_q16" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "cohort_throughput" in failures[0] and "dropped" in failures[0]


def test_cohort_row_missing_fails():
    """Dropping ONE cohort row (say the q16 headline) while keeping the
    section is row-level silent omission."""
    base = _snap({})
    base["cohort_throughput"] = {"serial_f32b": _row(1100.0),
                                 "cohort_f32b_q16": _row(340.0)}
    new = _snap({})
    new["cohort_throughput"] = {"serial_f32b": _row(1100.0)}
    failures, _ = compare_all(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert ("cohort_throughput/cohort_f32b_q16" in failures[0]
            and "MISSING" in failures[0])


def test_merge_min_folds_cohort_section():
    a = _snap({"jit-jax": _row(30.0)})
    a["cohort_throughput"] = {"cohort_f32b_q16": _row(390.0)}
    b = _snap({"jit-jax": _row(29.0)})
    b["cohort_throughput"] = {"cohort_f32b_q16": _row(355.0)}
    merged = merge_min([a, b])
    assert merged["cohort_throughput"]["cohort_f32b_q16"]["total_ms"] == 355.0


def test_merge_min_folds_scale1m_section():
    a = _snap({"jit-jax": _row(30.0)})
    a["scale_1m"] = {"sharded_bf16": _row(61.0)}
    b = _snap({"jit-jax": _row(29.0)})
    b["scale_1m"] = {"sharded_bf16": _row(58.0)}
    merged = merge_min([a, b])
    assert merged["scale_1m"]["sharded_bf16"]["total_ms"] == 58.0


def test_ingest_durability_section_gated_and_drop_fails():
    """The durable-ingest scenario gates under the same rules: a slowed
    journal fsync path or an O(corpus) recovery reads as a regression of
    exactly the row that pins it, and dropping the whole section is
    section-level silent omission."""
    base = _snap({"jit-jax": _row(30.0)})
    base["ingest_durability"] = {"insert_inline": _row(90.0),
                                 "insert_queued": _row(80.0),
                                 "recovery_snapshot": _row(4.0),
                                 "recovery_delta": _row(6.0)}
    ok = _snap({"jit-jax": _row(30.0)})
    ok["ingest_durability"] = {"insert_inline": _row(95.0),
                               "insert_queued": _row(85.0),
                               "recovery_snapshot": _row(4.5),
                               "recovery_delta": _row(6.5)}
    failures, notes = compare_all(ok, base, DEFAULT_TOL)
    assert failures == []
    assert any(n.startswith("ingest_durability/") for n in notes)
    bad = _snap({"jit-jax": _row(30.0)})
    bad["ingest_durability"] = {"insert_inline": _row(90.0),
                                "insert_queued": _row(80.0),
                                "recovery_snapshot": _row(4.0),
                                "recovery_delta": _row(60.0)}
    failures, _ = compare_all(bad, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "ingest_durability/recovery_delta" in failures[0]
    dropped = _snap({"jit-jax": _row(30.0)})
    failures, _ = compare_all(dropped, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert "ingest_durability" in failures[0] and "dropped" in failures[0]


def test_ingest_durability_row_missing_fails():
    """Dropping ONE durable-ingest row (say the queued INSERT headline)
    while keeping the section is row-level silent omission."""
    base = _snap({})
    base["ingest_durability"] = {"insert_inline": _row(90.0),
                                 "insert_queued": _row(80.0)}
    new = _snap({})
    new["ingest_durability"] = {"insert_inline": _row(90.0)}
    failures, _ = compare_all(new, base, DEFAULT_TOL)
    assert len(failures) == 1
    assert ("ingest_durability/insert_queued" in failures[0]
            and "MISSING" in failures[0])


def test_merge_min_folds_ingest_durability_section():
    a = _snap({"jit-jax": _row(30.0)})
    a["ingest_durability"] = {"insert_queued": _row(88.0)}
    b = _snap({"jit-jax": _row(29.0)})
    b["ingest_durability"] = {"insert_queued": _row(79.0)}
    merged = merge_min([a, b])
    assert merged["ingest_durability"]["insert_queued"]["total_ms"] == 79.0
