"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.modulations import mmr_select_np
from repro.kernels.mmr.ops import mmr_select
from repro.kernels.mmr.ref import mmr_ref
from repro.kernels.pem_score.ops import pem_score
from repro.kernels.pem_score.ref import pem_score_ref
from repro.kernels.topk.ops import topk
from repro.kernels.topk.ref import topk_ref

RNG = np.random.default_rng(0)


def _corpus(n, d, dtype):
    m = RNG.standard_normal((n, d)).astype(np.float32)
    m /= np.linalg.norm(m, axis=1, keepdims=True)
    return jnp.asarray(m, dtype=dtype)


@pytest.mark.parametrize("n", [100, 1000, 2049])
@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("b", [1, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pem_score_sweep(n, d, b, dtype):
    m = _corpus(n, d, dtype)
    qp = jnp.asarray(RNG.standard_normal((d, b)).astype(np.float32))
    qs = jnp.asarray(RNG.standard_normal((d, b)).astype(np.float32) * 0.3)
    decay = jnp.asarray((1.0 / (1.0 + RNG.random(n) * 10)).astype(np.float32))
    out = pem_score(m, qp, qs, decay, interpret=True, block_n=256, block_b=128)
    ref = pem_score_ref(m, qp, qs, decay)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2   # bf16 inputs, f32 accum
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


def test_pem_score_no_decay():
    m = _corpus(500, 128, jnp.float32)
    qp = jnp.asarray(RNG.standard_normal((128, 3)).astype(np.float32))
    qs = jnp.zeros((128, 3), jnp.float32)
    out = pem_score(m, qp, qs, None, interpret=True, block_n=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(m @ qp), atol=1e-5)


@pytest.mark.parametrize("n,k", [(1000, 1), (1000, 37), (5000, 500), (100, 100)])
def test_topk_sweep(n, k):
    s = jnp.asarray(RNG.standard_normal((4, n)).astype(np.float32))
    vk, ik = topk(s, k, interpret=True, block_n=512)
    vr, ir = topk_ref(s, k)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    # indices may differ on exact ties; values above already assert equal
    got = np.take_along_axis(np.asarray(s), np.asarray(ik), axis=1)
    np.testing.assert_array_equal(got, np.asarray(vr))


def test_topk_with_ties_and_negatives():
    s = jnp.asarray(np.tile(np.array([-1.0, 3.0, 3.0, -5.0, 0.0], np.float32), (2, 40)))
    vk, ik = topk(s, 10, interpret=True, block_n=128)
    vr, _ = topk_ref(s, 10)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    # no index returned twice
    for row in np.asarray(ik):
        assert len(set(row.tolist())) == len(row)


@pytest.mark.parametrize("n,k,d", [(64, 8, 32), (200, 50, 128), (300, 17, 64)])
def test_mmr_sweep(n, k, d):
    e = RNG.standard_normal((2, n, d)).astype(np.float32)
    e /= np.linalg.norm(e, axis=-1, keepdims=True)
    rel = RNG.standard_normal((2, n)).astype(np.float32)
    ik, vk = mmr_select(jnp.asarray(e), jnp.asarray(rel), k, 0.7, interpret=True)
    ir, vr = mmr_ref(jnp.asarray(e), jnp.asarray(rel), k, 0.7)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    for b in range(2):
        np_sel = mmr_select_np(e[b], rel[b], k, 0.7)
        np.testing.assert_array_equal(np.asarray(ik[b]), np_sel)


def test_mmr_lambda_extremes():
    e = RNG.standard_normal((1, 60, 16)).astype(np.float32)
    e /= np.linalg.norm(e, axis=-1, keepdims=True)
    rel = RNG.standard_normal((1, 60)).astype(np.float32)
    # lam=1.0 -> pure relevance order == topk order
    ik, _ = mmr_select(jnp.asarray(e), jnp.asarray(rel), 10, 1.0, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(ik[0]), np.argsort(-rel[0], kind="stable")[:10])


def test_fold_plan_matches_modulation_pipeline():
    """kernel-input folding (q_pre/q_sup) == the paper's fixed-order math."""
    from repro.core import modulations as M
    from repro.core.grammar import parse
    from repro.embed import HashEmbedder
    from repro.kernels.pem_score.ops import fold_plan

    emb = HashEmbedder(128)
    mat = _corpus(400, 128, jnp.float32)
    days = np.abs(RNG.standard_normal(400)).astype(np.float32) * 30
    plan = parse("similar:alpha beta from:old to:new decay:14 "
                 "suppress:noise one suppress:noise two", emb)
    q_pre, q_sup = fold_plan(plan)
    decay = (1.0 / (1.0 + days / 14.0)).astype(np.float32)
    fused = decay * (np.asarray(mat) @ q_pre) + np.asarray(mat) @ q_sup
    ref = M.modulate_scores(np.asarray(mat), days, plan)
    np.testing.assert_allclose(fused, np.asarray(ref), atol=1e-5)
