"""Structural operators (paper §3.2): cluster:K and central columns."""

import sqlite3

import numpy as np
import pytest

from repro.core.materializer import Materializer
from repro.core.structural import centrality, kmeans_labels
from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.sqlio.schema import load_embedding_matrix


def _clustered_embeds(n_per=20, k=3, d=32, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)).astype(np.float32) * 4
    e = np.concatenate(
        [centers[i] + 0.2 * rng.standard_normal((n_per, d)).astype(np.float32)
         for i in range(k)])
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def test_kmeans_recovers_planted_clusters():
    e = _clustered_embeds()
    labels = kmeans_labels(e, 3)
    assert set(labels.tolist()) <= {0, 1, 2}
    # every planted cluster maps to exactly one label
    for i in range(3):
        block = labels[i * 20:(i + 1) * 20]
        assert len(set(block.tolist())) == 1
    assert len({labels[0], labels[20], labels[40]}) == 3


def test_kmeans_deterministic_and_bounded():
    e = _clustered_embeds(seed=3)
    a = kmeans_labels(e, 5)
    b = kmeans_labels(e, 5)
    np.testing.assert_array_equal(a, b)
    assert kmeans_labels(e[:2], 10).max() <= 1   # k clamped to n


def test_centrality_bounds_and_ordering():
    e = _clustered_embeds(n_per=30, k=2, seed=1)
    c = centrality(e)
    assert c.shape == (60,)
    assert (c >= -1 - 1e-6).all() and (c <= 1 + 1e-6).all()
    # a duplicate-heavy pool: the duplicated point is most central
    dup = np.concatenate([np.tile(e[:1], (10, 1)), e[30:35]])
    cd = centrality(dup)
    assert cd[:10].mean() > cd[10:].mean()
    assert centrality(e[:1]).tolist() == [0.0]


@pytest.fixture(scope="module")
def db():
    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=800, n_sessions=40, seed=5)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    ids, matrix, ts = load_embedding_matrix(conn, 64)
    return conn, VectorCache(ids, matrix, ts, emb)


def test_cluster_column_via_sql(db):
    conn, cache = db
    mz = Materializer(conn, cache, now=1_770_000_000.0)
    cols, rows = mz.execute(
        "SELECT v.cluster, COUNT(*) AS n, AVG(v.score) AS mean_score "
        "FROM vec_ops('similar:server lifecycle cluster:4 pool:40') v "
        "GROUP BY v.cluster ORDER BY n DESC"
    )
    assert cols == ["cluster", "n", "mean_score"]
    assert 1 <= len(rows) <= 4
    assert sum(r[1] for r in rows) == 40


def test_central_column_via_sql(db):
    conn, cache = db
    mz = Materializer(conn, cache, now=1_770_000_000.0)
    cols, rows = mz.execute(
        "SELECT v.id, v.score, v.central FROM "
        "vec_ops('similar:identity provenance central pool:20') v "
        "ORDER BY v.central DESC LIMIT 5"
    )
    assert cols == ["id", "score", "central"]
    assert len(rows) == 5
    cents = [r[2] for r in rows]
    assert cents == sorted(cents, reverse=True)
    assert all(-1.0 <= c <= 1.0 for c in cents)


def test_structural_composes_with_modulations(db):
    conn, cache = db
    mz = Materializer(conn, cache, now=1_770_000_000.0)
    cols, rows = mz.execute(
        "SELECT v.id, v.cluster, v.central FROM vec_ops("
        "'similar:server lifecycle diverse decay:30 suppress:website page "
        "cluster:3 central pool:30') v"
    )
    assert cols == ["id", "cluster", "central"]
    assert len(rows) == 30


def test_plain_vec_ops_unified_contract(db):
    """Without structural tokens, vec_ops carries exactly the unified
    result contract (id, score, snippet) — no cluster/central columns."""
    conn, cache = db
    mz = Materializer(conn, cache, now=1_770_000_000.0)
    cols, rows = mz.execute(
        "SELECT * FROM vec_ops('similar:server pool:5') v")
    assert cols == ["id", "score", "snippet"]
