"""Fused score->select equivalence + PlanCache retrace contract.

The device-resident pipeline's two invariants:

1. ``score_select`` (device top-k for jit-jax / pallas / sharded, host
   path for the numpy backends) returns the same top-``pool`` candidate
   set as the host oracle — ``select_candidates`` over the full score
   array and ``pem_topk_reference`` — with scores to 1e-5, including the
   diverse/MMR oversample path and per-request ``k`` mixes.
2. The ``PlanCache`` never retraces for distinct query texts with the
   same plan *structure*; a genuinely new suppress-count bucket traces
   exactly once more.  Traces are counted from INSIDE the traced python
   bodies (``PlanCache.jax_traces``), so any accidental shape/dtype
   wobble in the host-side argument prep would show up here.
"""

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.backends import (JitJaxBackend, PlanCache, PlanStructure,
                                 ShardedBackend, finalize_candidates,
                                 get_backend, list_backends,
                                 select_candidates, selection_width, top_idx)
from repro.embed import HashEmbedder

BACKENDS = list_backends()
EMB = HashEmbedder(32)


def _corpus(n=220, d=32, seed=13):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    return mat, days


def _plan(text="how the retrieval system works", *, n_suppress=2, decay=True,
          diverse=False, trajectory=True, pool=30):
    suppress = tuple(
        M.SuppressSpec(direction=M.l2_normalize(EMB(f"noise concept {i}")),
                       weight=0.5 - 0.1 * i)
        for i in range(n_suppress)
    )
    traj = None
    if trajectory:
        traj = M.TrajectorySpec(
            direction=M.l2_normalize(EMB("production deployment"))
            - M.l2_normalize(EMB("prototype sketch")))
    return M.ModulationPlan(
        query=M.l2_normalize(EMB(text)),
        trajectory=traj,
        decay=M.DecaySpec(half_life_days=30.0) if decay else None,
        suppress=suppress,
        diverse=M.DiverseSpec() if diverse else None,
        pool=pool,
    )


# ---------------------------------------------------------------------------
# Fused-selection equivalence (satellite: device results == host oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_score_select_matches_host_topk(backend):
    """Plain top-k: same indices as top_idx over the full oracle scores."""
    mat, days = _corpus()
    plan = _plan()
    oracle = np.asarray(M.modulate_scores(mat, days, plan))
    k = plan.pool
    (idx, vals), = get_backend(backend).score_select(mat, days, [plan], [k])
    assert idx.shape == vals.shape == (k,)
    assert list(idx) == list(top_idx(oracle, k))
    np.testing.assert_allclose(vals, oracle[idx], atol=1e-5, rtol=1e-5)
    # descending order is part of the contract
    assert np.all(np.diff(vals) <= 1e-7)


@pytest.mark.parametrize("backend", BACKENDS)
def test_score_select_diverse_oversample_path(backend):
    """Diverse plans: device-MMR backends return the FINAL-k selection
    (bit-identical to select_candidates on the full oracle); host
    backends — and device ones forced to ``fused_mmr=False`` — return
    the oversample pool, and finalize reproduces the same answer."""
    mat, days = _corpus(seed=17)
    plan = _plan(diverse=True, pool=20)
    oracle = np.asarray(M.modulate_scores(mat, days, plan))
    k = plan.pool
    w = selection_width(plan, k, mat.shape[0])
    assert w == min(plan.diverse.oversample * plan.pool, mat.shape[0])
    expected = select_candidates(mat, oracle, k, plan)

    b = get_backend(backend)
    (idx, vals), = b.score_select(mat, days, [plan], [k])
    if b.device_mmr:
        # fused in-kernel MMR: final k straight off the device
        assert idx.shape == (k,)
        assert list(idx) == list(expected)
        np.testing.assert_allclose(vals, oracle[idx], atol=1e-5, rtol=1e-5)
        # explicit opt-out restores the host-pool contract
        (idx, vals), = b.score_select(mat, days, [plan], [k],
                                      fused_mmr=False)
    assert idx.shape == (w,)
    # the top-pool SET matches the host oracle's oversampled pool
    assert set(idx.tolist()) == set(top_idx(oracle, w).tolist())
    np.testing.assert_allclose(vals, oracle[idx], atol=1e-5, rtol=1e-5)

    fidx, fvals = finalize_candidates(mat, idx, vals, k, plan)
    assert list(fidx) == list(expected)
    np.testing.assert_allclose(fvals, oracle[expected], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_score_select_matches_pem_topk_reference(backend):
    """Against the dist oracle: uniform half-life, fused panels, global
    top-k — the contract every sharded/fused lowering must reproduce."""
    import jax.numpy as jnp

    from repro.dist.pem_sharded import pem_topk_reference

    mat, days = _corpus(seed=23)
    plan = _plan(decay=True)
    k = 40
    q_pre, q_sup = M.fold_plans([plan])
    i_ref, v_ref = pem_topk_reference(
        jnp.asarray(mat), jnp.asarray(days), jnp.asarray(q_pre),
        jnp.asarray(q_sup), k, half_life=plan.decay.half_life_days)

    (idx, vals), = get_backend(backend).score_select(mat, days, [plan], [k])
    assert list(idx) == list(np.asarray(i_ref)[0])
    np.testing.assert_allclose(vals, np.asarray(v_ref)[0],
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_score_select_mixed_batch_per_request_k(backend):
    """Engine-style micro-batch: mixed decay/no-decay plans, different k
    per request — every plan's candidates match its own oracle column."""
    mat, days = _corpus(seed=29)
    plans = [
        _plan("alpha architecture", n_suppress=2),
        _plan("beta deployment", n_suppress=1, decay=False, trajectory=False),
        _plan("gamma landing page", n_suppress=0, decay=True),
    ]
    ks = [7, 13, 5]
    selected = get_backend(backend).score_select(mat, days, plans, ks)
    assert len(selected) == len(plans)
    for (idx, vals), plan, k in zip(selected, plans, ks):
        oracle = np.asarray(M.modulate_scores(mat, days, plan))
        assert list(idx) == list(top_idx(oracle, k))
        np.testing.assert_allclose(vals, oracle[idx], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_score_select_k_zero_and_requires_days(backend):
    mat, days = _corpus(seed=31)
    plan = _plan()
    (idx, vals), = get_backend(backend).score_select(mat, days, [plan], [0])
    assert idx.size == 0 and vals.size == 0
    with pytest.raises(ValueError, match="decay"):
        get_backend(backend).score_select(mat, None, [plan], [5])


# ---------------------------------------------------------------------------
# PlanCache: zero retraces on identical structure, one per new bucket
# ---------------------------------------------------------------------------


def test_plan_structure_buckets():
    mat, days = _corpus()
    n = mat.shape[0]
    mk = lambda s: _plan(n_suppress=s)
    k = 10
    w = [selection_width(mk(0), k, n)]
    assert PlanStructure.of([mk(3)], w, n).suppress_bucket == 4
    assert PlanStructure.of([mk(4)], w, n).suppress_bucket == 4
    assert PlanStructure.of([mk(5)], w, n).suppress_bucket == 8
    assert PlanStructure.of([mk(0)], w, n).suppress_bucket == 0
    # top-k width pads to powers of two, clamped to the ROW BUCKET (the
    # device row grid is itself pow2-padded; masking hides the padding)
    assert PlanStructure.of([mk(1)], [10], n).width == 16
    assert PlanStructure.of([mk(1)], [1000], n).width == 256
    # the row count keys by pow2 bucket, not exactly: nearby segment /
    # pre-filter sizes share one compiled executable
    assert (PlanStructure.of([mk(1)], [10], 220)
            == PlanStructure.of([mk(1)], [10], 255))
    assert (PlanStructure.of([mk(1)], [10], 220)
            != PlanStructure.of([mk(1)], [10], 257))
    assert PlanStructure.of([mk(1)], [10], n).n_rows == 256
    # distinct texts, same shape -> the SAME structure (cache key)
    s1 = PlanStructure.of([_plan("first text")], [10], n)
    s2 = PlanStructure.of([_plan("totally different text")], [10], n)
    assert s1 == s2


def test_plan_cache_zero_retraces_across_distinct_texts():
    """Three queries with distinct texts but identical plan structure:
    exactly ONE jax trace (counted from inside the traced body)."""
    mat, days = _corpus(seed=37)
    be = JitJaxBackend()
    for text in ("alpha query text", "beta entirely different words",
                 "gamma third phrasing"):
        be.score_select(mat, days, [_plan(text)], [10])
    assert be.plan_cache.builds == 1
    assert be.plan_cache.hits == 2
    assert be.plan_cache.jax_traces == 1


def test_plan_cache_retraces_on_new_suppress_bucket():
    mat, days = _corpus(seed=41)
    be = JitJaxBackend()
    be.score_select(mat, days, [_plan(n_suppress=1)], [10])
    assert be.plan_cache.jax_traces == 1
    # same bucket (1): no retrace even though the direction values differ
    be.score_select(mat, days, [_plan("other text", n_suppress=1)], [10])
    assert be.plan_cache.jax_traces == 1
    # bucket 1 -> 2: a genuinely new suppress-count bucket traces once
    be.score_select(mat, days, [_plan(n_suppress=2)], [10])
    assert be.plan_cache.jax_traces == 2
    # 3 and 4 suppressions share bucket 4: one trace serves both
    be.score_select(mat, days, [_plan(n_suppress=3)], [10])
    be.score_select(mat, days, [_plan(n_suppress=4)], [10])
    assert be.plan_cache.jax_traces == 3
    # suppress-free plans drop the second matmul: separate graph
    be.score_select(mat, days, [_plan(n_suppress=0)], [10])
    assert be.plan_cache.jax_traces == 4


def test_plan_cache_decay_presence_is_structural():
    mat, days = _corpus(seed=43)
    be = JitJaxBackend()
    be.score_select(mat, days, [_plan(decay=True)], [10])
    be.score_select(mat, days, [_plan(decay=False)], [10])
    assert be.plan_cache.jax_traces == 2
    # different half-lives are runtime DATA, not structure
    p = _plan(decay=True)
    p2 = M.ModulationPlan(query=p.query, trajectory=p.trajectory,
                          decay=M.DecaySpec(half_life_days=7.0),
                          suppress=p.suppress, pool=p.pool)
    be.score_select(mat, days, [p2], [10])
    assert be.plan_cache.jax_traces == 2


def test_plan_cache_lru_eviction_bounds_executables():
    """Varied row buckets each compile once; LRU eviction bounds how many
    executables stay retained, and a HIT refreshes the entry (the hot
    segments' executables survive a stream of one-off shapes)."""
    cache = PlanCache(lambda s: ("fn", s), maxsize=2)
    mk = lambda n: PlanStructure(batch=1, n_rows=n, has_decay=True,
                                 suppress_bucket=1, width=16)
    cache.get(mk(128))
    cache.get(mk(256))
    cache.get(mk(512))          # evicts mk(128) (least recently used)
    assert len(cache) == 2 and cache.evictions == 1
    cache.get(mk(512))          # still cached
    assert cache.hits == 1
    cache.get(mk(128))          # rebuilt after eviction
    assert cache.builds == 4
    # LRU, not FIFO: a hit refreshes — the OLDER-inserted but
    # recently-USED entry survives the next eviction
    cache.get(mk(128))
    cache.get(mk(512))          # refresh 512 (inserted before 128)
    cache.get(mk(1024))         # evicts 128, NOT the refreshed 512
    assert cache.get(mk(512)) is not None
    assert cache.builds == 5    # 512 never rebuilt
    stats = cache.stats()
    assert stats["entries"] == 2 and stats["builds"] == 5


def test_sharded_plan_cache_zero_retraces():
    """The sharded fused path shares the PlanCache contract."""
    mat, days = _corpus(seed=47)
    be = ShardedBackend()
    for text in ("one query", "another query", "a third query"):
        be.score_select(mat, days, [_plan(text)], [10])
    assert be.plan_cache.builds == 1
    assert be.plan_cache.jax_traces == 1
