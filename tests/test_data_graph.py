"""Data substrates: corpora, BEIR-like datasets, graphs, neighbor sampler."""

import numpy as np
import pytest

from repro.data.beir import DATASET_SPECS, make_dataset
from repro.data.corpus import generate_corpus
from repro.data.graph import (
    CSRGraph,
    GraphBatch,
    _max_edges,
    _max_nodes,
    make_graph,
    make_molecule_batch,
    sample_subgraph,
)
from repro.data.recsys import CRITEO_1TB_VOCAB_SIZES, dlrm_batch, twotower_batch


def test_corpus_structure():
    chunks = generate_corpus(n_chunks=2000, n_sessions=50, seed=0)
    assert len(chunks) == 2000
    clusters = {c.cluster for c in chunks}
    assert clusters == {"descriptive", "implementation", "neutral"}
    n_desc = sum(c.cluster == "descriptive" for c in chunks)
    n_impl = sum(c.cluster == "implementation" for c in chunks)
    assert n_desc > 2 * n_impl          # descriptive cluster dominates (§5.1)
    types = {c.type for c in chunks}
    assert types <= {"user_prompt", "assistant", "tool_call", "file"}
    assert len({c.session_id for c in chunks}) == 50


def test_corpus_deterministic():
    a = generate_corpus(n_chunks=100, n_sessions=5, seed=9)
    b = generate_corpus(n_chunks=100, n_sessions=5, seed=9)
    assert [c.content for c in a] == [c.content for c in b]


@pytest.mark.parametrize("name", sorted(DATASET_SPECS))
def test_beir_like_datasets(name):
    ds = make_dataset(name)
    n_docs = DATASET_SPECS[name][0]
    assert len(ds.doc_texts) == n_docs
    assert len(ds.queries) >= 30                      # paper: 30 queries/set
    assert all(q for q in ds.queries)
    assert all(len(r) > 0 for r in ds.qrels)
    # synthetic 90-day uniform timestamps (paper Appendix A caveat)
    spread = (ds.now - ds.timestamps) / 86400.0
    assert spread.min() >= 0 and spread.max() <= 90.0


def test_csr_and_sampler():
    g = make_graph(300, 1500, 16, seed=0)
    csr = CSRGraph(300, g.edge_src, g.edge_dst)
    assert csr.indptr[-1] == 1500
    rng = np.random.default_rng(0)
    seeds = np.arange(20)
    sub = sample_subgraph(g, csr, seeds, [4, 3], rng)
    max_n = _max_nodes(20, [4, 3]) + 1
    max_e = _max_edges(20, [4, 3])
    assert sub.feats.shape == (max_n, 16)             # STATIC shapes
    assert sub.edge_src.shape == (max_e,)
    # real edges reference in-range nodes; padded edges hit the sink
    sink = max_n - 1
    assert (sub.edge_src[~sub.edge_mask] == sink).all()
    assert (sub.edge_src[sub.edge_mask] < max_n).all()
    # only seeds supervised
    assert sub.node_mask.sum() == len(seeds)
    # features of sampled nodes match the parent graph
    real = sub.feats[: sub.node_mask.shape[0]][~np.isclose(sub.feats, 0).all(1)]
    assert real.shape[0] >= len(seeds)


def test_sampler_isolated_nodes_self_loop():
    g = GraphBatch(
        feats=np.eye(4, dtype=np.float32),
        edge_src=np.array([0], np.int32), edge_dst=np.array([1], np.int32),
        labels=np.zeros(4, np.int32),
        node_mask=np.ones(4, bool), edge_mask=np.ones(1, bool),
    )
    csr = CSRGraph(4, g.edge_src, g.edge_dst)
    nbrs = csr.sample_neighbors(np.array([3]), 4, np.random.default_rng(0))
    assert (nbrs == 3).all()                          # self-loop fallback


def test_molecule_batch_block_diagonal():
    mol = make_molecule_batch(8, 10, 20, 6, seed=0)
    gid_src = mol.graph_ids[mol.edge_src]
    gid_dst = mol.graph_ids[mol.edge_dst]
    assert (gid_src == gid_dst).all()                 # no cross-graph edges


def test_criteo_vocab_published_sizes():
    assert len(CRITEO_1TB_VOCAB_SIZES) == 26
    assert sum(CRITEO_1TB_VOCAB_SIZES) > 1.8e8        # ~188M rows total
    assert max(CRITEO_1TB_VOCAB_SIZES) < 4.1e7        # MLPerf 40M row cap


def test_recsys_batches_within_vocab():
    b = dlrm_batch(64, 13, CRITEO_1TB_VOCAB_SIZES[:5], seed=0)
    for i, v in enumerate(CRITEO_1TB_VOCAB_SIZES[:5]):
        assert b["sparse"][:, i].max() < v
    t = twotower_batch(32, 100, 200, 8, seed=0)
    assert t["hist"].min() >= -1                       # -1 = bag padding
    assert (t["pos_item"] < 200).all()
