"""Async continuous-batching engine: admission, deadlines, pipeline, drain.

Deterministic control comes from a gate backend (``score_select`` blocks
until the test releases it), so queue states are pinned exactly — no
sleep-and-hope.  The pipeline-overlap test uses sleeps INSIDE the two
stages (pure waiting, not CPU), so the wall-clock comparison is a
scheduling property, robust on loaded CI runners.
"""

import asyncio
import concurrent.futures as cf
import sqlite3
import threading
import time

import numpy as np
import pytest

import repro.serve.engine as engine_mod
from repro.core.backends import FusedNumpyBackend
from repro.core.segments import CompactionPolicy, SegmentedCorpusStore
from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.serve.engine import (BatchedRetrievalEngine, DeadlineExceededError,
                                EngineClosedError, QueueFullError, Request)
from repro.serve.retrieval import RetrievalService

NOW = 90 * 86400.0

# captured ONCE at import: _run_staged patches this name per engine run,
# and grabbing it inside the helper would wrap the previous run's wrapper
_ORIG_TAIL = engine_mod.finalize_segment_candidates


class GateBackend(FusedNumpyBackend):
    """Backend whose scoring pass blocks until the test releases it (and
    optionally sleeps, to give the device stage a controllable duration)."""

    name = "gate"

    def __init__(self, *, released: bool = False, delay_s: float = 0.0):
        self.release = threading.Event()
        if released:
            self.release.set()
        self.entered = threading.Event()
        self.delay_s = delay_s
        self.calls = 0

    def score_select(self, *args, **kwargs):
        self.calls += 1
        self.entered.set()
        if self.delay_s:
            time.sleep(self.delay_s)
        if not self.release.wait(timeout=15.0):
            raise RuntimeError("gate backend never released (test bug)")
        return super().score_select(*args, **kwargs)


def make_cache(n=200, dim=32):
    emb = HashEmbedder(dim)
    texts = [f"item group {i % 7} tail {i}" for i in range(n)]
    return VectorCache(np.arange(n), emb.embed_batch(texts),
                       np.linspace(0, 89 * 86400, n), emb), emb


def wait_for(predicate, timeout=10.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# admission: backpressure + bounded queue
# ---------------------------------------------------------------------------


def test_backpressure_rejects_at_capacity():
    cache, _ = make_cache()
    gate = GateBackend()
    eng = BatchedRetrievalEngine(cache, max_batch=1, engine=gate, max_queue=2)
    try:
        with cf.ThreadPoolExecutor(4) as ex:
            first = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)  # first request is IN the device pass
            queued = [ex.submit(eng.search, f"similar:group {i} tail", 5)
                      for i in (2, 3)]
            assert wait_for(lambda: eng.queue_depth == 2)
            with pytest.raises(QueueFullError):
                eng.search("similar:group 4 tail", 5, timeout=5.0)
            assert eng.rejected == 1
            gate.release.set()
            assert len(first.result(10.0)) == 5
            for f in queued:
                assert len(f.result(10.0)) == 5
        assert eng.queue_depth == 0
        assert eng.stats()["rejected"] == 1
    finally:
        gate.release.set()
        eng.close()


# ---------------------------------------------------------------------------
# deadlines + priorities at collect time
# ---------------------------------------------------------------------------


def test_deadline_miss_fails_at_collect():
    cache, _ = make_cache()
    gate = GateBackend()
    eng = BatchedRetrievalEngine(cache, max_batch=1, engine=gate)
    try:
        with cf.ThreadPoolExecutor(2) as ex:
            blocker = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)
            doomed = ex.submit(eng.search, "similar:group 2 tail", 5,
                               10.0, deadline_ms=20.0)
            assert wait_for(lambda: eng.queue_depth == 1)
            time.sleep(0.1)  # let the 20 ms deadline lapse while queued
            gate.release.set()
            assert len(blocker.result(10.0)) == 5
            with pytest.raises(DeadlineExceededError):
                doomed.result(10.0)
        assert eng.deadline_misses == 1
    finally:
        gate.release.set()
        eng.close()


def test_priority_orders_collect():
    cache, _ = make_cache()
    # one-permit-per-batch gate: a one-shot release would let every batch
    # through at once, and with sub-ms batches the "which search() call
    # returned first" observation races worker-thread wakeups — stepping
    # batch by batch makes the serving order directly observable
    sem = threading.Semaphore(0)

    class StepGate(GateBackend):
        def score_select(self, *args, **kwargs):
            self.calls += 1
            self.entered.set()
            if not sem.acquire(timeout=15.0):
                raise RuntimeError("gate backend never released (test bug)")
            return FusedNumpyBackend.score_select(self, *args, **kwargs)

    gate = StepGate()
    eng = BatchedRetrievalEngine(cache, max_batch=1, engine=gate)
    order = []
    try:
        with cf.ThreadPoolExecutor(4) as ex:
            blocker = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)

            def tagged(tokens, tag, priority):
                eng.search(tokens, 5, priority=priority)
                order.append(tag)

            low = ex.submit(tagged, "similar:group 2 tail", "low", 0)
            assert wait_for(lambda: eng.queue_depth == 1)
            high = ex.submit(tagged, "similar:group 3 tail", "high", 5)
            assert wait_for(lambda: eng.queue_depth == 2)
            sem.release()                    # serve the blocker batch
            blocker.result(10.0)
            sem.release()                    # serve ONE queued request...
            assert wait_for(lambda: len(order) == 1)  # ...observe its return
            sem.release()                    # then the other
            high.result(10.0)
            low.result(10.0)
        # max_batch=1: the two queued requests served one per batch,
        # highest priority first despite arriving second
        assert order == ["high", "low"]
    finally:
        sem.release()
        sem.release()
        sem.release()
        eng.close()


# ---------------------------------------------------------------------------
# close() drains the queue (no 30 s hang)
# ---------------------------------------------------------------------------


def test_close_drains_pending_requests():
    cache, _ = make_cache()
    gate = GateBackend()
    eng = BatchedRetrievalEngine(cache, max_batch=1, engine=gate)
    with cf.ThreadPoolExecutor(4) as ex:
        in_flight = ex.submit(eng.search, "similar:group 1 tail", 5)
        assert gate.entered.wait(5.0)
        queued = [ex.submit(eng.search, f"similar:group {i} tail", 5)
                  for i in (2, 3)]
        assert wait_for(lambda: eng.queue_depth == 2)
        t0 = time.monotonic()
        closer = ex.submit(eng.close)
        time.sleep(0.05)
        gate.release.set()
        closer.result(10.0)
        # in-flight batch completes; everything queued fails FAST with a
        # clear shutdown error instead of hanging into its 30 s timeout
        assert len(in_flight.result(10.0)) == 5
        for f in queued:
            with pytest.raises(EngineClosedError):
                f.result(10.0)
        assert time.monotonic() - t0 < 10.0
    with pytest.raises(EngineClosedError):
        eng.search("similar:anything", 3)


# ---------------------------------------------------------------------------
# monotonic latency accounting
# ---------------------------------------------------------------------------


def test_latency_clock_is_monotonic_not_wall():
    # time.time() is ~1.7e9 s; time.monotonic() is process/boot-relative.
    # If someone reverts enqueued_at to wall clock, this pins it.
    req = Request(tokens="similar:x")
    assert abs(req.enqueued_at - time.monotonic()) < 60.0
    cache, _ = make_cache()
    eng = BatchedRetrievalEngine(cache, engine="fused")
    try:
        req2 = Request(tokens="similar:group 1 tail", k=3)
        eng._submit(req2)
        req2.future.result(10.0)
        assert 0.0 <= req2.latency_ms < 60_000.0
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# async facade + equivalence
# ---------------------------------------------------------------------------


def test_asearch_matches_direct_path():
    cache, _ = make_cache(300)
    eng = BatchedRetrievalEngine(cache, max_batch=16, now=NOW, engine="fused")
    tokens = [f"similar:group {i % 7} tail decay:14" for i in range(20)]
    try:
        async def main():
            return await asyncio.gather(
                *[eng.asearch(t, 5) for t in tokens])

        batched = asyncio.run(main())
        direct = [cache.search(t, now=NOW)[:5] for t in tokens]
        # rankings bit-identical; scores to fp tolerance (the (d, B) panel
        # matmul and the single-query matvec reassociate differently)
        for b, d in zip(batched, direct):
            assert [i for i, _ in b] == [i for i, _ in d]
            np.testing.assert_allclose([v for _, v in b],
                                       [v for _, v in d], rtol=1e-5)
        assert eng.batches_served < len(tokens)  # batching actually batched
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# the pipeline: overlap counter + wall-clock win
# ---------------------------------------------------------------------------


def _run_staged(monkeypatch, *, pipeline: bool, n_requests: int = 8,
                stage_s: float = 0.03):
    """Serve n_requests with both stages stubbed to sleep ``stage_s``
    (sleeps release the GIL and cost no CPU, so the comparison measures
    SCHEDULING, not machine load)."""
    cache, _ = make_cache(50)
    gate = GateBackend(released=True, delay_s=stage_s)

    def slow_tail(*args, **kwargs):
        time.sleep(stage_s)
        return _ORIG_TAIL(*args, **kwargs)

    monkeypatch.setattr(engine_mod, "finalize_segment_candidates", slow_tail)
    eng = BatchedRetrievalEngine(cache, max_batch=1, max_wait_ms=0.5,
                                 engine=gate, pipeline=pipeline)
    try:
        t0 = time.monotonic()
        with cf.ThreadPoolExecutor(n_requests) as ex:
            futs = [ex.submit(eng.search, f"similar:group {i % 7} tail", 3)
                    for i in range(n_requests)]
            for f in futs:
                assert len(f.result(30.0)) == 3
        wall = time.monotonic() - t0
        return wall, eng.overlapped_batches
    finally:
        eng.close()


def test_pipeline_overlaps_and_beats_sync_core(monkeypatch):
    wall_sync, overlap_sync = _run_staged(monkeypatch, pipeline=False)
    wall_pipe, overlap_pipe = _run_staged(monkeypatch, pipeline=True)
    # sync core serializes device+tail (~2*stage per batch); the pipeline
    # overlaps tail i with device pass i+1 (~1*stage per batch in steady
    # state).  Generous margin: pipelined must be at least 20% faster.
    assert overlap_sync == 0
    assert overlap_pipe > 0
    assert wall_pipe < wall_sync * 0.8, (wall_pipe, wall_sync)


# ---------------------------------------------------------------------------
# background compaction: idle gaps only, never inside a scoring pass
# ---------------------------------------------------------------------------


def test_compaction_policy_picks_victims():
    store = SegmentedCorpusStore(dim=4)
    rng = np.random.default_rng(0)
    for s in range(6):
        store.append(np.arange(s * 10, s * 10 + 10),
                     rng.standard_normal((10, 4)).astype(np.float32))
    # liveness pressure: tombstone 6/10 of segment 0
    store.delete(list(range(6)))
    pol = CompactionPolicy(min_live_fraction=0.5, max_segments=10)
    assert pol.should_compact(store)
    assert store.maybe_compact(pol) == 1          # folds the sparse segment
    assert store.n_segments == 6                  # 5 survivors + 1 merged
    assert not pol.should_compact(store)
    # count pressure: cap at 3 segments -> the smallest fold together
    pol2 = CompactionPolicy(min_live_fraction=0.1, max_segments=3)
    assert pol2.should_compact(store)
    assert store.maybe_compact(pol2) >= 3
    assert store.n_segments <= 3
    assert store.n_live == 54                     # no live row lost
    assert store.maybe_compact(pol2) == 0         # converged, no churn


def test_idle_compaction_never_inside_scoring_pass(monkeypatch):
    cache, _ = make_cache(300)
    store = cache.store
    windows = {"score": [], "fold": []}

    orig_sss = engine_mod.score_select_segments

    def recording_sss(*args, **kwargs):
        t0 = time.monotonic()
        out = orig_sss(*args, **kwargs)
        windows["score"].append((t0, time.monotonic()))
        return out

    monkeypatch.setattr(engine_mod, "score_select_segments", recording_sss)

    orig_fold = SegmentedCorpusStore._fold

    def recording_fold(self, victims):
        t0 = time.monotonic()
        out = orig_fold(self, victims)
        if out:
            windows["fold"].append((t0, time.monotonic()))
        return out

    monkeypatch.setattr(SegmentedCorpusStore, "_fold", recording_fold)

    pol = CompactionPolicy(min_live_fraction=0.9, max_segments=4)
    eng = BatchedRetrievalEngine(cache, max_batch=8, now=NOW, engine="fused",
                                 compaction=pol)
    emb = HashEmbedder(32)
    try:
        stop = threading.Event()

        def searcher(seed):
            i = seed
            while not stop.is_set():
                eng.search(f"similar:group {i % 7} tail decay:14", 5)
                i += 1

        threads = [threading.Thread(target=searcher, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        # fragment the store while queries race: appends + deletes
        next_id = 10_000
        rng = np.random.default_rng(1)
        for cycle in range(8):
            ids = np.arange(next_id, next_id + 12)
            next_id += 12
            eng.ingest(ids, rng.standard_normal((12, 32)).astype(np.float32),
                       np.full(12, NOW - 1000.0))
            eng.delete(ids[:8].tolist())
            time.sleep(0.02)
        stop.set()
        for t in threads:
            t.join(10.0)
        # idle gap: the scheduler should now run the compaction policy
        assert wait_for(lambda: eng.compactions_run >= 1, timeout=10.0)
        assert store.compactions >= 1
    finally:
        eng.close()

    assert windows["fold"], "compaction never ran"
    for fs, fe in windows["fold"]:
        for ss, se in windows["score"]:
            assert fe <= ss or se <= fs, (
                f"compaction [{fs:.4f},{fe:.4f}] landed inside scoring "
                f"pass [{ss:.4f},{se:.4f}]")


# ---------------------------------------------------------------------------
# concurrent ingest/delete racing the scheduler
# ---------------------------------------------------------------------------


def test_concurrent_mutations_stay_bit_identical():
    cache, _ = make_cache(250)
    eng = BatchedRetrievalEngine(
        cache, max_batch=8, now=NOW, engine="fused",
        compaction=CompactionPolicy(min_live_fraction=0.6, max_segments=5))
    tokens = [f"similar:group {i} tail decay:14" for i in range(7)]
    tokens.append("similar:group 2 tail diverse decay:14")
    errors = []
    try:
        stop = threading.Event()

        def searcher(seed):
            i = seed
            while not stop.is_set():
                try:
                    out = eng.search(tokens[i % len(tokens)], 5)
                    assert out, "search returned empty on a live corpus"
                except Exception as e:  # pragma: no cover - failure path
                    errors.append(e)
                    return
                i += 1

        threads = [threading.Thread(target=searcher, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()

        # mutate in bursts; between bursts (mutations quiesced, searches
        # still racing) batched rankings must be bit-identical to the
        # direct VectorCache path on the SAME store state
        rng = np.random.default_rng(7)
        next_id = 50_000
        for burst in range(5):
            ids = np.arange(next_id, next_id + 30)
            next_id += 30
            eng.ingest(ids,
                       rng.standard_normal((30, 32)).astype(np.float32),
                       np.linspace(0, 80 * 86400, 30))
            eng.delete(rng.choice(ids, size=10, replace=False).tolist())
            time.sleep(0.01)
            for t_q in tokens:
                batched = eng.search(t_q, 5)
                direct = cache.search(t_q, now=NOW)[:5]
                assert ([i for i, _ in batched] == [i for i, _ in direct]
                        ), (burst, t_q, batched, direct)
                np.testing.assert_allclose([v for _, v in batched],
                                           [v for _, v in direct],
                                           rtol=1e-5)
        stop.set()
        for t in threads:
            t.join(10.0)
        assert not errors, errors
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# service surface: async entry points + serving stats
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def async_service():
    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=300, n_sessions=20, seed=5)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    svc = RetrievalService(conn, dim=64, embedder=emb, now=1_770_000_000.0)
    yield svc
    svc.close()


def test_service_async_surface(async_service):
    svc = async_service

    async def main():
        res = await svc.flex_search_async(
            "SELECT v.id FROM vec_ops('similar:server pool:5') v LIMIT 3")
        assert res.ok, res.error
        hits = await svc.search_async("similar:server lifecycle decay:30", 5)
        assert len(hits) == 5
        row = (9001, "s1", "user", "fresh doc text", 1_769_000_000.0, 0,
               "proj", None, None, None)
        assert await svc.ingest_async([row]) == 1
        hit_ids = [i for i, _ in
                   await svc.search_async("similar:fresh doc text", 3)]
        assert 9001 in hit_ids
        assert await svc.delete_async([9001]) == 1
        return svc.stats()

    stats = asyncio.run(main())
    serving = stats["serving"]
    assert serving["requests_served"] >= 2
    assert serving["queue_depth"] == 0
    for key in ("rejected", "deadline_misses", "overlapped_batches",
                "compactions_run", "max_queue", "batches_served"):
        assert key in serving


# ---------------------------------------------------------------------------
# async dispatch: device future + held admission window
# ---------------------------------------------------------------------------


def test_async_dispatch_holds_window_on_busy_device():
    """While a device pass is in flight, the admission window stays open:
    arrivals fold into ONE next cohort instead of fragmenting into queued
    micro-batches behind the busy executor."""
    cache, _ = make_cache()
    gate = GateBackend()
    eng = BatchedRetrievalEngine(cache, max_batch=4, engine=gate)
    try:
        assert eng.async_dispatch
        with cf.ThreadPoolExecutor(4) as ex:
            first = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)  # batch 1 is IN the device pass
            held = [ex.submit(eng.search, f"similar:group {i} tail", 5)
                    for i in (2, 3)]
            assert wait_for(lambda: eng.queue_depth == 2)
            # the scheduler reaches the busy-device hold (device still
            # gated, held arrivals pending) before we let the pass finish
            assert wait_for(lambda: eng.overlapped_collects >= 1)
            gate.release.set()
            assert len(first.result(10.0)) == 5
            for f in held:
                assert len(f.result(10.0)) == 5
        assert eng.overlapped_collects >= 1
        assert eng.batches_served == 2  # the two held requests = one cohort
    finally:
        gate.release.set()
        eng.close()


def test_async_dispatch_off_matches_on_and_direct():
    cache, _ = make_cache(300)
    tokens = [f"similar:group {i % 7} tail decay:14" for i in range(16)]
    res = {}
    for mode in (True, False):
        eng = BatchedRetrievalEngine(cache, max_batch=8, now=NOW,
                                     engine="fused", async_dispatch=mode)
        try:
            with cf.ThreadPoolExecutor(8) as ex:
                res[mode] = list(ex.map(lambda t: eng.search(t, 5), tokens))
        finally:
            eng.close()
    direct = [cache.search(t, now=NOW)[:5] for t in tokens]
    for a, b, d in zip(res[True], res[False], direct):
        assert ([i for i, _ in a] == [i for i, _ in b]
                == [i for i, _ in d])


def test_async_dispatch_failures_stay_per_batch():
    """A backend failure under async dispatch fails ITS batch through the
    completion chain; the engine keeps serving."""
    cache, _ = make_cache()

    class FlakyBackend(FusedNumpyBackend):
        name = "flaky"
        boom = True

        def score_select(self, *args, **kwargs):
            if FlakyBackend.boom:
                FlakyBackend.boom = False
                raise RuntimeError("injected device failure")
            return super().score_select(*args, **kwargs)

    eng = BatchedRetrievalEngine(cache, max_batch=4, engine=FlakyBackend())
    try:
        with pytest.raises(RuntimeError, match="injected"):
            eng.search("similar:group 1 tail", 5, timeout=10.0)
        assert len(eng.search("similar:group 2 tail", 5, timeout=10.0)) == 5
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# adaptive batch window
# ---------------------------------------------------------------------------


def test_adaptive_window_learns_gap_and_reports():
    cache, _ = make_cache()
    eng = BatchedRetrievalEngine(cache, max_batch=64, max_wait_ms=2.0,
                                 engine="fused")
    try:
        with cf.ThreadPoolExecutor(8) as ex:
            futs = [ex.submit(eng.search, f"similar:group {i % 7} tail", 3)
                    for i in range(24)]
            for f in futs:
                assert len(f.result(10.0)) == 3
        st = eng.stats()
        assert st["adaptive_window"] is True
        # learned quiescence gap: clamped to [0.05 ms, 4x base]
        assert 0.05 <= st["window_ms"] <= 8.0
        for key in ("overlapped_collects", "windows_extended",
                    "async_dispatch"):
            assert key in st
    finally:
        eng.close()


def test_fixed_window_mode_reports_base():
    cache, _ = make_cache()
    eng = BatchedRetrievalEngine(cache, max_wait_ms=3.0, engine="fused",
                                 adaptive_window=False)
    try:
        st = eng.stats()
        assert st["adaptive_window"] is False
        assert st["window_ms"] == 3.0
        assert len(eng.search("similar:group 1 tail", 5)) == 5
        assert eng.windows_extended == 0
    finally:
        eng.close()
