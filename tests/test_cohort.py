"""Cohort-streamed scoring: the multi-query corpus-stream-amortizing mode.

The pinned contract (``ShardWorker._fast_pass`` Q>1 branch + the
``score_select_cohort`` entry in ``core/backends``): a Q-plan cohort is a
LOOP REORDERING of Q serial passes — every per-plan (d, 2) GEMM runs on
the same 1536-row corpus blocks with the same operands — so cohort
rankings AND scores are bit-identical to the serial per-query pass, while
each shard's corpus streams from RAM once per cohort instead of once per
query (counter-pinned via ``corpus_streams``).  Satellites ride along:
replica failover, per-shard row skew, and pow2 Q-bucketing of the device
plan cache.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import (JitJaxBackend, score_select_cohort,
                                 score_select_segments)
from repro.core.segments import SegmentedCorpusStore
from repro.core.vectorcache import VectorCache
from repro.dist.procgroup import ProcessGroup
from repro.embed import HashEmbedder

DIM = 64
NOW = 1_770_000_000.0
N = 480  # 3 shards x 160 rows, block-aligned (160 % 4 == 0)


@pytest.fixture(scope="module")
def emb():
    return HashEmbedder(DIM)


def _texts(n, offset=0):
    return [f"topic {(offset + i) % 37} filler {(offset + i) % 11}"
            for i in range(n)]


@pytest.fixture(scope="module")
def corpus(emb):
    ids = np.arange(N, dtype=np.int64)
    matrix = emb.embed_batch(_texts(N))
    ts = np.linspace(NOW - 90 * 86400.0, NOW - 3600.0, N)
    return ids, matrix, ts


def _group(corpus, **kw):
    ids, matrix, ts = corpus
    kw.setdefault("n_shards", 3)
    kw.setdefault("transport", "inline")
    kw.setdefault("dtype", "f32b")
    return ProcessGroup.build(ids, matrix, ts, **kw)


def _vc(corpus, emb):
    ids, matrix, ts = corpus
    return VectorCache(ids, matrix, ts, emb)


def _parse(vc, tokens):
    return grammar.parse(tokens, vc.embed_fn, vc.embeddings_for_ids,
                         vc.lexical_fn)


# mixed cohort: distinct half-lives (incl. none), suppression widths 0-2,
# one diverse plan — every hl-group branch of the cohort pass executes
COHORT_SHAPES = [
    "similar:server lifecycle pool:60",
    "similar:session handling suppress:landing page decay:30 pool:60",
    "similar:retry logic decay:7 pool:60",
    "similar:cache eviction suppress:website design suppress:draft decay:30 pool:64",
    "similar:error handling diverse pool:48",
]


def _cohort_plans(vc, q):
    return [_parse(vc, COHORT_SHAPES[i % len(COHORT_SHAPES)])
            for i in range(q)]


# -- cohort == serial, bit for bit ----------------------------------------


@pytest.mark.parametrize("dtype", ["f32b", "bf16"])
@pytest.mark.parametrize("transport,n_shards",
                         [("inline", 1), ("inline", 3), ("thread", 3)])
def test_cohort_bit_identical_to_serial(corpus, emb, dtype, transport,
                                        n_shards):
    vc = _vc(corpus, emb)
    with _group(corpus, dtype=dtype, transport=transport,
                n_shards=n_shards) as g:
        for q in (1, 4, 16):
            plans = _cohort_plans(vc, q)
            serial = [g.search_plan(p, now=NOW, k=20) for p in plans]
            cohort = g.search_plan_batch(plans, [None] * q, now=NOW,
                                         ks=[20] * q)
            # full tuple equality: ids AND float scores, no tolerance
            assert cohort == serial, (dtype, transport, n_shards, q)


def test_cohort_streams_corpus_once(corpus, emb):
    """The counter-pinned bandwidth claim: Q=16 -> ONE blocked stream per
    shard per cohort; 16 serial queries -> 16 streams per shard."""
    vc = _vc(corpus, emb)
    with _group(corpus) as g:
        plans = _cohort_plans(vc, 16)
        before = {s["shard"]: s["corpus_streams"]
                  for s in g.stats()["shards"]}
        g.search_plan_batch(plans, [None] * 16, now=NOW, ks=[10] * 16)
        after = {s["shard"]: s for s in g.stats()["shards"]}
        for sid, row in after.items():
            assert row["corpus_streams"] - before[sid] == 1
            assert row["cohort_passes"] >= 1
            assert row["cohort_plans"] >= 16
        mid = {s["shard"]: s["corpus_streams"]
               for s in g.stats()["shards"]}
        for p in plans:
            g.search_plan(p, now=NOW, k=10)
        final = {s["shard"]: s["corpus_streams"]
                 for s in g.stats()["shards"]}
        for sid in final:
            assert final[sid] - mid[sid] == 16
        assert g.stats()["corpus_streams"] >= 17


def test_cohort_parity_under_mutations(corpus, emb):
    """Delete + append between cohorts: cohort == serial at every store
    state (the blocked view rebuilds identically for both paths)."""
    ids, matrix, ts = corpus
    vc = _vc(corpus, emb)
    with _group(corpus) as g:
        rng = np.random.default_rng(3)
        next_id = 20_000
        for burst in range(3):
            dead = [int(i) for i in rng.choice(ids, 25, replace=False)
                    if i < N][:20]
            g.delete(dead)
            fresh = np.arange(next_id, next_id + 96, dtype=np.int64)
            next_id += 96
            g.append(fresh, emb.embed_batch(_texts(96, offset=700 + burst)),
                     np.full(96, NOW - 7200.0 * (burst + 1)))
            plans = _cohort_plans(vc, 8)
            serial = [g.search_plan(p, now=NOW, k=15) for p in plans]
            cohort = g.search_plan_batch(plans, [None] * 8, now=NOW,
                                         ks=[15] * 8)
            assert cohort == serial, f"burst {burst}"


# -- satellite: replica failover ------------------------------------------


def _small(emb, n=128):
    ids = np.arange(n, dtype=np.int64)
    matrix = emb.embed_batch(_texts(n))
    ts = np.linspace(NOW - 30 * 86400.0, NOW - 3600.0, n)
    return ids, matrix, ts


def test_failover_retries_surviving_replica(emb):
    ids, matrix, ts = _small(emb)
    vc = VectorCache(ids, matrix, ts, emb)
    plan = _parse(vc, "similar:server lifecycle pool:40")
    with ProcessGroup.build(ids, matrix, ts, n_shards=2, replicas=2,
                            transport="process") as g:
        want = g.search_plan(plan, now=NOW)
        victim = g._clients[0][0]
        victim._proc.kill()
        victim._proc.join(timeout=5.0)
        # both round-robin positions must survive the dead replica
        assert g.search_plan(plan, now=NOW) == want
        assert g.search_plan(plan, now=NOW) == want
        st = g.stats()
        assert st["failovers"] >= 1
        assert st["dead_replicas"] == 1
        # mutations keep fanning to survivors (dead replica skipped)
        assert g.delete([0, 1, 2, 3]) == 4
        assert g.n_live == len(ids) - 4
        vc.store.delete([0, 1, 2, 3])
        got = g.search_plan(plan, now=NOW)
        assert {int(i) for i, _ in got}.isdisjoint({0, 1, 2, 3})


def test_failover_exhausted_raises(emb):
    ids, matrix, ts = _small(emb, n=64)
    vc = VectorCache(ids, matrix, ts, emb)
    plan = _parse(vc, "similar:server lifecycle pool:20")
    with ProcessGroup.build(ids, matrix, ts, n_shards=2, replicas=1,
                            transport="process") as g:
        g.search_plan(plan, now=NOW)
        victim = g._clients[1][0]
        victim._proc.kill()
        victim._proc.join(timeout=5.0)
        with pytest.raises(RuntimeError, match="no surviving replicas"):
            for _ in range(2):  # hit both round-robin positions
                g.search_plan(plan, now=NOW)


def test_application_errors_do_not_failover(emb):
    """A worker-side ValueError is a BAD REQUEST, not a dead transport:
    it must propagate (as the pickle-RPC's wrapped RuntimeError — the
    worker stays alive) and never retry a replica or mark anything dead."""
    ids, matrix, _ = _small(emb, n=64)
    vc = VectorCache(ids, matrix, None, emb)
    plan = _parse(vc, "similar:x decay:14")  # decay w/o timestamps
    with ProcessGroup.build(ids, matrix, n_shards=2, replicas=2,
                            transport="process") as g:
        with pytest.raises(RuntimeError, match="decay"):
            g.search_plan(plan, now=NOW)
        st = g.stats()
        assert st["failovers"] == 0
        assert st["dead_replicas"] == 0


# -- satellite: per-shard row skew ----------------------------------------


def test_stats_expose_row_skew(corpus, emb):
    with _group(corpus) as g:
        st = g.stats()
        skew = st["row_skew"]
        assert skew["max_live"] == skew["min_live"] == N // 3
        assert skew["spread"] == 0 and skew["ratio"] == 1.0
        # tombstone 100 rows dealt to shard 0 only -> visible imbalance
        dead = [i for i, s in g._shard_of.items() if s == 0][:100]
        g.delete(dead)
        skew = g.stats()["row_skew"]
        assert skew["max_live"] == N // 3
        assert skew["min_live"] == N // 3 - 100
        assert skew["spread"] == 100
        assert skew["ratio"] == round((N // 3) / (N // 3 - 100), 3)


# -- device plan-cache Q-bucketing ----------------------------------------


def _seg_store(emb, n=256):
    mat = emb.embed_batch(_texts(n))
    ts = NOW - np.linspace(1.0, 50.0, n) * 86400.0
    store = SegmentedCorpusStore(dim=DIM)
    store.append(np.arange(n), mat, ts, normalized=True)
    return store


def test_jit_cohort_pow2_buckets_share_executables(corpus, emb):
    """cohort=True pow2-buckets the batch axis: Q=3 and Q=4 cohorts of
    the same plan shape compile ONE executable; without the flag each Q
    is its own structure."""
    store = _seg_store(emb)
    vc = VectorCache(store=store, embed_fn=emb)
    segs = store.segments
    mk = lambda i: _parse(vc, f"similar:topic {i} filler pool:40")

    be = JitJaxBackend()
    out3 = score_select_cohort(be, segs, [mk(i) for i in range(3)],
                               [10] * 3, now=NOW)
    out4 = score_select_cohort(be, segs, [mk(i + 3) for i in range(4)],
                               [10] * 4, now=NOW)
    assert be.plan_cache.builds == 1  # both cohorts land in the Q=4 bucket
    assert len(out3) == 3 and len(out4) == 4

    be2 = JitJaxBackend()
    score_select_segments(be2, segs, [mk(i) for i in range(3)],
                          [10] * 3, now=NOW)
    score_select_segments(be2, segs, [mk(i + 3) for i in range(4)],
                          [10] * 4, now=NOW)
    assert be2.plan_cache.builds == 2  # exact-Q structures don't bucket

    # padded cohort columns slice away: per-plan ids == the fused oracle
    for j, (gidx, _) in enumerate(out3):
        (want,) = score_select_segments("fused-numpy", segs, [mk(j)], [10],
                                        now=NOW)
        np.testing.assert_array_equal(gidx, want[0])
