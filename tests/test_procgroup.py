"""Cross-shard merge parity: the ProcessGroup shard-replica router vs the
monolithic fused-numpy oracle.

The pinned contract (see ``repro/dist/procgroup.py``): with every sealed
per-shard slice block-aligned (row counts divisible by 4 — these tests
deal appends divisible by ``n_shards * 32``), the group's fan-out +
exact-union merge is BIT-IDENTICAL to a monolithic ``VectorCache`` over
the same rows, across segmentations x tombstones x candidate masks x
diverse lambdas, including exact cross-shard score ties (resolved by
insertion rank, = the monolith's stable sort order).  Filtered cases pin
against an always-mask oracle (``PrefilterRouter(mask_threshold=0.0)``)
because the router's gather path scores a scratch matrix whose BLAS
tail-kernel low bits differ from the warm-segment masked pass.

Batched-engine routing is pinned at id level, the same contract as
``test_batched_engine_matches_direct``: the engine folds B plans into one
GEMM panel whose low bits differ from the B=1 direct pass.
"""

import concurrent.futures as cf
import dataclasses
import zlib

import numpy as np
import pytest

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import PrefilterRouter, top_idx
from repro.core.segments import pack_bf16, unpack_bf16
from repro.core.vectorcache import VectorCache
from repro.dist.procgroup import ProcessGroup, ShardWorker
from repro.embed import HashEmbedder

DIM = 64
NOW = 1_770_000_000.0
N = 480  # 3 shards x 160 rows, 160 % 4 == 0


def _texts(n, offset=0):
    # i and i+407 share a text exactly -> identical embeddings -> exact
    # score ties, landing in DIFFERENT shards (407 % 3 != 0), so the
    # cross-shard rank-based tie merge is actually exercised
    return [f"topic {(offset + i) % 37} filler {(offset + i) % 11}"
            for i in range(n)]


@pytest.fixture(scope="module")
def emb():
    return HashEmbedder(DIM)


@pytest.fixture(scope="module")
def corpus(emb):
    ids = np.arange(N, dtype=np.int64)
    matrix = emb.embed_batch(_texts(N))
    ts = np.linspace(NOW - 90 * 86400.0, NOW - 3600.0, N)
    return ids, matrix, ts


def _lex(term, limit):
    """Deterministic synthetic keyword resolver over ids 0..N-1."""
    seed = zlib.crc32(term.encode())
    rng = np.random.default_rng(seed)
    n = min(limit, 64)
    ids = rng.choice(N, size=n, replace=False).astype(np.int64)
    scores = np.sort(rng.random(n).astype(np.float32))[::-1]
    return ids, M.minmax_normalize(scores)


def _oracle(corpus, emb, always_mask=False):
    ids, matrix, ts = corpus
    pf = PrefilterRouter(mask_threshold=0.0) if always_mask else None
    return VectorCache(ids, matrix, ts, emb, prefilter=pf, lexical_fn=_lex)


def _group(corpus, **kw):
    ids, matrix, ts = corpus
    kw.setdefault("n_shards", 3)
    kw.setdefault("transport", "inline")
    return ProcessGroup.build(ids, matrix, ts, **kw)


def _parse(vc, tokens):
    return grammar.parse(tokens, vc.embed_fn, vc.embeddings_for_ids,
                         vc.lexical_fn)


TOKEN_SHAPES = [
    "similar:server lifecycle pool:60",
    "similar:session handling suppress:landing page pool:60",
    "similar:retry logic decay:21 pool:60",
    "similar:cache eviction suppress:website design decay:30 pool:64",
    "similar:error handling diverse pool:48",
    "similar:auth keyword:token fuse:weighted,0.6 pool:40",
    "similar:auth keyword:token fuse:rrf pool:40",
]


# -- bf16 codec -----------------------------------------------------------


def test_bf16_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((257, DIM)).astype(np.float32)
    codes = pack_bf16(x)
    assert codes.dtype == np.uint16 and codes.shape == x.shape
    dec = unpack_bf16(codes)
    # decode == truncate-to-bf16 exactly (low 16 mantissa bits zeroed)
    want = (x.view(np.uint32) & np.uint32(0xFFFF0000)).view(np.float32)
    np.testing.assert_array_equal(dec, want)
    # codes survive a decode->re-encode cycle bit-for-bit
    np.testing.assert_array_equal(pack_bf16(dec), codes)
    # reusable scratch path
    scratch = np.empty(codes.shape, dtype=np.uint32)
    np.testing.assert_array_equal(unpack_bf16(codes, out=scratch), want)


def test_top_idx_deterministic_ties():
    rng = np.random.default_rng(1)
    scores = rng.integers(0, 40, 500).astype(np.float32)  # heavy ties
    for k in (1, 7, 40, 250, 499, 500):
        got = top_idx(scores, k)
        want = np.argsort(-scores, kind="stable")[:k]
        np.testing.assert_array_equal(got, want)


# -- group vs monolith parity --------------------------------------------


@pytest.mark.parametrize("transport", ["inline", "thread"])
def test_group_matches_monolith(corpus, emb, transport):
    vc = _oracle(corpus, emb)
    with _group(corpus, transport=transport) as g:
        for tokens in TOKEN_SHAPES:
            plan = _parse(vc, tokens)
            a = g.search_plan(plan, now=NOW)
            b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
            assert a == b, f"mismatch for {tokens!r}"


def test_group_segmentations_and_tombstones(corpus, emb):
    ids, matrix, ts = corpus
    vc = _oracle(corpus, emb)
    with _group(corpus) as g:
        # grow both sides in aligned slices (96 and 192 rows: per-shard
        # slices of 32/64 rows) -> multiple sealed segments per shard
        for extra, off in ((96, 1000), (192, 2000)):
            eids = np.arange(off, off + extra, dtype=np.int64)
            emat = emb.embed_batch(_texts(extra, offset=off))
            ets = np.linspace(NOW - 40 * 86400.0, NOW - 7200.0, extra)
            vc.store.append(eids, emat, ets)
            g.append(eids, emat, ets)
        # tombstones: full-segment GEMMs are unaffected by liveness, so
        # any spread works; hit every shard and every segment
        dead = ([int(i) for i in range(0, 90, 5)]
                + [1000 + i for i in range(0, 40, 7)]
                + [2000 + i for i in range(0, 150, 11)])
        assert vc.store.delete(dead) == g.delete(dead) == len(dead)
        assert g.n_live == vc.store.n_live
        for tokens in TOKEN_SHAPES:
            plan = _parse(vc, tokens)
            a = g.search_plan(plan, now=NOW)
            b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
            assert a == b, f"mismatch for {tokens!r}"


def test_group_candidate_masks(corpus, emb):
    # always-mask oracle: the default router would gather sharp filters
    # into a scratch matrix whose tail-kernel low bits diverge
    vc = _oracle(corpus, emb, always_mask=True)
    rng = np.random.default_rng(7)
    with _group(corpus) as g:
        for frac in (0.5, 0.3):
            cand = rng.choice(N, size=int(N * frac), replace=False)
            for tokens in TOKEN_SHAPES:
                plan = _parse(vc, tokens)
                a = g.search_plan(plan, list(cand), now=NOW)
                b = vc.search_plan(plan, list(cand), now=NOW,
                                   engine="fused-numpy")
                assert a == b, f"mismatch for {tokens!r} @ {frac}"
        # empty candidate set -> empty result, not an error
        plan = _parse(vc, TOKEN_SHAPES[0])
        assert g.search_plan(plan, [], now=NOW) == []


def test_group_diverse_lambda_sweep(corpus, emb):
    vc = _oracle(corpus, emb)
    with _group(corpus) as g:
        base = _parse(vc, "similar:error handling diverse pool:48")
        for lam in (0.0, 0.3, 0.7, 1.0):
            plan = dataclasses.replace(
                base, diverse=M.DiverseSpec(lam=lam))
            a = g.search_plan(plan, now=NOW)
            b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
            assert a == b, f"mismatch at lambda={lam}"


def test_group_cross_shard_tie_order(corpus, emb):
    """Exact duplicate rows in different shards: global order must be the
    monolith's insertion order (rank merge), asserted on a plan whose
    top-k actually contains both tie members."""
    vc = _oracle(corpus, emb)
    with _group(corpus) as g:
        tokens = f"similar:{_texts(1)[0]} pool:80"  # query == row 0's text
        plan = _parse(vc, tokens)
        a = g.search_plan(plan, now=NOW)
        b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
        assert a == b
        pos = {int(i): p for p, (i, _) in enumerate(a)}
        assert 0 in pos and 407 in pos, "tie pair missing from top-80"
        assert pos[0] < pos[407], "tie must resolve by insertion order"


def test_group_fuse_filter_parity(corpus, emb):
    """fuse:filter promotes the FTS hit set to the Phase-1 candidate set
    on both sides (satellite: selectivity crossover for the lexical leg)."""
    vc = _oracle(corpus, emb, always_mask=True)
    with _group(corpus) as g:
        for tokens in ("similar:auth keyword:token fuse:filter pool:40",
                       "similar:auth keyword:token fuse:filter,0.8 pool:40"):
            plan = _parse(vc, tokens)
            a = g.search_plan(plan, now=NOW)
            b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
            assert a == b, f"mismatch for {tokens!r}"
            got = {int(i) for i, _ in a}
            hits = set(int(i) for i in plan.lexical.ids)
            assert got <= hits, "fuse:filter must restrict to FTS hits"


def test_group_k_truncation(corpus, emb):
    vc = _oracle(corpus, emb)
    with _group(corpus) as g:
        plan = _parse(vc, "similar:server lifecycle pool:60")
        full = g.search_plan(plan, now=NOW)
        assert len(full) == 60
        assert g.search_plan(plan, now=NOW, k=10) == full[:10]
        assert len(g.search_plan(plan, now=NOW, k=10_000)) == g.n_live


# -- process transport ----------------------------------------------------


def test_process_transport_parity(emb):
    ids = np.arange(128, dtype=np.int64)
    matrix = emb.embed_batch(_texts(128))
    ts = np.linspace(NOW - 30 * 86400.0, NOW - 3600.0, 128)
    vc = VectorCache(ids, matrix, ts, emb, lexical_fn=_lex)
    with ProcessGroup.build(ids, matrix, ts, n_shards=2,
                            transport="process") as g:
        for tokens in ("similar:server lifecycle pool:40",
                       "similar:retry logic decay:21 diverse pool:32"):
            plan = _parse(vc, tokens)
            a = g.search_plan(plan, now=NOW)
            b = vc.search_plan(plan, now=NOW, engine="fused-numpy")
            assert a == b, f"mismatch for {tokens!r}"
        # mutations cross the pipe too
        g.delete([0, 1, 2, 3])
        vc.store.delete([0, 1, 2, 3])
        plan = _parse(vc, "similar:server lifecycle pool:40")
        assert (g.search_plan(plan, now=NOW)
                == vc.search_plan(plan, now=NOW, engine="fused-numpy"))


# -- bf16 scoring mode ----------------------------------------------------


def test_bf16_group_quality_and_fallback(corpus, emb):
    ids, matrix, ts = corpus
    vc = _oracle(corpus, emb, always_mask=True)
    with _group(corpus, dtype="bf16") as g, _group(corpus) as g32:
        plan = _parse(vc, "similar:server lifecycle decay:21 pool:60")
        b16 = g.search_plan(plan, now=NOW, k=20)
        f32 = g32.search_plan(plan, now=NOW, k=20)
        top = {int(i) for i, _ in b16} & {int(i) for i, _ in f32}
        assert len(top) >= 15, f"bf16 top-20 overlap too low: {len(top)}"
        # candidate sets disable the packed fast path -> exact f32 parity
        cand = [int(i) for i in ids[::2]]
        a = g.search_plan(plan, cand, now=NOW)
        b = vc.search_plan(plan, cand, now=NOW, engine="fused-numpy")
        assert a == b
        st = g.stats()
        for s in st["shards"]:
            assert s["dtype"] == "bf16"
            assert 0 < s["codes_bytes"] == s["matrix_bytes"] // 2
            assert s["scoring_bytes"] in (s["codes_bytes"],
                                          s["matrix_bytes"])


@pytest.mark.parametrize("dtype", ["bf16", "f32b"])
def test_fast_path_decay_requires_timestamps(emb, dtype):
    ids = np.arange(64, dtype=np.int64)
    matrix = emb.embed_batch(_texts(64))
    vc = VectorCache(ids, matrix, None, emb)
    with ProcessGroup.build(ids, matrix, n_shards=2, dtype=dtype) as g:
        plan = _parse(vc, "similar:x decay:14")
        with pytest.raises(ValueError, match="decay"):
            g.search_plan(plan, now=NOW)


# -- f32b blocked single-stream mode --------------------------------------


def test_f32b_group_quality_and_fallback(corpus, emb):
    ids, matrix, ts = corpus
    vc = _oracle(corpus, emb, always_mask=True)
    with _group(corpus, dtype="f32b") as g, _group(corpus) as g32:
        plan = _parse(vc, "similar:server lifecycle decay:21 pool:60")
        fast = g.search_plan(plan, now=NOW, k=20)
        exact = g32.search_plan(plan, now=NOW, k=20)
        # same rows, same formula — only final-ulp GEMM accumulation
        # order differs, so rankings agree up to boundary near-ties
        top = {int(i) for i, _ in fast} & {int(i) for i, _ in exact}
        assert len(top) >= 18, f"f32b top-20 overlap too low: {len(top)}"
        got = np.array([s for _, s in fast], dtype=np.float32)
        want = dict(exact)
        ref = np.array([want.get(int(i), np.nan) for i, _ in fast],
                       dtype=np.float32)
        mask = ~np.isnan(ref)
        np.testing.assert_allclose(got[mask], ref[mask], atol=1e-5)
        # candidate sets disable the blocked fast path -> exact parity
        cand = [int(i) for i in ids[::2]]
        a = g.search_plan(plan, cand, now=NOW)
        b = vc.search_plan(plan, cand, now=NOW, engine="fused-numpy")
        assert a == b
        st = g.stats()
        for s in st["shards"]:
            assert s["dtype"] == "f32b"
            assert s["codes_bytes"] == 0  # no packed codes in this mode
            # the live view is a zero-copy segment reference here
            assert s["scoring_bytes"] == s["matrix_bytes"]


def test_f32b_group_mutations_rebuild_view(corpus, emb):
    ids, matrix, ts = corpus
    vc = _oracle(corpus, emb)
    with _group(corpus, dtype="f32b") as g:
        plan = _parse(vc, "similar:session handling pool:48")
        g.search_plan(plan, now=NOW)
        # tombstone a spread of rows and append a fresh slice: the live
        # view must rebuild (gather path) and stay in ranking agreement
        # with the exact monolith over the same mutations
        dead = [int(i) for i in ids[5:200:7]]
        g.delete(dead)
        vc.store.delete(dead)
        new_ids = np.arange(5000, 5000 + 96, dtype=np.int64)
        new_mat = emb.embed_batch(_texts(96, offset=600))
        new_ts = np.full(96, NOW - 7200.0)
        g.append(new_ids, new_mat, new_ts)
        vc.store.append(new_ids, new_mat, new_ts)
        fast = g.search_plan(plan, now=NOW, k=20)
        exact = vc.search_plan(plan, now=NOW, engine="fused-numpy")[:20]
        top = {int(i) for i, _ in fast} & {int(i) for i, _ in exact}
        assert len(top) >= 18
        assert not ({int(i) for i, _ in fast} & set(dead))
        for s in g.stats()["shards"]:
            # gathered live view: dead rows dropped from scoring bytes
            assert 0 < s["scoring_bytes"] < s["matrix_bytes"]


def test_f32b_batched_plans_agree(corpus, emb):
    vc = _oracle(corpus, emb)
    plans = [_parse(vc, t) for t in
             ("similar:server lifecycle pool:60",
              "similar:retry logic decay:21 pool:60")]
    with _group(corpus, dtype="f32b") as g:
        batch = g.search_plan_batch(plans, [None, None], now=NOW,
                                    ks=[20, 20])
        for plan, got in zip(plans, batch):
            want = vc.search_plan(plan, now=NOW, engine="fused-numpy")[:20]
            top = {int(i) for i, _ in got[:20]} & {int(i) for i, _ in want}
            assert len(top) >= 18


# -- replicas, stats, validation -----------------------------------------


def test_replicas_round_robin(corpus, emb):
    vc = _oracle(corpus, emb)
    with _group(corpus, replicas=2) as g:
        plan = _parse(vc, "similar:server lifecycle pool:60")
        want = vc.search_plan(plan, now=NOW, engine="fused-numpy")
        # consecutive searches hit alternating replicas; both exact
        assert g.search_plan(plan, now=NOW) == want
        assert g.search_plan(plan, now=NOW) == want
        # mutations fan to every replica
        g.delete([10, 11])
        vc.store.delete([10, 11])
        want = vc.search_plan(plan, now=NOW, engine="fused-numpy")
        assert g.search_plan(plan, now=NOW) == want
        assert g.search_plan(plan, now=NOW) == want
        st = g.stats()
        assert st["replicas"] == 2
        assert len(st["shards"]) == 6  # 3 shards x 2 replicas
        assert {s["replica"] for s in st["shards"]} == {0, 1}


def test_group_stats_shape(corpus, emb):
    vc = _oracle(corpus, emb)
    with _group(corpus) as g:
        plan = _parse(vc, "similar:server lifecycle pool:60")
        g.search_plan(plan, now=NOW)
        st = g.stats()
        assert st["n_shards"] == 3 and st["live"] == N
        assert st["searches"] == 1
        assert st["last_fanout_ms"] >= 0 and st["last_merge_ms"] >= 0
        rows = st["shards"]
        assert len(rows) == 3 and sum(s["live"] for s in rows) == N
        for s in rows:  # per-shard memory + latency ledger
            assert s["matrix_bytes"] > 0 and s["scoring_bytes"] > 0
            assert s["passes"] == 1 and s["last_pass_ms"] >= 0


def test_group_append_validation(corpus):
    with _group(corpus) as g:
        dup = np.array([5], dtype=np.int64)
        vec = np.ones((1, DIM), dtype=np.float32)
        with pytest.raises(ValueError, match="already live|duplicate"):
            g.append(dup, vec, [NOW])
        with pytest.raises(ValueError):
            g.append(np.array([9000, 9001]), np.ones((2, DIM), np.float32),
                     [NOW])  # misaligned timestamps
        with pytest.raises(ValueError):
            g.append(np.array([9000]), np.ones((1, 16), np.float32), [NOW])


def test_group_compact(corpus, emb):
    with _group(corpus) as g:
        g.delete(list(range(0, 240)))
        n = g.n_live
        folded = g.compact(min_live_fraction=0.9)
        assert folded == 3  # one fold per shard
        assert g.n_live == n
        st = g.stats()
        assert all(s["rows"] == s["live"] for s in st["shards"])


# -- serve-layer routing --------------------------------------------------


@pytest.fixture()
def service():
    import sqlite3

    from repro.data.corpus import build_database, generate_corpus
    from repro.serve.retrieval import RetrievalService

    e = HashEmbedder(DIM)
    chunks = generate_corpus(n_chunks=N, n_sessions=24, seed=11)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, e)
    svc = RetrievalService(conn, dim=DIM, embedder=e, now=NOW)
    yield svc
    svc.close()


SVC_TOKENS = [
    "similar:server lifecycle pool:50",
    "similar:session handling suppress:landing page decay:30 pool:64",
    "similar:retry logic diverse pool:48",
    "similar:cache keyword:server fuse:rrf pool:40",
]


def test_service_shard_group_routing(service):
    oracle = [service.search(t, k=20) for t in SVC_TOKENS]
    g = service.shard_group(n_shards=3, transport="inline")
    assert g is service.shard_group()  # idempotent attach
    for t, want in zip(SVC_TOKENS, oracle):
        assert service.search(t, k=20) == want, f"mismatch for {t!r}"
    st = service.stats()
    assert len(st["shard_group"]["shards"]) == 3
    service.close()
    assert service._shard_group is None


def test_service_shard_group_mutations(service):
    g = service.shard_group(n_shards=3, transport="inline")
    rows = [(10_000 + i, f"s{i % 4}", "text",
             f"fresh server lifecycle note {i}", NOW - i * 3600.0,
             i, "proj", None, None, None) for i in range(48)]
    service.ingest(rows)          # 16 rows/shard, block-aligned
    service.delete(list(range(0, 96, 2)))
    assert g.n_live == service.cache.store.n_live
    # group-routed search agrees with the group's own plan-level answer
    res = service.search(SVC_TOKENS[0], k=20)
    plan = _parse(service.cache, SVC_TOKENS[0])
    assert res == g.search_plan(plan, now=NOW, k=20)
    assert any(i >= 10_000 for i, _ in res)


def test_service_engine_fans_out_to_group(service):
    g = service.shard_group(n_shards=3, transport="inline")
    direct = [service.search(t, k=20) for t in SVC_TOKENS]
    eng = service.serving(max_batch=8, max_wait_ms=4.0)
    assert eng.shard_group is g
    with cf.ThreadPoolExecutor(8) as ex:
        batched = list(ex.map(lambda t: service.search(t, k=20),
                              SVC_TOKENS * 3))
    # id-level contract (panel-width GEMM low bits; see module docstring)
    for t, got, want in zip(SVC_TOKENS * 3, batched,
                            direct * 3):
        assert [i for i, _ in got] == [i for i, _ in want], \
            f"engine mismatch for {t!r}"
    assert eng.batches_served < 12  # batching actually batched
