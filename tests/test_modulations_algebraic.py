"""Algebraic correctness suite (paper §4.4 / Appendix A).

Each modulation's output must match its Table-1 formula to 1e-3. The paper
reports 1,840 comparisons across four corpora with zero mismatches; this
suite performs >= 1,840 comparisons across four synthetic corpora and
asserts zero mismatches, for BOTH execution engines (reference and fused).
"""

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.grammar import parse
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder

TOL = 1e-3
EMB = HashEmbedder(128)

CORPORA = {}
for name, (n, seed) in {
    "corpus_sci": (400, 1), "corpus_bio": (300, 2),
    "corpus_cs": (350, 3), "corpus_fin": (320, 4),
}.items():
    rng = np.random.default_rng(seed)
    texts = [f"topic {i % 23} term {rng.integers(100)} body {i}" for i in range(n)]
    mat = EMB.embed_batch(texts)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True) + 1e-12
    days = rng.uniform(0, 90, n).astype(np.float32)
    CORPORA[name] = (mat, days)

COMPARISONS = {"n": 0}


def _assert_scores(actual, expected):
    actual = np.asarray(actual)
    expected = np.asarray(expected)
    assert actual.shape == expected.shape
    mism = np.abs(actual - expected) > TOL
    assert not mism.any(), f"{mism.sum()} mismatches > {TOL}"
    COMPARISONS["n"] += actual.size


@pytest.fixture(params=sorted(CORPORA))
def corpus(request):
    return CORPORA[request.param]


@pytest.mark.parametrize("engine", ["reference", "fused"])
class TestFormulas:
    def test_suppress(self, corpus, engine):
        mat, days = corpus
        q = M.l2_normalize(EMB("query about systems"))
        x = M.l2_normalize(EMB("web design"))
        plan = M.ModulationPlan(query=q, suppress=(M.SuppressSpec(direction=x),))
        got = _run(mat, days, plan, engine)
        _assert_scores(got, mat @ q - 0.5 * (mat @ x))

    def test_multi_suppress(self, corpus, engine):
        mat, days = corpus
        q = M.l2_normalize(EMB("query"))
        xs = [M.l2_normalize(EMB(t)) for t in ("alpha beta", "gamma delta", "eps zeta")]
        plan = M.ModulationPlan(
            query=q,
            suppress=tuple(M.SuppressSpec(direction=x, weight=w)
                           for x, w in zip(xs, (0.5, 0.3, 0.7))),
        )
        expected = mat @ q
        for x, w in zip(xs, (0.5, 0.3, 0.7)):
            expected = expected - w * (mat @ x)
        _assert_scores(_run(mat, days, plan, engine), expected)

    def test_decay(self, corpus, engine):
        mat, days = corpus
        q = M.l2_normalize(EMB("temporal query"))
        plan = M.ModulationPlan(query=q, decay=M.DecaySpec(half_life_days=7.0))
        _assert_scores(_run(mat, days, plan, engine),
                       (mat @ q) * (1.0 / (1.0 + days / 7.0)))

    def test_trajectory(self, corpus, engine):
        mat, days = corpus
        q = M.l2_normalize(EMB("base query"))
        a = M.l2_normalize(EMB("prototype"))
        b = M.l2_normalize(EMB("production"))
        plan = M.ModulationPlan(query=q, trajectory=M.TrajectorySpec(direction=b - a))
        _assert_scores(_run(mat, days, plan, engine),
                       0.5 * (mat @ q) + 0.5 * (mat @ (b - a)))

    def test_centroid(self, corpus, engine):
        mat, days = corpus
        q = M.l2_normalize(EMB("anchored query"))
        ex = mat[:5]
        plan = M.ModulationPlan(query=q, centroid=M.CentroidSpec(examples=ex))
        qc = 0.5 * q + 0.5 * ex.mean(axis=0)
        qc = qc / np.linalg.norm(qc)
        _assert_scores(_run(mat, days, plan, engine), mat @ qc)

    def test_fixed_order_composition(self, corpus, engine):
        """decay applies BEFORE suppress (paper §3.3 fixed order)."""
        mat, days = corpus
        q = M.l2_normalize(EMB("compound query"))
        x = M.l2_normalize(EMB("suppress this"))
        a = M.l2_normalize(EMB("from a"))
        b = M.l2_normalize(EMB("to b"))
        plan = M.ModulationPlan(
            query=q,
            trajectory=M.TrajectorySpec(direction=b - a),
            decay=M.DecaySpec(half_life_days=30.0),
            suppress=(M.SuppressSpec(direction=x),),
        )
        expected = (0.5 * (mat @ q) + 0.5 * (mat @ (b - a)))
        expected = expected * (1.0 / (1.0 + days / 30.0))
        expected = expected - 0.5 * (mat @ x)
        _assert_scores(_run(mat, days, plan, engine), expected)


def _run(mat, days, plan, engine):
    if engine == "fused":
        return M.fused_modulate_scores(mat, days, plan)
    return M.modulate_scores(mat, days, plan)


def test_mmr_formula():
    """MMR selection follows score = lam*rel - (1-lam)*max_sim exactly."""
    rng = np.random.default_rng(0)
    for _ in range(4):
        e = rng.standard_normal((50, 16)).astype(np.float32)
        e /= np.linalg.norm(e, axis=1, keepdims=True)
        rel = rng.standard_normal(50).astype(np.float32)
        sel = M.mmr_select_np(e, rel, 10, lam=0.7)
        # brute-force oracle
        chosen, max_sim = [], np.full(50, -np.inf)
        for _i in range(10):
            mmr = 0.7 * rel - 0.3 * np.where(np.isneginf(max_sim), 0, max_sim)
            mmr[chosen] = -np.inf
            j = int(np.argmax(mmr))
            chosen.append(j)
            max_sim = np.maximum(max_sim, e @ e[j])
        assert list(sel) == chosen
        COMPARISONS["n"] += 10


def test_zzz_comparison_count():
    """Paper Appendix A: 1,840 comparisons, zero mismatches. We exceed it.
    (Named zzz_ to run after the suite under pytest's file ordering.)"""
    assert COMPARISONS["n"] >= 1840, COMPARISONS["n"]
