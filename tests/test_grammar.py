"""Token grammar parser (paper §3.4.2)."""

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.grammar import GrammarError, build_plan, parse, tokenize
from repro.embed import HashEmbedder

EMB = HashEmbedder(64)


def test_multiword_clauses():
    p = tokenize(
        "similar:how the system works architecture diverse "
        "suppress:website landing page design tagline "
        "suppress:documentation readme community post"
    )
    assert p.similar == "how the system works architecture"
    assert p.suppress == [
        "website landing page design tagline",
        "documentation readme community post",
    ]
    assert p.diverse


def test_any_order_same_plan():
    a = tokenize("similar:auth tokens diverse suppress:jwt decay:7 pool:100")
    b = tokenize("decay:7 pool:100 suppress:jwt similar:auth tokens diverse")
    assert a == b


def test_defaults():
    p = tokenize("similar:x")
    assert p.pool == M.DEFAULT_POOL and p.decay is None and not p.diverse
    plan = build_plan(p, EMB)
    assert plan.pool == 500 and plan.diverse is None


def test_bare_words_are_similar():
    p = tokenize("auth tokens diverse")
    assert p.similar == "auth tokens" and p.diverse


def test_decay_value_and_default():
    assert tokenize("similar:x decay:14").decay == 14.0
    assert tokenize("similar:x decay:").decay == M.DEFAULT_DECAY_HALF_LIFE


def test_centroid_ids():
    p = tokenize("similar:x centroid:3,5,9")
    assert p.centroid_ids == [3, 5, 9]


def test_from_to():
    p = tokenize("from:prototype idea to:production system")
    assert p.from_text == "prototype idea" and p.to_text == "production system"
    plan = build_plan(p, EMB)
    assert plan.trajectory is not None
    assert np.allclose(
        plan.trajectory.direction,
        M.l2_normalize(EMB("production system")) - M.l2_normalize(EMB("prototype idea")),
    )


@pytest.mark.parametrize("bad", [
    "",                       # no query at all
    "diverse",                # keyword only
    "similar:x decay:abc",    # non-numeric decay
    "similar:x decay:-5",     # negative half-life
    "similar:x pool:0",       # zero pool
    "similar:x centroid:a,b", # non-integer ids
    "from:a",                 # from without to
    "to:b",                   # to without from
    "suppress: similar:x",    # empty suppress text
])
def test_errors_are_explicit(bad):
    with pytest.raises(GrammarError):
        build_plan(tokenize(bad), EMB)


def test_plan_binding():
    plan = parse("similar:alpha suppress:beta suppress:gamma decay:3 diverse pool:42",
                 EMB)
    assert plan.pool == 42
    assert len(plan.suppress) == 2
    assert plan.decay.half_life_days == 3.0
    assert plan.diverse.lam == M.DEFAULT_MMR_LAMBDA
    assert plan.n_directions == 3
    assert abs(float(np.linalg.norm(plan.query)) - 1.0) < 1e-5
