"""Phase-1 filtered retrieval: masked-device path, router, engine threading.

The filtered-search invariants this suite pins:

1. **Masked == gather == oracle** — a pre-filtered search through the
   masked-device path (candidates ∧ live masked to -inf over the warm
   per-segment matrices) and through the gather-host path (scratch
   sub-corpus) are bit-identical to each other and to a monolithic
   host-gather oracle, on ALL five backends, for every segmentation ×
   tombstone/candidate overlap × decay × diverse combination.
2. **Router** — the selectivity threshold picks the path per query and
   the ``prefilter`` counters ledger every decision.
3. **Non-strict candidates** — ids deleted between the Phase-1 SQL and
   Phase-2 scoring (or never known, or duplicated) drop silently on BOTH
   router paths; an all-dead candidate set yields [] not an error.
4. **Engine threading** — ``candidate_ids`` flows through
   ``search``/``asearch``; filtered requests group by candidate set
   inside a batch and rank identically to the direct path.
5. **Zero per-query gather on the masked path** — a filtered query via
   the masked route performs no device upload on a warm store (pinned on
   the ``uploads`` counter) and never materializes the live view.
"""

import asyncio
import sqlite3
import threading
import time

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.backends import (JitJaxBackend, PrefilterRouter,
                                 FusedNumpyBackend, get_backend,
                                 list_backends, score_select_prefiltered,
                                 select_candidates)
from repro.core.segments import SegmentedCorpusStore
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder

BACKENDS = list_backends()
NOW = 90 * 86400.0
EMB = HashEmbedder(32)

MASKED = dict(mask_threshold=0.0)   # router kwargs forcing each path
GATHER = dict(mask_threshold=2.0)


def _corpus(n=230, d=32, seed=3):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    ts = NOW - days.astype(np.float64) * 86400.0
    return mat, ts


def _composed_plan(*, diverse=True, decay=True):
    q = M.l2_normalize(EMB("how the retrieval system works"))
    a = M.l2_normalize(EMB("prototype sketch"))
    b = M.l2_normalize(EMB("production deployment"))
    x1 = M.l2_normalize(EMB("website landing page"))
    return M.ModulationPlan(
        query=q,
        trajectory=M.TrajectorySpec(direction=b - a),
        decay=M.DecaySpec(half_life_days=14.0) if decay else None,
        suppress=(M.SuppressSpec(direction=x1),),
        diverse=M.DiverseSpec() if diverse else None,
        pool=25,
    )


def _store_from_splits(mat, ts, splits, deleted=()):
    store = SegmentedCorpusStore(dim=mat.shape[1])
    start = 0
    for size in splits:
        store.append(np.arange(start, start + size), mat[start:start + size],
                     ts[start:start + size], normalized=True)
        start += size
    assert start == mat.shape[0]
    if len(deleted):
        store.delete(deleted)
    return store


def _gather_oracle(mat, ts, deleted, candidate_ids, plan, k):
    """The monolithic host-gather reference: unique live candidate rows in
    ascending global-row order, scored by the reference formulation, then
    the shared top-k/MMR selection.  ids == arange here, so row == id."""
    rows = np.setdiff1d(np.unique(np.asarray(candidate_ids, dtype=np.int64)),
                        np.asarray(deleted, dtype=np.int64))
    rows = rows[rows < mat.shape[0]]  # unknown ids drop
    if rows.size == 0:
        return []
    days = ((NOW - ts[rows]) / 86400.0).astype(np.float32)
    scores = np.asarray(M.modulate_scores(mat[rows], days, plan))
    sel = select_candidates(mat[rows], scores, min(k, rows.size), plan)
    return [(int(rows[i]), float(scores[i])) for i in sel]


SEGMENTATIONS = [
    ("one-segment", [230], ()),
    ("three-segments", [100, 60, 70], tuple(range(40, 80)) + (150, 229)),
    ("ragged", [5, 120, 25, 60, 20], tuple(range(0, 230, 7))),
]

# candidate set deliberately overlapping tombstones, with duplicates and
# ids the store never saw
CANDIDATES = tuple(range(0, 230, 2)) + (41, 41, 151, 9999, 10_000)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize(
    "splits,deleted", [(s, d) for _, s, d in SEGMENTATIONS],
    ids=[name for name, _, _ in SEGMENTATIONS])
def test_filtered_search_matches_host_gather_oracle(backend, splits, deleted):
    """Both router paths == the monolithic host-gather oracle, through the
    full VectorCache search path (incl. decay + MMR finishing)."""
    mat, ts = _corpus()
    for diverse in (False, True):
        plan = _composed_plan(diverse=diverse)
        ref = _gather_oracle(mat, ts, deleted, CANDIDATES, plan, plan.pool)
        for kwargs in (MASKED, GATHER):
            store = _store_from_splits(mat, ts, splits, deleted)
            vc = VectorCache(store=store, embed_fn=EMB,
                             prefilter=PrefilterRouter(**kwargs))
            got = vc.search_plan(plan, CANDIDATES, now=NOW, engine=backend)
            assert [i for i, _ in got] == [i for i, _ in ref]
            np.testing.assert_allclose(
                [s for _, s in got], [s for _, s in ref],
                atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_filtered_no_decay_store_without_timestamps(backend):
    """Filtered search works on a timestamp-free store (non-decay plan)."""
    mat, _ = _corpus(seed=11)
    store = SegmentedCorpusStore(dim=32)
    store.append(np.arange(100), mat[:100], None, normalized=True)
    store.append(np.arange(100, 230), mat[100:], None, normalized=True)
    plan = _composed_plan(diverse=False, decay=False)
    cands = tuple(range(1, 230, 3))
    ref = _gather_oracle(mat, np.full(230, NOW), (), cands, plan, plan.pool)
    for kwargs in (MASKED, GATHER):
        vc = VectorCache(store=store, embed_fn=EMB,
                         prefilter=PrefilterRouter(**kwargs))
        got = vc.search_plan(plan, cands, now=NOW, engine=backend)
        assert [i for i, _ in got] == [i for i, _ in ref]


def test_router_selectivity_boundary():
    """The threshold is a >= boundary on unique-candidate count over live
    rows; every decision lands in the counters."""
    mat, ts = _corpus(n=200, seed=5)
    store = _store_from_splits(mat, ts, [200])
    router = PrefilterRouter(mask_threshold=0.3)
    vc = VectorCache(store=store, embed_fn=EMB, prefilter=router)
    plan = _composed_plan(diverse=False)

    vc.search_plan(plan, list(range(60)), now=NOW, engine="fused-numpy")
    assert (router.routed_masked, router.routed_gather) == (1, 0)
    assert router.mask_build_ms > 0.0

    built = router.mask_build_ms
    vc.search_plan(plan, list(range(59)), now=NOW, engine="fused-numpy")
    assert (router.routed_masked, router.routed_gather) == (1, 1)
    assert router.mask_build_ms == built  # gather path builds no mask

    # duplicates don't inflate selectivity: 59 unique ids stay gather
    vc.search_plan(plan, list(range(59)) * 3, now=NOW, engine="fused-numpy")
    assert (router.routed_masked, router.routed_gather) == (1, 2)

    # the full-corpus (unfiltered) path never consults the router
    vc.search_plan(plan, now=NOW, engine="fused-numpy")
    assert (router.routed_masked, router.routed_gather) == (1, 2)


@pytest.mark.parametrize("kwargs", [MASKED, GATHER],
                         ids=["masked", "gather"])
def test_candidates_deleted_between_phases_drop_silently(kwargs):
    """The concurrent-delete bugfix: ids tombstoned between the Phase-1
    SQL and Phase-2 scoring are non-strict on BOTH router paths — dropped,
    never raised; an entirely-dead candidate set yields []."""
    mat, ts = _corpus(seed=19)
    store = _store_from_splits(mat, ts, [120, 110])
    vc = VectorCache(store=store, embed_fn=EMB,
                     prefilter=PrefilterRouter(**kwargs))
    plan = _composed_plan()
    candidates = list(range(0, 230, 2))  # Phase-1 ran: these were live

    vc.delete(candidates[:30])           # ...then a concurrent delete won
    got = vc.search_plan(plan, candidates, now=NOW, engine="jit-jax")
    assert got, "surviving candidates must still rank"
    gone = set(candidates[:30])
    assert not gone & {i for i, _ in got}
    ref = _gather_oracle(mat, ts, candidates[:30], candidates, plan,
                         plan.pool)
    assert [i for i, _ in got] == [i for i, _ in ref]

    vc.delete(candidates)                # now the whole candidate set died
    assert vc.search_plan(plan, candidates, now=NOW, engine="jit-jax") == []


def test_masked_path_zero_gather_zero_upload_on_warm_store():
    """THE tentpole contract: a masked filtered query scores the warm
    device-resident segment matrices — no new upload, no plan retrace
    beyond the width bucket, and no live-view materialization."""
    mat, ts = _corpus(n=300, seed=23)
    be = JitJaxBackend()
    store = _store_from_splits(mat, ts, [200, 100])
    vc = VectorCache(store=store, embed_fn=EMB,
                     prefilter=PrefilterRouter(mask_threshold=0.0))
    plan = _composed_plan(diverse=False)

    for _ in range(2):  # warm: two uploads (one per segment)
        vc.search_plan(plan, now=NOW, engine=be)
    uploads = be.uploads
    traces = be.plan_cache.jax_traces
    assert vc._view is None  # the segmented pipeline never built a view

    for lo in (0, 10, 20):  # several distinct filters, same structure
        got = vc.search_plan(plan, list(range(lo, 300, 2)), now=NOW,
                             engine=be)
        assert got
    assert be.uploads == uploads          # zero per-query upload
    assert be.plan_cache.jax_traces == traces  # zero per-query retrace
    assert vc._view is None               # still no live view

    # the gather path, by contrast, uploads a scratch matrix every query
    vc.prefilter = PrefilterRouter(mask_threshold=2.0)
    vc.search_plan(plan, list(range(0, 300, 2)), now=NOW, engine=be)
    vc.search_plan(plan, list(range(0, 300, 2)), now=NOW, engine=be)
    assert be.uploads == uploads + 2


def test_engine_groups_filtered_requests_in_one_batch():
    """Mixed filtered/unfiltered requests collected into ONE batch: the
    heterogeneous-filter cohort rides a single (N, B) mask-panel scoring
    pass (unfiltered requests get all-live columns — the cohort never
    splits), and every request ranks exactly like the direct path."""

    class GateBackend(FusedNumpyBackend):
        name = "gate-prefilter"

        def __init__(self):
            self.release = threading.Event()
            self.entered = threading.Event()
            self.calls = 0

        def score_select(self, *args, **kwargs):
            self.calls += 1
            self.entered.set()
            if not self.release.wait(timeout=15.0):
                raise RuntimeError("gate never released (test bug)")
            return super().score_select(*args, **kwargs)

    from repro.serve.engine import BatchedRetrievalEngine

    emb = HashEmbedder(64)
    texts = [f"item group {i % 5} tail {i}" for i in range(150)]
    vc = VectorCache(np.arange(150), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 150), emb)
    gate = GateBackend()
    eng = BatchedRetrievalEngine(vc, max_batch=8, max_wait_ms=1.0, now=NOW,
                                 engine=gate)
    cand_a = list(range(0, 150, 2))
    cand_b = list(range(0, 150, 3))
    try:
        # park a dummy request inside the device stage, then enqueue the
        # real mix while it blocks — they collect into one batch
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(7) as ex:
            dummy = ex.submit(eng.search, "similar:group 0 tail", 3)
            assert gate.entered.wait(timeout=10.0)
            specs = [("similar:group 1 tail", cand_a),
                     ("similar:group 2 tail", cand_a),
                     ("similar:group 1 tail", list(cand_b)),
                     ("similar:group 3 tail", None),
                     ("similar:group 4 tail", None)]
            futs = [ex.submit(eng.search, q, 5, 20.0, candidate_ids=c)
                    for q, c in specs]
            while eng.queue_depth < len(specs):
                time.sleep(0.005)
            routed_before = (vc.prefilter.routed_masked
                             + vc.prefilter.routed_gather)
            panel_before = vc.prefilter.routed_panel
            panel_batches_before = vc.fused.panel_batches
            gate.release.set()
            dummy.result(20.0)
            results = [f.result(20.0) for f in futs]
        assert eng.batches_served == 2  # dummy, then the 5-request batch
        # the whole 5-request heterogeneous cohort (cand_a x2, cand_b,
        # unfiltered x2) routed through ONE mask-panel pass: per-query
        # panel counter +5, nothing on the per-filter routes
        assert vc.prefilter.routed_panel - panel_before == 5
        assert (vc.prefilter.routed_masked + vc.prefilter.routed_gather
                - routed_before) == 0
        assert vc.fused.panel_batches - panel_batches_before == 1
        # ...and the scoring passes folded all the way down: dummy + one
        # panel pass over the one-segment store = 2 backend calls
        assert gate.calls == 2
        for (q, c), got in zip(specs, results):
            direct = vc.search(q, c, now=NOW, engine="fused-numpy")[:5]
            assert [i for i, _ in got] == [i for i, _ in direct], q
    finally:
        eng.close()


def test_asearch_threads_candidate_ids():
    emb = HashEmbedder(64)
    texts = [f"doc topic {i % 7} body {i}" for i in range(90)]
    vc = VectorCache(np.arange(90), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 90), emb)
    from repro.serve.engine import BatchedRetrievalEngine

    eng = BatchedRetrievalEngine(vc, max_batch=4, now=NOW)
    cand = list(range(0, 90, 2))
    try:
        got = asyncio.run(eng.asearch("similar:doc topic 3 body", 6,
                                      candidate_ids=cand))
        direct = vc.search("similar:doc topic 3 body", cand, now=NOW)[:6]
        assert [i for i, _ in got] == [i for i, _ in direct]
        assert all(i % 2 == 0 for i, _ in got)
    finally:
        eng.close()


def test_materializer_prefilter_routes_through_serving_engine():
    """With a serving engine attached, vec_ops (pre-filtered included)
    batches through it — same rows as the direct materializer, and the
    router counters show up in service stats."""
    from repro.data.corpus import build_database, generate_corpus
    from repro.serve.retrieval import RetrievalService

    emb = HashEmbedder(64)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, generate_corpus(n_chunks=200, n_sessions=10,
                                         seed=9), emb)
    svc = RetrievalService(conn, dim=64, embedder=emb,
                           now=1_770_000_000.0, engine="fused")
    q = ("SELECT v.id, v.score FROM vec_ops("
         "'similar:server lifecycle pool:20',"
         "'SELECT id FROM chunks WHERE type = ''assistant''') v "
         "ORDER BY v.score DESC LIMIT 5")
    direct = svc.flex_search(q)
    assert direct.ok, direct.error
    svc.serving(max_batch=8)

    embed_calls = []
    inner = svc.cache.embed_fn

    def counting_embed(text):
        embed_calls.append(text)
        return inner(text)

    svc.cache.embed_fn = counting_embed
    try:
        batched = svc.flex_search(q)
        assert batched.ok, batched.error
        assert batched.rows == direct.rows
        # the parsed plan is handed to the engine: ONE parse (one
        # similar: embed) per query, not one per layer
        assert len(embed_calls) == 1, embed_calls
        stats = svc.stats()
        assert stats["prefilter"]["routed_masked"] + \
            stats["prefilter"]["routed_gather"] >= 2
        assert stats["serving"]["requests_served"] >= 1
    finally:
        svc.cache.embed_fn = inner
        svc.close()


def test_search_full_structural_tail_without_live_view():
    """The structural operators gather their <=pool rows off the store's
    id index — a filtered structural query on a multi-segment store never
    materializes the full live-view matrix."""
    mat, ts = _corpus(seed=29)
    store = _store_from_splits(mat, ts, [100, 130], deleted=(3, 104))
    vc = VectorCache(store=store, embed_fn=EMB)
    cands = [i for i in range(0, 230, 2)]
    cols, rows = vc.search_full(
        "similar:how the retrieval system works cluster:3 central pool:12",
        cands, now=NOW, engine="jit-jax")
    assert cols == ["id", "score", "cluster", "central"]
    assert rows and all(len(r) == 4 for r in rows)
    assert all(int(r[0]) % 2 == 0 for r in rows)
    assert vc._view is None  # satellite: no full-matrix materialization


def test_prefiltered_driver_empty_and_unknown_sets():
    mat, ts = _corpus(n=50, seed=31)
    store = _store_from_splits(mat, ts, [50])
    plan = _composed_plan(diverse=False)
    for kwargs in (MASKED, GATHER):
        router = PrefilterRouter(**kwargs)
        out = score_select_prefiltered(
            get_backend("fused-numpy"), store, store.segments,
            [plan], [10], [], now=NOW, router=router)
        assert [o[0].size for o in out] == [0]
        out = score_select_prefiltered(
            get_backend("fused-numpy"), store, store.segments,
            [plan], [10], [777, 888], now=NOW, router=router)
        assert [o[0].size for o in out] == [0]


# ---------------------------------------------------------------------------
# adaptive threshold: the crossover learned from the router's own samples
# ---------------------------------------------------------------------------


def test_adaptive_threshold_static_until_both_arms_warm():
    r = PrefilterRouter(mask_threshold=0.25, min_samples=3)
    assert r.effective_threshold() == 0.25
    for _ in range(3):
        r.record_masked(10.0, 100_000)   # a = 1e-4 ms per live row
    assert r.effective_threshold() == 0.25   # gather arm still cold
    for _ in range(2):
        r.record_gather(1.0, 1_000)      # b = 1e-3 ms per candidate
    assert r.effective_threshold() == 0.25   # 2 < min_samples
    r.record_gather(1.0, 1_000)
    # both arms warm: crossover a/b = 0.1 replaces the static seed,
    # and the >= routing boundary moves with it
    assert abs(r.effective_threshold() - 0.1) < 1e-12
    assert r.use_masked(10_000, 100_000)
    assert not r.use_masked(9_999, 100_000)
    st = r.stats()
    assert st["threshold"] == 0.25
    assert st["threshold_effective"] == 0.1
    assert st["masked_samples"] == 3 and st["gather_samples"] == 3


def test_adaptive_threshold_clamps_and_opt_out():
    hi = PrefilterRouter(min_samples=1)
    hi.record_masked(100.0, 100)         # masked terrible: 1 ms/live row
    hi.record_gather(0.001, 10_000)
    assert hi.effective_threshold() == 0.9   # clamped: never all-gather
    lo = PrefilterRouter(min_samples=1)
    lo.record_masked(0.0001, 1_000_000)  # masked nearly free
    lo.record_gather(100.0, 10)
    assert lo.effective_threshold() == 0.01  # clamped: never all-masked
    off = PrefilterRouter(adaptive=False, min_samples=1)
    off.record_masked(100.0, 100)
    off.record_gather(0.001, 10_000)
    assert off.effective_threshold() == off.mask_threshold
    # degenerate samples are ignored, not folded into the model
    z = PrefilterRouter(min_samples=1)
    z.record_masked(1.0, 0)
    z.record_gather(-1.0, 100)
    assert z.masked_samples == 0 and z.gather_samples == 0


def test_prefiltered_passes_record_timing_samples():
    """Both router arms feed the adaptive model from the REAL driver:
    the masked arm records live rows swept, the gather arm candidates."""
    mat, ts = _corpus(n=200, seed=9)
    store = _store_from_splits(mat, ts, [200])
    router = PrefilterRouter(mask_threshold=0.3)
    vc = VectorCache(store=store, embed_fn=EMB, prefilter=router)
    plan = _composed_plan(diverse=False)
    vc.search_plan(plan, list(range(100)), now=NOW, engine="fused-numpy")
    assert router.masked_samples == 1 and router.masked_rows == 200
    assert router.masked_ms > 0.0
    vc.search_plan(plan, list(range(10)), now=NOW, engine="fused-numpy")
    assert router.gather_samples == 1 and router.gather_rows == 10
    assert router.gather_ms > 0.0
    # empty early-returns record nothing (no cost model pollution)
    score_select_prefiltered(
        get_backend("fused-numpy"), store, store.segments, [plan], [10],
        [777_777], now=NOW, router=router)
    assert router.gather_samples == 1 and router.masked_samples == 1
