"""Roofline machinery: HLO collective-byte parsing + three-term math."""

import numpy as np

from repro.roofline.analysis import (
    HW,
    RooflineReport,
    analyze,
    collective_bytes_from_hlo,
)

HLO_SAMPLE = """
HloModule jit_step
  %ar = f32[1024,128]{1,0} all-reduce(f32[1024,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[2048,64]{1,0} all-gather(bf16[128,64]{1,0} %y), dimensions={0}
  %aa = f32[16,256]{1,0} all-to-all(f32[16,256]{1,0} %z), dimensions={1}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %w), source_target_pairs={{0,1}}
  %rs = f32[64,64]{1,0} reduce-scatter(f32[512,64]{1,0} %v), dimensions={0}
  %dot = f32[64,64]{1,0} dot(f32[64,128]{1,0} %a, f32[128,64]{1,0} %b)
  %ar2 = f32[100]{0} all-reduce-done(f32[100]{0} %h)
"""


def test_collective_parse():
    total, per_op = collective_bytes_from_hlo(HLO_SAMPLE)
    expect = {
        "all-reduce": 1024 * 128 * 4,
        "all-gather": 128 * 64 * 2,
        "all-to-all": 16 * 256 * 4,
        "collective-permute": 8 * 4,
        "reduce-scatter": 512 * 64 * 4,
    }
    for op, b in expect.items():
        assert per_op[op] == b, (op, per_op.get(op), b)
    assert total == sum(expect.values())


def test_collective_parse_ignores_dots_and_done():
    total, per_op = collective_bytes_from_hlo(
        "%dot = f32[4096,4096]{1,0} dot(f32[4096,128]{1,0} %a, f32[128,4096]{1,0} %b)")
    assert total == 0 and per_op == {}


def test_three_terms_and_bottleneck():
    rep = RooflineReport(
        arch="x", shape="y", mesh="16x16", chips=256,
        hlo_flops=256 * HW.peak_flops,        # exactly 1s of compute
        hlo_bytes=256 * HW.hbm_bw * 0.5,      # 0.5s of memory
        collective_bytes=256 * HW.link_bw * 0.25,
        collective_by_op={}, model_flops=256 * HW.peak_flops * 0.8,
    )
    assert abs(rep.t_compute - 1.0) < 1e-9
    assert abs(rep.t_memory - 0.5) < 1e-9
    assert abs(rep.t_collective - 0.25) < 1e-9
    assert rep.bottleneck == "compute"
    assert abs(rep.useful_flops_ratio - 0.8) < 1e-9
    assert abs(rep.roofline_fraction - 0.8) < 1e-9


def test_analyze_scales_per_device_to_fleet():
    rep = analyze("a", "s", "16x16", 256, {"flops": 10.0, "bytes accessed": 20.0},
                  HLO_SAMPLE, model_flops=1000.0)
    assert rep.hlo_flops == 10.0 * 256
    assert rep.hlo_bytes == 20.0 * 256
    assert rep.collective_bytes > 0


def test_report_rendering(tmp_path):
    import json

    from repro.roofline.report import load_cells, roofline_table

    cell = analyze("a", "s", "16x16", 256, {"flops": 1e9, "bytes accessed": 1e9},
                   "", model_flops=1e11).to_dict()
    cell.update({"rules": "default", "compile_s": 1.0})
    (tmp_path / "a__s__16x16.json").write_text(json.dumps(cell))
    cells = load_cells(tmp_path)
    assert len(cells) == 1
    table = roofline_table(cells)
    assert "| a | s |" in table and "compute" in table or "memory" in table
