"""Continuous-batching LM decode engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import default_rules
from repro.models import transformer as T
from repro.models.layers import LMConfig
from repro.serve.lm_engine import DecodeRequest, LMDecodeEngine


def _engine(n_slots=3, max_ctx=48):
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32,
                   q_chunk=16, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    params = T.init_params(cfg, jax.random.key(0))
    return mesh, cfg, rules, params, LMDecodeEngine(
        cfg, params, rules, n_slots=n_slots, max_ctx=max_ctx)


def test_continuous_batching_serves_more_requests_than_slots():
    mesh, cfg, rules, params, eng = _engine(n_slots=2)
    rng = np.random.default_rng(0)
    reqs = [DecodeRequest(prompt=rng.integers(0, 64, 5).astype(np.int32),
                          max_new_tokens=4) for _ in range(5)]
    with mesh:
        stats = eng.run(reqs)
    assert stats["requests"] == 5            # 5 requests through 2 slots
    assert all(r.done for r in reqs)
    # prefill emits 1 token, then max_new_tokens decode steps
    for r in reqs:
        assert len(r.tokens) == 1 + 4
    assert 1.0 <= stats["mean_occupancy"] <= 2.0


def test_engine_matches_sequential_decode():
    """Tokens from the slot engine == naive one-request-at-a-time decode."""
    mesh, cfg, rules, params, eng = _engine(n_slots=2)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, 6).astype(np.int32) for _ in range(2)]
    reqs = [DecodeRequest(prompt=p, max_new_tokens=3) for p in prompts]
    with mesh:
        eng.run(list(reqs))

        for p, r in zip(prompts, reqs):
            logits, cache = T.prefill_step(params, jnp.asarray(p[None]), cfg, rules)
            big = T.make_cache(cfg, 1, 48)
            big = tuple(jax.lax.dynamic_update_slice(b, c, (0, 0, 0, 0, 0))
                        for b, c in zip(big, cache))
            toks = [int(jnp.argmax(logits[0]))]
            ln = len(p)
            for _ in range(3):
                lg, big = T.decode_step(
                    params, jnp.asarray([[toks[-1]]], jnp.int32), big,
                    jnp.int32(ln), cfg, rules)
                toks.append(int(jnp.argmax(lg[0])))
                ln += 1
            assert r.tokens == toks, (r.tokens, toks)


def test_eos_frees_slot_early():
    mesh, cfg, rules, params, eng = _engine(n_slots=1)
    rng = np.random.default_rng(2)
    # find which token the model emits first, use it as EOS for req 1
    probe = DecodeRequest(prompt=rng.integers(0, 64, 4).astype(np.int32),
                          max_new_tokens=2)
    with mesh:
        eng.run([probe])
        eos = probe.tokens[1]
        req = DecodeRequest(prompt=probe.prompt.copy(), max_new_tokens=8,
                            eos_id=eos)
        stats = eng.run([req])
    assert req.done
    assert len(req.tokens) < 1 + 8            # stopped early on EOS
