"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement §f)."""

import math

import pytest

from repro.configs import ASSIGNED, REGISTRY, get_arch


@pytest.mark.parametrize("arch_id", ASSIGNED + ["flexvec"])
def test_arch_smoke(arch_id):
    out = get_arch(arch_id).smoke_run()
    assert math.isfinite(out["loss"]), (arch_id, out)
    if "grad_norm" in out:
        assert math.isfinite(out["grad_norm"])
    if "grad_finite" in out:
        assert out["grad_finite"]
    if "logits_shape" in out:                 # LM family
        assert out["logits_shape"] == (2, out["vocab"])
        assert out["decode_shape"] == (2, out["vocab"])
    if "graph_logits_shape" in out:           # PNA graph task
        assert out["graph_logits_shape"] == (8, 5)
    if "idx_shape" in out:                    # flexvec retrieval
        assert out["idx_shape"] == (2, 8)
        assert out["val_finite"]


def test_registry_covers_assignment():
    assert set(ASSIGNED) <= set(REGISTRY)
    assert len(ASSIGNED) == 10
    for aid in ASSIGNED:
        arch = get_arch(aid)
        assert len(arch.cells()) == 4, aid    # 4 shapes per assigned arch


def test_cells_have_sources():
    for aid in ASSIGNED:
        assert get_arch(aid).source


def test_long_500k_skip_annotation():
    """Full-attention LM archs must carry the long_500k skip note
    (DESIGN.md §3.5) while still lowering it as a beyond-assignment cell."""
    for aid in ["granite-34b", "minitron-4b", "internlm2-1.8b",
                "granite-moe-1b-a400m", "qwen3-moe-235b-a22b"]:
        cell = get_arch(aid).cells()["long_500k"]
        assert cell.skip_reason and "full" in cell.skip_reason
        assert cell.beyond_assignment
