"""Production mesh construction + a minimal 512-device lowering, in a
subprocess so the device-count flag never leaks into the test process."""

import subprocess
import sys
import textwrap


def test_production_mesh_512_devices_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_production_mesh

        single = make_production_mesh()
        assert single.devices.shape == (16, 16)
        assert single.axis_names == ("data", "model")
        multi = make_production_mesh(multi_pod=True)
        assert multi.devices.shape == (2, 16, 16)
        assert multi.axis_names == ("pod", "data", "model")

        # minimal sharded lowering on the multi-pod mesh
        x = jax.ShapeDtypeStruct((512, 256), jnp.float32,
                                 sharding=NamedSharding(multi, P(("pod", "data"), "model")))
        w = jax.ShapeDtypeStruct((256, 128), jnp.float32,
                                 sharding=NamedSharding(multi, P("model", None)))
        with multi:
            compiled = jax.jit(lambda x, w: x @ w).lower(x, w).compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        assert dict(ca).get("flops", 0) > 0
        print("MESH_OK", jax.device_count())
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH_OK 512" in r.stdout


def test_mesh_import_does_not_touch_devices():
    # importing mesh.py must not initialize jax devices (module has no
    # module-level mesh constants)
    import repro.launch.mesh as m

    assert callable(m.make_production_mesh)
