import os

# Tests must see the real single CPU device — the 512-device flag belongs
# ONLY to launch/dryrun.py (never set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
