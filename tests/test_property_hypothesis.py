"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import modulations as M
from repro.core.grammar import tokenize
from repro.metrics.ranking import ils, ndcg_at_k, rbo

SET = settings(max_examples=40, deadline=None)

vecs = hnp.arrays(np.float32, st.integers(8, 64),
                  elements=st.floats(-5, 5, width=32)).filter(
    lambda v: np.linalg.norm(v) > 1e-3)


@SET
@given(vecs)
def test_l2_normalize_unit_and_idempotent(v):
    n1 = np.asarray(M.l2_normalize(v))
    assert abs(np.linalg.norm(n1) - 1.0) < 1e-4
    np.testing.assert_allclose(np.asarray(M.l2_normalize(n1)), n1, atol=1e-5)


def _corpus_and_plan(draw):
    d = draw(st.sampled_from([16, 32]))
    n = draw(st.integers(20, 120))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0, 90, n).astype(np.float32)
    q = mat[0] + 0.1 * rng.standard_normal(d).astype(np.float32)
    q = np.asarray(M.l2_normalize(q))
    n_sup = draw(st.integers(0, 3))
    sups = tuple(
        M.SuppressSpec(
            direction=np.asarray(M.l2_normalize(
                rng.standard_normal(d).astype(np.float32))),
            weight=draw(st.floats(0.1, 1.0)),
        ) for _ in range(n_sup)
    )
    traj = None
    if draw(st.booleans()):
        traj = M.TrajectorySpec(direction=np.asarray(M.l2_normalize(
            rng.standard_normal(d).astype(np.float32))))
    decay = M.DecaySpec(draw(st.floats(1.0, 60.0))) if draw(st.booleans()) else None
    plan = M.ModulationPlan(query=q, trajectory=traj, decay=decay, suppress=sups)
    return mat, days, plan


plans = st.composite(_corpus_and_plan)()


@SET
@given(plans)
def test_fused_equals_reference_for_any_plan(args):
    """The one-GEMM folded execution == the paper's sequential pipeline,
    for arbitrary modulation combinations (composability invariant)."""
    mat, days, plan = args
    ref = np.asarray(M.modulate_scores(mat, days, plan))
    fused = np.asarray(M.fused_modulate_scores(mat, days, plan))
    np.testing.assert_allclose(fused, ref, atol=1e-4)


@SET
@given(plans)
def test_suppress_stacks_additively(args):
    mat, days, plan = args
    if not plan.suppress:
        return
    base = M.ModulationPlan(query=plan.query, trajectory=plan.trajectory,
                            decay=plan.decay, suppress=())
    s0 = np.asarray(M.modulate_scores(mat, days, base))
    s1 = np.asarray(M.modulate_scores(mat, days, plan))
    manual = s0.copy()
    for spec in plan.suppress:
        manual -= spec.weight * (mat @ spec.direction)
    np.testing.assert_allclose(s1, manual, atol=1e-4)


@SET
@given(st.integers(0, 10_000), st.floats(1.0, 60.0))
def test_decay_monotone_in_age(seed, hl):
    rng = np.random.default_rng(seed)
    days = np.sort(rng.uniform(0, 120, 50)).astype(np.float32)
    s = np.ones(50, np.float32)
    out = np.asarray(M.apply_decay(s, days, M.DecaySpec(hl)))
    assert (np.diff(out) <= 1e-7).all()          # older -> never higher
    assert (out > 0).all() and (out <= 1.0).all()


@SET
@given(st.integers(0, 2**31 - 1), st.integers(2, 30), st.integers(31, 80))
def test_mmr_invariants(seed, k, n):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((n, 16)).astype(np.float32)
    e /= np.linalg.norm(e, axis=1, keepdims=True)
    rel = rng.standard_normal(n).astype(np.float32)
    sel = M.mmr_select_np(e, rel, k)
    assert len(sel) == k
    assert len(set(sel.tolist())) == k            # no duplicates
    assert (sel >= 0).all() and (sel < n).all()   # within pool
    assert sel[0] == int(np.argmax(rel))          # first pick = pure relevance


@SET
@given(st.lists(st.integers(0, 50), min_size=1, max_size=25, unique=True),
       st.lists(st.integers(0, 50), min_size=1, max_size=25, unique=True))
def test_rbo_bounds_and_identity(a, b):
    r = rbo(a, b)
    assert -1e-9 <= r <= 1.0 + 1e-9
    assert abs(rbo(a, a) - 1.0) < 1e-9
    assert abs(rbo(a, b) - rbo(b, a)) < 1e-9      # symmetry


@SET
@given(st.integers(0, 10_000))
def test_ils_bounds(seed):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((10, 8)).astype(np.float32)
    v = ils(e)
    assert -1.0 - 1e-6 <= v <= 1.0 + 1e-6
    same = np.tile(e[:1], (5, 1))
    assert ils(same) > 0.999                      # duplicates -> max ILS


@SET
@given(st.integers(0, 10_000))
def test_ndcg_perfect_ranking_is_one(seed):
    rng = np.random.default_rng(seed)
    docs = list(range(20))
    qrels = {d: int(rng.integers(0, 3)) for d in docs}
    if not any(qrels.values()):
        qrels[0] = 1
    ranked = sorted(docs, key=lambda d: -qrels[d])
    assert abs(ndcg_at_k(ranked, qrels, 10) - 1.0) < 1e-9
    assert 0.0 <= ndcg_at_k(list(rng.permutation(docs)), qrels, 10) <= 1.0


@SET
@given(st.permutations(["similar:alpha beta", "decay:7", "suppress:gamma delta",
                        "diverse", "pool:50"]))
def test_token_order_irrelevant(parts):
    """Tokens in any order produce the identical parse (paper §3.4.2)."""
    p = tokenize(" ".join(parts))
    q = tokenize("similar:alpha beta decay:7 suppress:gamma delta diverse pool:50")
    assert p == q
