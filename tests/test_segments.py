"""Segmented corpus store: equivalence, delta-ingest, and cache contracts.

The storage-refactor invariants:

1. **Segment equivalence** — any segmentation of the corpus (1, 2, 7
   segments; with/without tombstones; an all-tombstoned segment; an empty
   append) produces bit-identical candidate ids — and scores to 1e-5 —
   to the monolithic reference oracle over the live rows, on ALL five
   backends, including the diverse/MMR finishing path.
2. **Delta ingest is delta-cost** — appending a segment to a warm store
   uploads and traces ONLY the new segment (pinned via the device-matrix
   ``uploads`` counter and ``PlanCache.jax_traces``).
3. Store mechanics: append/delete/compact, the id index, the live view,
   and the engine/materializer/service threading of ingest + delete.
"""

import sqlite3

import numpy as np
import pytest

from repro.core import modulations as M
from repro.core.backends import (JitJaxBackend, get_backend, list_backends,
                                 score_select_segments)
from repro.core.segments import (SegmentedCorpusStore, gather_ids,
                                 gather_rows)
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder

BACKENDS = list_backends()
NOW = 90 * 86400.0
EMB = HashEmbedder(32)


def _corpus(n=230, d=32, seed=3):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    ts = NOW - days.astype(np.float64) * 86400.0
    return mat, ts


def _composed_plan(mat, *, diverse=True, decay=True):
    q = M.l2_normalize(EMB("how the retrieval system works"))
    a = M.l2_normalize(EMB("prototype sketch"))
    b = M.l2_normalize(EMB("production deployment"))
    x1 = M.l2_normalize(EMB("website landing page"))
    return M.ModulationPlan(
        query=q,
        trajectory=M.TrajectorySpec(direction=b - a),
        decay=M.DecaySpec(half_life_days=14.0) if decay else None,
        suppress=(M.SuppressSpec(direction=x1),),
        diverse=M.DiverseSpec() if diverse else None,
        pool=25,
    )


def _store_from_splits(mat, ts, splits, deleted=()):
    """Build a store by appending `splits` row-ranges, then tombstoning."""
    store = SegmentedCorpusStore(dim=mat.shape[1])
    start = 0
    for size in splits:
        store.append(np.arange(start, start + size), mat[start:start + size],
                     ts[start:start + size], normalized=True)
        start += size
    assert start == mat.shape[0]
    if len(deleted):
        store.delete(deleted)
    return store


SEGMENTATIONS = [
    ("one-segment", [230], ()),
    ("two-segments", [150, 80], ()),
    ("seven-segments", [40, 40, 40, 40, 40, 20, 10], ()),
    ("tombstones", [150, 80], tuple(range(10, 60)) + (200, 229)),
    ("all-dead-middle-segment", [100, 30, 100],
     tuple(range(100, 130)) + (5, 140)),
    ("tombstones-seven", [40, 40, 40, 40, 40, 20, 10],
     tuple(range(0, 230, 3))),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize(
    "splits,deleted", [(s, d) for _, s, d in SEGMENTATIONS],
    ids=[name for name, _, _ in SEGMENTATIONS])
def test_segmented_search_matches_monolithic_oracle(backend, splits, deleted):
    """Any segmentation == the monolithic reference oracle on live rows,
    through the full VectorCache search path (incl. MMR finishing)."""
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, splits, deleted)
    vc = VectorCache(store=store, embed_fn=EMB)

    live = np.setdiff1d(np.arange(mat.shape[0]), np.asarray(deleted, int))
    mono = VectorCache(live, mat[live], ts[live], EMB, normalized=True)

    for diverse in (False, True):
        plan = _composed_plan(mat, diverse=diverse)
        ref = mono.search_plan(plan, now=NOW, engine="reference-numpy")
        got = vc.search_plan(plan, now=NOW, engine=backend)
        assert [i for i, _ in got] == [i for i, _ in ref]
        np.testing.assert_allclose([s for _, s in got], [s for _, s in ref],
                                   atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_batch_mixed_k(backend):
    """The raw driver: mixed plans + per-request k over a tombstoned
    3-segment store match per-plan monolithic oracles."""
    mat, ts = _corpus(seed=11)
    deleted = tuple(range(60, 90))
    store = _store_from_splits(mat, ts, [100, 60, 70], deleted)
    segs = store.segments
    live = np.setdiff1d(np.arange(mat.shape[0]), np.asarray(deleted, int))
    days = ((NOW - ts) / 86400.0).astype(np.float32)

    plans = [_composed_plan(mat, diverse=False),
             _composed_plan(mat, diverse=False, decay=False)]
    ks = [7, 31]
    got = score_select_segments(backend, segs, plans, ks, now=NOW)
    assert len(got) == 2
    for (gidx, vals), plan, k in zip(got, plans, ks):
        oracle = np.asarray(M.modulate_scores(mat[live], days[live], plan))
        order = np.argsort(-oracle, kind="stable")[:k]
        # global rows == original row ids here (ids = arange, no offsets
        # shifted by deletes), so compare via gathered ids
        assert list(gather_ids(segs, gidx)) == list(live[order])
        np.testing.assert_allclose(vals, oracle[order], atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(
            gather_rows(segs, gidx), mat[live[order]], atol=0, rtol=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_append_and_all_tombstoned_store(backend):
    mat, ts = _corpus(seed=17)
    store = _store_from_splits(mat, ts, [230])
    # an empty append is a no-op: no segment, no version bump
    v = store.version
    assert store.append([], np.zeros((0, 32), np.float32), []) is None
    assert store.version == v and store.n_segments == 1
    # a fully-tombstoned store returns empty results, not an error
    store.delete(range(230))
    vc = VectorCache(store=store, embed_fn=EMB)
    assert vc.search_plan(_composed_plan(mat), now=NOW, engine=backend) == []


def test_delta_append_uploads_and_traces_only_the_new_segment():
    """THE delta-ingest contract: append to a warm store re-uploads and
    retraces only the new segment; the hot segment stays warm."""
    mat, ts = _corpus(n=300, seed=23)
    be = JitJaxBackend()
    store = _store_from_splits(mat[:260], ts[:260], [260])
    vc = VectorCache(store=store, embed_fn=EMB)
    plan = _composed_plan(mat, diverse=False)

    for _ in range(2):  # warm the store: one upload, one trace
        vc.search_plan(plan, now=NOW, engine=be)
    assert be.uploads == 1
    assert be.plan_cache.jax_traces == 1

    # append 40 chunks -> one NEW upload (the delta), one NEW trace (a
    # genuinely new row bucket: 64 vs 512); the 260-row segment's device
    # copy and compiled executable are untouched
    vc.ingest(np.arange(260, 300), mat[260:300], ts[260:300],
              normalized=True)
    vc.search_plan(plan, now=NOW, engine=be)
    assert be.uploads == 2
    assert be.plan_cache.jax_traces == 2

    # steady state: queries on the 2-segment store hit everything warm
    vc.search_plan(plan, now=NOW, engine=be)
    assert be.uploads == 2
    assert be.plan_cache.jax_traces == 2
    assert be.device_cache_stats()["entries"] == 2

    # deletes flip tombstones only: no upload, no retrace
    vc.delete(np.arange(260, 280))
    vc.search_plan(plan, now=NOW, engine=be)
    assert be.uploads == 2
    assert be.plan_cache.jax_traces == 2

    # compaction rewrites the half-dead segment (20 live rows -> the 32
    # bucket: one trace for the genuinely new shape), then stays warm
    store.compact(0.9)
    vc.search_plan(plan, now=NOW, engine=be)
    assert be.plan_cache.jax_traces == 3
    vc.search_plan(plan, now=NOW, engine=be)
    assert be.plan_cache.jax_traces == 3
    # the 260-row segment NEVER re-uploaded through any of this
    assert be.uploads == 3  # base + delta + compacted


# ---------------------------------------------------------------------------
# Store mechanics
# ---------------------------------------------------------------------------


def test_store_append_delete_compact_index():
    mat, ts = _corpus(n=100, seed=5)
    store = _store_from_splits(mat, ts, [60, 40])
    assert store.n_rows == 100 and store.n_live == 100
    assert 17 in store and 99 in store

    assert store.delete([10, 11, 99]) == 3
    assert store.n_live == 97 and 99 not in store
    # deleting again is a no-op (not an error) unless strict
    assert store.delete([10]) == 0
    with pytest.raises(KeyError, match="not live"):
        store.delete([10], strict=True)

    # duplicate live ids are rejected; re-appending a tombstoned id is OK
    with pytest.raises(ValueError, match="already live"):
        store.append([17], mat[:1], ts[:1])
    store.append([10], mat[10:11], ts[10:11], normalized=True)
    assert 10 in store and store.n_live == 98

    # compact: segments below the live fraction merge, dead rows drop
    segs_before = store.n_segments
    assert segs_before == 3
    compacted = store.compact(1.0)  # everything with any tombstone
    assert compacted == 2
    assert store.n_rows == store.n_live == 98
    assert store.n_segments == 2
    # the index survives compaction
    np.testing.assert_allclose(
        store.embedding_for_id(10), mat[10] / np.linalg.norm(mat[10]),
        atol=1e-6)

    stats = store.stats()
    assert stats["segments"] == 2 and stats["compactions"] == 1


def test_store_timestamp_consistency_and_dim_checks():
    store = SegmentedCorpusStore(dim=8)
    store.append([1, 2], np.eye(8, dtype=np.float32)[:2], [1.0, 2.0])
    with pytest.raises(ValueError, match="timestamp presence"):
        store.append([3], np.eye(8, dtype=np.float32)[:1], None)
    with pytest.raises(ValueError, match="dim"):
        store.append([3], np.ones((1, 4), np.float32), [3.0])
    with pytest.raises(ValueError, match="inconsistent"):
        store.append([3, 4], np.ones((1, 8), np.float32), [3.0])


def test_vectorcache_live_view_and_lookup_helpers():
    mat, ts = _corpus(n=50, seed=7)
    vc = VectorCache(np.arange(50), mat, ts, EMB)
    # zero-copy single-segment view
    assert vc.matrix.shape == (50, 32) and vc.ids.shape == (50,)
    vc.delete([3, 4])
    assert vc.matrix.shape == (48, 32)
    assert list(vc.ids[:5]) == [0, 1, 2, 5, 6]

    # rows_for_ids: silent drop by default, strict names the missing
    assert list(vc.rows_for_ids([0, 3, 5])) == [0, 3]
    with pytest.raises(KeyError, match=r"\[3, 777\]"):
        vc.rows_for_ids([0, 3, 777], strict=True)
    # embeddings_for_ids reports WHICH ids are missing
    from repro.core.grammar import GrammarError
    with pytest.raises(GrammarError, match=r"\[888, 999\]"):
        vc.embeddings_for_ids([888, 999])


def test_batched_engine_ingest_between_batches():
    emb = HashEmbedder(64)
    texts = [f"item group {i % 5} tail {i}" for i in range(120)]
    vc = VectorCache(np.arange(120), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 120), emb)
    from repro.serve.engine import BatchedRetrievalEngine

    eng = BatchedRetrievalEngine(vc, max_batch=4, now=NOW)
    try:
        before = eng.search("similar:group 1 tail", 5)
        new_texts = [f"brand new doc about group 1 tail {i}"
                     for i in range(8)]
        eng.ingest(np.arange(500, 508), emb.embed_batch(new_texts),
                   np.full(8, NOW))
        after = eng.search("similar:brand new doc group 1 tail", 8)
        assert any(i >= 500 for i, _ in after)
        eng.delete(np.arange(500, 508))
        gone = eng.search("similar:brand new doc group 1 tail", 8)
        assert all(i < 500 for i, _ in gone)
        # batched ranking still matches the direct path post-mutation
        direct = vc.search("similar:group 1 tail", now=NOW)[:5]
        again = eng.search("similar:group 1 tail", 5)
        assert [i for i, _ in again] == [i for i, _ in direct]
        assert [i for i, _ in before] == [i for i, _ in direct]
    finally:
        eng.close()


def test_materializer_sql_ingest_surface():
    """INSERT/DELETE against the chunks view: SQLite + FTS + cache segment
    stay in sync; other writes stay rejected."""
    from repro.core.materializer import Materializer
    from repro.data.corpus import build_database, generate_corpus
    from repro.sqlio.schema import load_embedding_matrix

    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=200, n_sessions=10, seed=9)
    conn = sqlite3.connect(":memory:")
    build_database(conn, chunks, emb)
    ids, matrix, ts = load_embedding_matrix(conn, 64)
    cache = VectorCache(ids, matrix, ts, emb)
    mz = Materializer(conn, cache, now=1_770_000_000.0)
    n0 = cache.store.n_live
    new_id = int(ids.max()) + 1

    cols, rows = mz.execute(
        "INSERT INTO chunks (id, session_id, type, content, created_at) "
        f"VALUES ({new_id}, 'sess-new', 'assistant', "
        "'zanzibar exotic retrieval topic', 1769000000.0)"
    )
    assert cols == ["id"] and rows == [(new_id,)]
    assert cache.store.n_live == n0 + 1
    assert cache.store.n_segments == 2  # one delta segment, nothing else

    # the new chunk is immediately searchable through all three phases
    _, found = mz.execute(
        "SELECT v.id, v.score FROM vec_ops('similar:zanzibar exotic "
        "retrieval topic') v ORDER BY v.score DESC LIMIT 3")
    assert found and found[0][0] == new_id
    _, kw = mz.execute(f"SELECT k.id FROM keyword('zanzibar') k")
    assert (new_id,) in kw

    # DELETE tombstones the cache row and drops SQLite + FTS rows
    cols, rows = mz.execute(f"DELETE FROM chunks WHERE id = {new_id}")
    assert rows == [(new_id,)]
    assert cache.store.n_live == n0
    assert conn.execute("SELECT COUNT(*) FROM _raw_chunks WHERE id=?",
                        (new_id,)).fetchone()[0] == 0
    _, found = mz.execute(
        "SELECT v.id FROM vec_ops('similar:zanzibar exotic retrieval "
        "topic') v LIMIT 3")
    assert (new_id,) not in found
    _, kw = mz.execute("SELECT k.id FROM keyword('zanzibar') k")
    assert (new_id,) not in kw

    # everything else stays read-only
    from repro.core.materializer import MaterializeError
    with pytest.raises(MaterializeError):
        mz.execute("DELETE FROM _raw_chunks")
    with pytest.raises(MaterializeError):
        mz.execute("UPDATE _raw_chunks SET content='x'")


def test_materializer_failed_ingest_rolls_back():
    """A failing INSERT leaves NO trace: no pending transaction rows, no
    FTS postings, no cache segment — the agent's retry works."""
    from repro.core.materializer import MaterializeError, Materializer
    from repro.data.corpus import build_database, generate_corpus
    from repro.sqlio.schema import load_embedding_matrix

    emb = HashEmbedder(64)
    conn = sqlite3.connect(":memory:")
    build_database(conn, generate_corpus(n_chunks=50, n_sessions=4, seed=21),
                   emb)
    ids, matrix, ts = load_embedding_matrix(conn, 64)
    cache = VectorCache(ids, matrix, ts, embed_fn=None)  # no embed fn
    mz = Materializer(conn, cache)
    with pytest.raises(MaterializeError, match="embed"):
        mz.execute("INSERT INTO chunks (id, session_id, type, content, "
                   "created_at) VALUES (7777, 's', 'assistant', 'orphan "
                   "row', 1.0)")
    assert not conn.in_transaction  # rolled back, not left pending
    conn.commit()  # an unrelated commit must not resurrect the row
    assert conn.execute("SELECT COUNT(*) FROM _raw_chunks WHERE id=7777"
                        ).fetchone()[0] == 0
    assert cache.store.n_segments == 1
    # a duplicate-id INSERT fails explicitly and also rolls back fully
    cache.embed_fn = emb
    dup = int(ids[0])
    with pytest.raises(MaterializeError):
        mz.execute("INSERT INTO chunks (id, session_id, type, content, "
                   f"created_at) VALUES ({dup}, 's', 'assistant', 'x', 1.0)")
    assert not conn.in_transaction


def test_service_ingest_rejects_duplicate_ids_before_writing():
    from repro.data.corpus import build_database, generate_corpus
    from repro.serve.retrieval import RetrievalService

    emb = HashEmbedder(64)
    conn = sqlite3.connect(":memory:")
    build_database(conn, generate_corpus(n_chunks=50, n_sessions=4, seed=25),
                   emb)
    svc = RetrievalService(conn, dim=64, embedder=emb)
    live_id = int(svc.cache.ids[0])
    old_content = conn.execute(
        "SELECT content FROM _raw_chunks WHERE id=?", (live_id,)
    ).fetchone()[0]
    with pytest.raises(ValueError, match="already live"):
        svc.ingest([(live_id, "s", "assistant", "replacement", 2.0,
                     0, None, None, None, None)])
    # SQLite row untouched (no silent REPLACE), store consistent
    assert conn.execute("SELECT content FROM _raw_chunks WHERE id=?",
                        (live_id,)).fetchone()[0] == old_content
    assert svc.cache.store.n_segments == 1


def test_retrieval_service_ingest_delete_and_stats():
    from repro.data.corpus import build_database, generate_corpus
    from repro.serve.retrieval import RetrievalService

    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=150, n_sessions=8, seed=13)
    conn = sqlite3.connect(":memory:")
    build_database(conn, chunks, emb)
    svc = RetrievalService(conn, dim=64, embedder=emb,
                           now=1_770_000_000.0, engine="jit-jax")
    new_id = 10_000
    n = svc.ingest([(new_id, "sess-x", "assistant",
                     "quetzal plumage iridescent", 1_769_000_000.0,
                     0, "proj", None, None, None)])
    assert n == 1
    res = svc.flex_search(
        "SELECT v.id FROM vec_ops('similar:quetzal plumage iridescent') v "
        "ORDER BY v.score DESC LIMIT 3")
    assert res.ok and (new_id,) in res.rows

    stats = svc.stats()
    assert stats["engine"] == "jit-jax"
    assert stats["store"]["segments"] == 2
    assert stats["plan_cache"]["jax_traces"] >= 1
    assert stats["device_cache"]["uploads"] >= 1
    assert stats["queries"] == 1

    assert svc.delete([new_id]) == 1
    res = svc.flex_search(
        "SELECT v.id FROM vec_ops('similar:quetzal plumage iridescent') v "
        "LIMIT 3")
    assert res.ok and (new_id,) not in res.rows
    assert svc.stats()["store"]["tombstoned"] == 1

    # SQL-surface ingest through the single agent endpoint too
    res = svc.flex_search(
        "INSERT INTO chunks (id, session_id, type, content, created_at) "
        "VALUES (10001, 'sess-y', 'assistant', 'axolotl regeneration', "
        "1769000100.0)")
    assert res.ok and res.rows == [(10001,)]
    res = svc.flex_search("DELETE FROM chunks WHERE id = 10001")
    assert res.ok and res.rows == [(10001,)]
