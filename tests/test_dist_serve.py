"""Distributed PEM (shard_map) + serving engine + retrieval service."""

import concurrent.futures as cf
import sqlite3
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.dist.pem_sharded import make_pem_topk, pem_topk_reference
from repro.dist.sharding import default_rules
from repro.embed import HashEmbedder
from repro.serve.engine import BatchedRetrievalEngine
from repro.serve.retrieval import RetrievalService


def test_pem_sharded_matches_reference_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    rng = np.random.default_rng(0)
    corpus = jnp.asarray(rng.standard_normal((512, 64)).astype(np.float32))
    days = jnp.asarray(rng.uniform(0, 60, 512).astype(np.float32))
    qp = jnp.asarray(rng.standard_normal((64, 3)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((64, 3)).astype(np.float32))
    i1, v1 = make_pem_topk(mesh, rules, 25)(corpus, days, qp, qs)
    i2, v2 = pem_topk_reference(corpus, days, qp, qs, 25)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_pem_sharded_multi_device_subprocess():
    """True multi-shard correctness: run on 8 forced host devices in a
    subprocess (the flag must never leak into this process)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pem_sharded import make_pem_topk, pem_topk_reference
        from repro.dist.sharding import default_rules
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = default_rules(mesh)
        rng = np.random.default_rng(1)
        corpus = jnp.asarray(rng.standard_normal((1024, 32)).astype(np.float32))
        days = jnp.asarray(rng.uniform(0, 60, 1024).astype(np.float32))
        qp = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
        qs = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
        i1, v1 = make_pem_topk(mesh, rules, 50)(corpus, days, qp, qs)
        i2, v2 = pem_topk_reference(corpus, days, qp, qs, 50)
        # values: fp-identical up to fusion reassociation; indices: exact
        assert np.allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5), "values diverge"
        assert (np.asarray(i1) == np.asarray(i2)).all(), "indices diverge"
        print("MULTI_DEVICE_OK", jax.device_count())
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MULTI_DEVICE_OK 8" in r.stdout


@pytest.fixture(scope="module")
def small_service():
    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=500, n_sessions=25, seed=11)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    return RetrievalService(conn, dim=64, embedder=emb, now=1_770_000_000.0)


def test_flex_search_sql(small_service):
    res = small_service.flex_search(
        "SELECT v.id, v.score FROM vec_ops('similar:server lifecycle pool:10') v "
        "ORDER BY v.score DESC LIMIT 5")
    assert res.ok and len(res.rows) == 5
    assert res.latency_ms > 0


def test_flex_search_preset(small_service):
    res = small_service.flex_search("@orient")
    assert res.ok
    sections = {r[0] for r in res.rows}
    assert {"now", "about", "shape", "query_surface", "presets"} <= sections


def test_flex_search_error_then_retry(small_service):
    bad = small_service.flex_search("SELECT v.id FROM vec_ops('decay:zzz') v")
    assert not bad.ok and "decay" in bad.error
    good = small_service.flex_search(
        "SELECT v.id FROM vec_ops('similar:x decay:7') v LIMIT 3")
    assert good.ok                       # the agent's retry path
    assert small_service.error_count == 1


def test_batched_engine_matches_direct():
    emb = HashEmbedder(64)
    texts = [f"item group {i % 9} tail {i}" for i in range(400)]
    vc = VectorCache(np.arange(400), emb.embed_batch(texts),
                     np.linspace(0, 89 * 86400, 400), emb)
    eng = BatchedRetrievalEngine(vc, max_batch=16, now=90 * 86400.0)
    try:
        tokens = [f"similar:group {i % 9} tail decay:14" for i in range(24)]
        with cf.ThreadPoolExecutor(12) as ex:
            batched = list(ex.map(lambda t: eng.search(t, 5), tokens))
        direct = [vc.search(t, now=90 * 86400.0)[:5] for t in tokens]
        for b, d in zip(batched, direct):
            assert [i for i, _ in b] == [i for i, _ in d]
        assert eng.batches_served < len(tokens)   # batching actually batched
    finally:
        eng.close()
