"""Durable ingest: WAL recovery, fault injection, vectorizer, shedding.

Every crash point a :class:`FaultPlan` can name is exercised: the store
is driven to the point, the injected crash unwinds, and recovery from
the on-disk journal must reproduce — bit for bit — the state an oracle
store reached by applying exactly the acknowledged (journaled) ops.
The vectorizer's retry/backoff schedule runs against a fake clock, so
the exponential curve is asserted, not sampled.
"""

import os
import sqlite3
import time

import numpy as np
import pytest

from repro.core.journal import FaultPlan, InjectedCrash, StoreJournal
from repro.core.segments import SegmentedCorpusStore
from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.serve.engine import BatchedRetrievalEngine, QueueFullError
from repro.serve.retrieval import RetrievalService
from repro.serve.vectorizer import (IngestQueue, IngestQueueFullError,
                                    VectorizerWorker)

pytestmark = pytest.mark.durability

DIM = 32
RNG = np.random.default_rng(42)


def _rows(n, start=0):
    # seeded per (n, start): the oracle and the journaled store replay
    # the same script and must see the same bytes
    rng = np.random.default_rng(1_000 + 7 * start + n)
    ids = np.arange(start, start + n, dtype=np.int64)
    mat = rng.standard_normal((n, DIM)).astype(np.float32)
    ts = np.linspace(0.0, 86400.0 * n, n)
    return ids, mat, ts


def wait_for(predicate, timeout=10.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def assert_stores_identical(a: SegmentedCorpusStore,
                            b: SegmentedCorpusStore) -> None:
    """Bit-identical scoring state: same segments, same row order, same
    matrices, same tombstones — hence identical rankings."""
    assert a.n_segments == b.n_segments
    assert a.n_live == b.n_live
    for sa, sb in zip(a.segments, b.segments):
        assert sa.seg_id == sb.seg_id
        assert np.array_equal(sa.ids, sb.ids)
        assert np.array_equal(sa.tombstones, sb.tombstones)
        assert sa.matrix.tobytes() == sb.matrix.tobytes()  # bit-identical
        if sa.timestamps is None:
            assert sb.timestamps is None
        else:
            assert np.array_equal(sa.timestamps, sb.timestamps)


# ---------------------------------------------------------------------------
# store recovery: snapshot + delta replay, crash at every FaultPlan point
# ---------------------------------------------------------------------------


def _scripted_ops(store):
    """The mutation script both the journaled store and the oracle run."""
    ids, mat, ts = _rows(40)
    store.append(ids, mat, ts)
    store.delete([1, 5, 9])
    ids2, mat2, ts2 = _rows(10, start=100)
    store.append(ids2, mat2, ts2)
    store.delete(list(range(0, 40, 2)))
    store.compact(min_live_fraction=1.0)


def test_reopen_matches_never_crashed_oracle(tmp_path):
    oracle = SegmentedCorpusStore(DIM)
    _scripted_ops(oracle)

    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    _scripted_ops(store)
    store.journal.close()

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert_stores_identical(recovered, oracle)
    assert recovered.recovered_records == 5  # 2 appends + 2 deletes + compact


@pytest.mark.parametrize("crash_at", [
    "append:post-journal",
    "delete:post-journal",
    "compact:post-journal",
    "snapshot:pre-rename",
    "snapshot:post-rename",
])
def test_crash_at_every_point_recovers_to_oracle(tmp_path, crash_at):
    """WAL-first: any op that reached its post-journal point IS durable —
    recovery converges on the oracle that applied it.  The snapshot
    points must not lose anything either way (a snapshot is not a
    mutation, only a rotation)."""
    plan = FaultPlan(crash_at=crash_at)
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM,
                                      fault_plan=plan)
    oracle = SegmentedCorpusStore(DIM)
    ids, mat, ts = _rows(30)
    ids2, mat2, ts2 = _rows(8, start=50)

    with pytest.raises(InjectedCrash):
        # drive until the configured point fires, mirroring each
        # SUCCESSFUL op (and each journaled-but-interrupted one: the
        # journal fsync'd before the crash point, so it is acknowledged
        # state that recovery must reproduce) onto the oracle
        store.append(ids, mat, ts)
        oracle.append(ids, mat, ts)
        if crash_at.startswith("snapshot:"):
            store.checkpoint()
        store.delete([2, 4])
        oracle.delete([2, 4])
        store.append(ids2, mat2, ts2)
        oracle.append(ids2, mat2, ts2)
        store.delete(list(range(0, 30, 2)))
        oracle.delete(list(range(0, 30, 2)))
        store.compact(min_live_fraction=1.0)
        oracle.compact(min_live_fraction=1.0)
        raise AssertionError(f"fault plan never fired: {crash_at}")
    # the crashed op journaled before dying -> the oracle applies it too
    if crash_at == "append:post-journal":
        oracle.append(ids, mat, ts)
    elif crash_at == "delete:post-journal":
        oracle.delete([2, 4])
    elif crash_at == "compact:post-journal":
        # the delete already mirrored inside the script; only the
        # journaled-but-unapplied fold is outstanding
        oracle.compact(min_live_fraction=1.0)
    assert crash_at in plan.fired

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert_stores_identical(recovered, oracle)

    # the recovered store keeps working AND re-recovers identically
    ids3, mat3, ts3 = _rows(5, start=200)
    recovered.append(ids3, mat3, ts3)
    oracle.append(ids3, mat3, ts3)
    recovered.journal.close()
    again = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert_stores_identical(again, oracle)


def test_torn_tail_tolerated_and_truncated(tmp_path):
    """A record torn mid-``write(2)`` is NOT acknowledged: replay stops
    cleanly before it, the torn bytes are truncated away, and writes
    after recovery are replayable (nothing hides behind garbage)."""
    plan = FaultPlan()
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM,
                                      fault_plan=plan)
    ids, mat, ts = _rows(20)
    store.append(ids, mat, ts)
    plan.crash_at = "journal:torn-tail"  # arm: tear the NEXT record
    ids2, mat2, ts2 = _rows(6, start=50)
    with pytest.raises(InjectedCrash):
        store.append(ids2, mat2, ts2)  # this frame is written only half

    oracle = SegmentedCorpusStore(DIM)
    oracle.append(ids, mat, ts)

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert_stores_identical(recovered, oracle)
    assert recovered.journal.torn_tail_dropped == 1

    # post-recovery writes land where replay will find them
    recovered.append(ids2, mat2, ts2)
    oracle.append(ids2, mat2, ts2)
    recovered.journal.close()
    again = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert_stores_identical(again, oracle)


def test_recovery_is_o_delta_not_o_corpus(tmp_path):
    """The O(delta) pin: after a checkpoint, recovery replays ONLY the
    post-snapshot records, counted by ``recovered_records``."""
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    for i in range(25):
        ids, mat, ts = _rows(4, start=i * 10)
        store.append(ids, mat, ts)
    store.checkpoint()
    ids, mat, ts = _rows(3, start=900)
    store.append(ids, mat, ts)
    store.delete([900])
    store.journal.close()

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert recovered.recovered_records == 2  # NOT 27
    assert recovered.n_live == 25 * 4 + 3 - 1
    # and a fresh checkpoint drops it to zero
    recovered.checkpoint()
    recovered.journal.close()
    assert SegmentedCorpusStore.open(
        tmp_path / "j", dim=DIM).recovered_records == 0


def test_seq_resumes_across_checkpointed_reopen(tmp_path):
    """Records written by a store REOPENED after a checkpoint must survive
    the next recovery: the journal seq has to resume past the snapshot's
    seq even though the rotated journal file is empty (regression — a
    reset seq made ``replay(after_seq=snapshot.seq)`` filter them out)."""
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    ids, mat, ts = _rows(6)
    store.append(ids, mat, ts)
    store.checkpoint()
    store.journal.close()

    writer = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    ids, mat, ts = _rows(2, start=50)
    writer.append(ids, mat, ts)
    writer.delete([int(ids[0])])
    writer.journal.close()

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert recovered.recovered_records == 2
    assert recovered.n_live == 6 + 2 - 1
    assert_stores_identical(recovered, writer)
    recovered.journal.close()


def test_journal_bytes_and_checkpoint_counters_in_stats(tmp_path):
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    ids, mat, ts = _rows(10)
    store.append(ids, mat, ts)
    st = store.stats()
    assert st["journal_bytes"] > 0
    assert st["checkpoints"] == 0
    store.checkpoint()
    st = store.stats()
    assert st["journal_bytes"] == 0  # rotated away
    assert st["checkpoints"] == 1
    store.journal.close()


# ---------------------------------------------------------------------------
# vectorizer: backoff schedule (fake clock), dead letters, queue bounds
# ---------------------------------------------------------------------------


class _FailingEmbedder:
    """Raises ``fail_times`` times, then embeds via HashEmbedder."""

    def __init__(self, fail_times=10**9, dim=DIM):
        self.fail_times = fail_times
        self.calls = 0
        self._emb = HashEmbedder(dim)

    def __call__(self, text):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise RuntimeError("embedder down")
        return self._emb(text)


def _worker(embed, **kw):
    sunk = []
    kw.setdefault("jitter", 0.0)
    kw.setdefault("base_backoff_s", 1.0)
    kw.setdefault("max_backoff_s", 8.0)
    worker = VectorizerWorker(
        IngestQueue(64), embed,
        lambda ids, vecs, ts: sunk.append((list(ids), vecs, list(ts))),
        **kw)
    return worker, sunk


def test_backoff_schedule_is_exponential_and_capped():
    worker, _ = _worker(_FailingEmbedder(), max_attempts=10)
    assert [worker.backoff_s(n) for n in (1, 2, 3, 4, 5, 6)] == [
        1.0, 2.0, 4.0, 8.0, 8.0, 8.0]  # base * 2^(n-1), capped


def test_jitter_bounds():
    worker, _ = _worker(_FailingEmbedder(), jitter=0.25, seed=3)
    for n in (1, 2, 3):
        base = min(8.0, 2.0 ** (n - 1))
        for _ in range(20):
            d = worker.backoff_s(n)
            assert base <= d <= base * 1.25


def test_retry_schedule_on_fake_clock():
    """Failures reschedule at exactly now + backoff; a drain BEFORE the
    due time takes nothing, a drain at it retries."""
    embed = _FailingEmbedder(fail_times=2)
    worker, sunk = _worker(embed, max_attempts=5)
    worker.enqueue([(1, "alpha text", 10.0)])

    assert worker.drain_once(now=0.0) == 0       # failure #1 -> due at 1.0
    assert worker.stats()["retries"] == 1
    assert not worker.has_due(now=0.99)          # backoff holds the row
    assert worker.drain_once(now=0.5) == 0       # nothing due -> no embed
    assert embed.calls == 1
    assert worker.drain_once(now=1.0) == 0       # failure #2 -> due at 3.0
    assert not worker.has_due(now=2.99)
    assert worker.has_due(now=3.0)
    assert worker.drain_once(now=3.0) == 1       # third attempt succeeds
    assert sunk and sunk[0][0] == [1]
    assert worker.stats()["retries"] == 2
    assert worker.stats()["embedded"] == 1
    assert len(worker.queue) == 0


def test_dead_letter_after_retry_budget():
    worker, sunk = _worker(_FailingEmbedder(), max_attempts=3)
    worker.enqueue([(7, "poison row", None), (8, "poison too", None)])
    now = 0.0
    for _ in range(3):
        worker.drain_once(now=now)
        now += 100.0  # past any backoff
    st = worker.stats()
    assert st["dead_letter"] == 2
    assert st["retries"] == 4        # 2 rows x 2 non-final failures
    assert len(worker.queue) == 0    # dead rows never re-queue
    assert not sunk
    assert {d["chunk_id"] for d in worker.dead_letters} == {7, 8}
    assert all(d["attempts"] == 3 for d in worker.dead_letters)
    # one more drain: nothing left, nothing resurrects
    assert worker.drain_once(now=now) == 0
    assert worker.stats()["dead_letter"] == 2


def test_flush_terminates_on_poison_rows():
    worker, _ = _worker(_FailingEmbedder(), max_attempts=4)
    worker.enqueue([(i, f"text {i}", None) for i in range(5)])
    assert worker.flush() == 0  # all poison -> nothing ingested, no hang
    assert worker.stats()["dead_letter"] == 5


def test_queue_backpressure_all_or_nothing():
    q = IngestQueue(maxsize=3)
    q.put([(1, "a", None), (2, "b", None)])
    with pytest.raises(IngestQueueFullError):
        q.put([(3, "c", None), (4, "d", None)])
    assert len(q) == 2           # the overflowing batch left no partial
    assert q.rejected == 2
    q.put([(3, "c", None)])
    assert len(q) == 3


def test_delete_discards_pending_rows():
    worker, sunk = _worker(_FailingEmbedder(fail_times=0))
    worker.enqueue([(1, "a", None), (2, "b", None)])
    assert worker.queue.discard([1]) == 1
    worker.flush()
    assert [ids for ids, _, _ in sunk] == [[2]]  # deleted row never embeds


# ---------------------------------------------------------------------------
# enqueued-but-never-embedded rows survive a crash
# ---------------------------------------------------------------------------


def test_pending_rows_recovered_after_crash(tmp_path):
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    emb = HashEmbedder(DIM)
    worker = VectorizerWorker(
        IngestQueue(64), emb,
        lambda ids, vecs, ts: store.append(
            ids, vecs, [t or 0.0 for t in ts]),
        journal=store.journal)
    worker.enqueue([(1, "first pending", 5.0), (2, "second pending", 6.0)])
    worker.drain_once()            # both embed and land in the store
    worker.enqueue([(3, "never embedded", 7.0)])
    # simulated crash: no close, no checkpoint — just drop everything

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert sorted(i for i, _, _ in recovered.recovered_pending) == [3]
    assert recovered.n_live == 2   # 1 and 2 are sealed rows, not pending

    # adopting re-admits without re-journaling; draining completes ingest
    worker2 = VectorizerWorker(
        IngestQueue(64), emb,
        lambda ids, vecs, ts: recovered.append(
            ids, vecs, [t or 0.0 for t in ts]),
        journal=recovered.journal)
    worker2.adopt(recovered.recovered_pending,
                  recovered.recovered_dead_letters)
    worker2.flush()
    assert recovered.n_live == 3


def test_vectorizer_post_embed_crash_reenqueues(tmp_path):
    """Crash AFTER embedding but BEFORE the sink ingest: the batch was
    never acknowledged into the store, so recovery re-surfaces it as
    pending (at-least-once, idempotent because ingest seals by id)."""
    plan = FaultPlan(crash_at="vectorizer:post-embed")
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM,
                                      fault_plan=plan)
    worker = VectorizerWorker(
        IngestQueue(64), HashEmbedder(DIM),
        lambda ids, vecs, ts: store.append(
            ids, vecs, [t or 0.0 for t in ts]),
        journal=store.journal, fault_plan=plan)
    worker.enqueue([(11, "doomed batch", None)])
    with pytest.raises(InjectedCrash):
        worker.drain_once()

    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert [i for i, _, _ in recovered.recovered_pending] == [11]
    assert recovered.n_live == 0


def test_dead_letters_survive_crash_and_checkpoint(tmp_path):
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    worker = VectorizerWorker(
        IngestQueue(64), _FailingEmbedder(),
        lambda *a: None, max_attempts=2, journal=store.journal,
        base_backoff_s=0.0, jitter=0.0)
    worker.enqueue([(5, "poison", None)])
    worker.flush()
    assert worker.stats()["dead_letter"] == 1

    # crash (no checkpoint): the dead_letter journal record recovers it
    recovered = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert [d["chunk_id"] for d in recovered.recovered_dead_letters] == [5]
    assert recovered.recovered_pending == []  # dead, not pending
    # checkpoint carries it through rotation too
    recovered.checkpoint(dead_letters=recovered.recovered_dead_letters)
    recovered.journal.close()
    again = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    assert [d["chunk_id"] for d in again.recovered_dead_letters] == [5]
    assert again.recovered_records == 0


# ---------------------------------------------------------------------------
# service end-to-end: queued INSERT, idle-gap drain, close() flush
# ---------------------------------------------------------------------------


def _service(tmp_path, **kwargs):
    emb = HashEmbedder(DIM)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, generate_corpus(n_chunks=80, n_sessions=6, seed=11),
                   emb)
    svc = RetrievalService(conn, dim=DIM, embedder=emb,
                           store_path=tmp_path / "store", **kwargs)
    return svc, conn


INSERT = ("INSERT INTO chunks (id, session_id, type, content, created_at) "
          "VALUES ({cid}, 'sess-d', 'assistant', '{text}', 1769000000.0)")


def test_insert_enqueues_and_drains_in_idle_gaps(tmp_path):
    svc, _ = _service(tmp_path)
    try:
        svc.serving(max_wait_ms=1.0)
        new_id = 9001
        res = svc.flex_search(INSERT.format(
            cid=new_id, text="quixotic durability payload"))
        assert res.ok, res.error
        # the INSERT returned after ENQUEUE: the row is not sealed yet
        # (it may embed moments later in an idle gap, hence >= checks)
        st = svc.stats()["ingest"]
        assert st["queued"] == 1
        # the scheduler's idle-gap hook drains it without any search
        assert wait_for(lambda: svc.stats()["ingest"]["embedded"] == 1)
        assert new_id in svc.cache.store
        assert svc.stats()["serving"]["vectorizer_drains"] >= 1
        hits = svc.search("similar:quixotic durability payload", k=3)
        assert hits and hits[0][0] == new_id
    finally:
        svc.close()


def test_close_flushes_pending_ingest(tmp_path):
    """The close() bugfix pin: accepted-but-not-yet-embedded rows must
    be embedded (or dead-lettered) by close, never silently dropped."""
    svc, conn = _service(tmp_path)
    svc.serving(max_wait_ms=2000.0)  # huge wait: no idle gap will fire
    new_id = 9002
    assert svc.flex_search(INSERT.format(
        cid=new_id, text="flush me on close")).ok
    svc.close()  # must flush the queue before checkpointing

    svc2 = RetrievalService(conn, dim=DIM, embedder=HashEmbedder(DIM),
                            store_path=tmp_path / "store")
    try:
        assert new_id in svc2.cache.store
        assert svc2.cache.store.recovered_records == 0  # checkpointed
        hits = svc2.search("similar:flush me on close", k=3)
        assert hits and hits[0][0] == new_id
    finally:
        svc2.close()


def test_service_crash_recovers_pending_through_adoption(tmp_path):
    """Kill-and-recover: a queued INSERT whose process dies before the
    background embed still completes after reopen (journal -> adopt)."""
    svc, conn = _service(tmp_path)
    svc.serving(max_wait_ms=2000.0)
    new_id = 9003
    assert svc.flex_search(INSERT.format(
        cid=new_id, text="survives the crash")).ok
    assert new_id not in svc.cache.store
    # simulated crash: stop the scheduler WITHOUT the close-path flush or
    # checkpoint (a SIGKILL'd process gets neither), then drop the journal
    eng, svc._serving = svc._serving, None
    eng.vectorizer = None
    eng.close()
    svc.cache.store.journal.close()

    svc2 = RetrievalService(conn, dim=DIM, embedder=HashEmbedder(DIM),
                            store_path=tmp_path / "store")
    try:
        svc2.serving(max_wait_ms=1.0)  # adopts recovered pending rows
        assert wait_for(lambda: new_id in svc2.cache.store)
        hits = svc2.search("similar:survives the crash", k=3)
        assert hits and hits[0][0] == new_id
    finally:
        svc2.close()


def test_embed_failures_retry_then_succeed_in_service(tmp_path):
    svc, _ = _service(tmp_path,
                      fault_plan=FaultPlan(embed_failures=2))
    try:
        svc.serving(max_wait_ms=1.0, ingest_base_backoff_s=0.001)
        assert svc.flex_search(INSERT.format(
            cid=9004, text="eventually embedded")).ok
        assert wait_for(lambda: svc.stats()["ingest"]["embedded"] == 1)
        st = svc.stats()["ingest"]
        assert st["retries"] == 2
        assert st["dead_letter"] == 0
    finally:
        svc.close()


def test_embed_failures_dead_letter_in_service(tmp_path):
    svc, _ = _service(tmp_path,
                      fault_plan=FaultPlan(embed_failures=10**6))
    try:
        svc.serving(max_wait_ms=1.0, ingest_max_attempts=2,
                    ingest_base_backoff_s=0.001)
        assert svc.flex_search(INSERT.format(
            cid=9005, text="never embeds")).ok
        assert wait_for(
            lambda: svc.stats()["ingest"]["dead_letter"] == 1)
        st = svc.stats()["ingest"]
        assert st["embedded"] == 0
        assert 9005 not in svc.cache.store
    finally:
        svc.close()
    # the dead letter is durable across the close/open cycle
    store = SegmentedCorpusStore.open(tmp_path / "store", dim=DIM)
    assert [d["chunk_id"] for d in store.recovered_dead_letters] == [9005]
    store.journal.close()


def test_insert_with_explicit_embedding_stays_synchronous(tmp_path):
    """Only rows MISSING embeddings queue; SQL writing the blob (none in
    the INSERT grammar today) and the direct ingest() path stay inline."""
    svc, _ = _service(tmp_path)
    try:
        svc.serving(max_wait_ms=2000.0)
        n0 = svc.cache.store.n_live
        svc.ingest([(9100, "sess-d", "assistant", "inline row", 1.0,
                     0, None, None, None, None)])
        assert svc.cache.store.n_live == n0 + 1  # no queue involved
        assert svc.stats()["ingest"]["queued"] == 0
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# priority-aware shedding at admission
# ---------------------------------------------------------------------------


def _gated_engine(max_queue=2):
    from tests.test_serve_async import GateBackend, make_cache

    cache, _ = make_cache()
    gate = GateBackend()
    eng = BatchedRetrievalEngine(cache, max_batch=1, engine=gate,
                                 max_queue=max_queue)
    return eng, gate


def test_full_queue_sheds_lowest_priority_for_higher(tmp_path):
    import concurrent.futures as cf

    eng, gate = _gated_engine(max_queue=2)
    try:
        with cf.ThreadPoolExecutor(4) as ex:
            blocker = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)
            low = ex.submit(eng.search, "similar:group 2 tail", 5,
                            **{"priority": 0})
            mid = ex.submit(eng.search, "similar:group 3 tail", 5,
                            **{"priority": 3})
            assert wait_for(lambda: eng.queue_depth == 2)
            # queue full; a HIGHER-priority arrival evicts the lowest
            high = ex.submit(eng.search, "similar:group 4 tail", 5,
                             **{"priority": 5})
            with pytest.raises(QueueFullError):
                low.result(10.0)
            assert eng.shed_low_priority == 1
            gate.release.set()
            assert len(blocker.result(10.0)) == 5
            assert len(mid.result(10.0)) == 5   # survivor, served
            assert len(high.result(10.0)) == 5  # newcomer, admitted
        assert eng.queue_depth == 0
        assert eng.stats()["shed_low_priority"] == 1
        assert eng.rejected == 0  # shed, not rejected
    finally:
        gate.release.set()
        eng.close()


def test_newcomer_rejected_when_itself_lowest(tmp_path):
    import concurrent.futures as cf

    eng, gate = _gated_engine(max_queue=2)
    try:
        with cf.ThreadPoolExecutor(4) as ex:
            blocker = ex.submit(eng.search, "similar:group 1 tail", 5)
            assert gate.entered.wait(5.0)
            waiters = [ex.submit(eng.search, f"similar:group {i} tail", 5,
                                 **{"priority": 5}) for i in (2, 3)]
            assert wait_for(lambda: eng.queue_depth == 2)
            with pytest.raises(QueueFullError):
                eng.search("similar:group 4 tail", 5, **{"priority": 1})
            assert eng.rejected == 1
            assert eng.shed_low_priority == 0  # equal/lower never sheds
            gate.release.set()
            blocker.result(10.0)
            for w in waiters:
                assert len(w.result(10.0)) == 5
    finally:
        gate.release.set()
        eng.close()


# ---------------------------------------------------------------------------
# procgroup: shard stores + coordinator recover from their journals
# ---------------------------------------------------------------------------


def test_process_group_reopens_from_journals(tmp_path):
    from repro.core import modulations as M
    from repro.dist.procgroup import ProcessGroup

    ids, mat, _ = _rows(60)
    jdir = str(tmp_path / "group")
    g = ProcessGroup.build(ids, mat, journal_dir=jdir, n_shards=3,
                           replicas=2)
    g.delete([3, 7])
    ids2, mat2, _ = _rows(12, start=200)
    g.append(ids2, mat2)
    plan = M.ModulationPlan(query=M.l2_normalize(mat[0]), pool=10)
    ref = g.search_plan(plan, k=10)
    g.checkpoint()
    ids3, mat3, _ = _rows(4, start=400)
    g.append(ids3, mat3)
    ref2 = g.search_plan(plan, k=10)
    g.close()

    g2 = ProcessGroup.open(jdir, DIM, n_shards=3, replicas=2)
    try:
        assert g2.recovered_records == 1      # O(delta) at the coordinator
        assert g2.search_plan(plan, k=10) == ref2
        # shard replicas each replayed only their post-snapshot delta
        for row in g2.stats()["shards"]:
            assert row["recovered_records"] <= 2
    finally:
        g2.close()
    assert ref  # both rankings exercised


def test_process_group_reconciles_unacked_crash_window(tmp_path):
    """A shard append that never reached the coordinator journal (crash
    between fan-out and group-ack) is dropped at open — recovery
    converges on the ACKNOWLEDGED state."""
    from repro.core import modulations as M
    from repro.dist.procgroup import ProcessGroup

    ids, mat, _ = _rows(30)
    jdir = str(tmp_path / "group")
    g = ProcessGroup.build(ids, mat, journal_dir=jdir, n_shards=2)
    plan = M.ModulationPlan(query=M.l2_normalize(mat[1]), pool=8)
    ref = g.search_plan(plan, k=8)
    # un-acked write: straight to the shard, bypassing the coordinator
    g._clients[0][0].call(
        "append", np.asarray([777], dtype=np.int64),
        RNG.standard_normal((1, DIM)).astype(np.float32), None)
    g.close()

    g2 = ProcessGroup.open(jdir, DIM, n_shards=2)
    try:
        assert 777 not in g2._shard_of
        assert g2.reconciled_drops == 1
        assert g2.search_plan(plan, k=8) == ref
    finally:
        g2.close()


def test_journal_files_exist_on_disk(tmp_path):
    store = SegmentedCorpusStore.open(tmp_path / "j", dim=DIM)
    ids, mat, ts = _rows(5)
    store.append(ids, mat, ts)
    assert os.path.exists(tmp_path / "j" / "journal.wal")
    store.checkpoint()
    assert os.path.exists(tmp_path / "j" / "snapshot.bin")
    store.journal.close()
    assert StoreJournal(tmp_path / "j").load_snapshot() is not None
