"""Gradient accumulation: n_micro microbatches == one full-batch step."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import default_rules
from repro.models import transformer as T
from repro.models.layers import LMConfig
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    init_opt_state,
    make_grad_accum_step,
)


def test_accum_matches_full_batch():
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32,
                   q_chunk=16, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    params = T.init_params(cfg, jax.random.key(0))
    ocfg = AdamWConfig(lr=1e-3, clip_norm=None, compress_grads=False)

    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg, rules)

    with mesh:
        # full batch
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        p_full, o_full, m_full = adamw_update(
            ocfg, params, grads, init_opt_state(params))
        # 4 microbatches of 2
        step = jax.jit(make_grad_accum_step(loss_fn, ocfg, n_micro=4))
        p_acc, o_acc, m_acc = step(params, init_opt_state(params), batch)

    np.testing.assert_allclose(float(m_acc["loss"]), float(loss), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_acc), jax.tree.leaves(p_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)


def test_accum_trains():
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32,
                   q_chunk=16, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    params = T.init_params(cfg, jax.random.key(0))
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg, rules)
    step = jax.jit(make_grad_accum_step(loss_fn, ocfg, n_micro=2))
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    with mesh:
        for _ in range(20):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
