"""Hybrid lexical+vector retrieval as a PEM modulation (the fusion stage).

Invariants pinned here:

1. **w=1.0 bit-identity** — ``fuse:weighted,1.0`` produces EXACTLY the
   unfused ranking (ids and float scores) on all five backends, for every
   segmentation × tombstone combination: the weight folds through the
   linear pipeline and every scale application is guarded, so no multiply
   ever happens.
2. **Weighted oracle** — ``fuse:weighted,w`` matches the host oracle
   ``w*modulated + (1-w)*minmax(bm25)[sparse]`` on all five backends.
3. **RRF** — ``fuse:rrf,K`` matches ``modulations.rrf_fuse`` over the
   pure-vector device ranking and the lexical list.
4. **Grammar** — keyword:/fuse: parsing, multi-word accumulation,
   malformed specs as explicit :class:`GrammarError`.
5. **pool: threading** — the lexical resolver receives the plan's pool
   width (no hardcoded LIMIT 500), through build_plan AND the FTS path.
6. **Unified SQL contract** — ``keyword()``/``vec_ops()``/
   ``HYBRID_SEARCH()``/``VECTOR_SEARCH()`` all materialize
   ``(id, score, snippet)`` with min-max-normalized scores; FTS5
   special-character fallback quoting holds on the hybrid path.
7. **Serving parity** — the sync ``RetrievalService.search`` facade ranks
   identically with and without the batched engine attached, hybrid
   plans included.
"""

import sqlite3

import numpy as np
import pytest

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import (finalize_fusion, fusion_bias_arrays,
                                 get_backend, list_backends,
                                 plan_fusion_bias)
from repro.core.grammar import GrammarError
from repro.core.materializer import Materializer
from repro.core.segments import SegmentedCorpusStore
from repro.core.vectorcache import VectorCache
from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder

BACKENDS = list_backends()
NOW = 90 * 86400.0
EMB = HashEmbedder(32)

SEGMENTATIONS = ([230], [100, 130], [80, 80, 70])
TOMBSTONES = ((), (3, 104, 171))


def _corpus(n=230, d=32, seed=5):
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, d)).astype(np.float32)
    mat /= np.linalg.norm(mat, axis=1, keepdims=True)
    days = rng.uniform(0.0, 60.0, n).astype(np.float32)
    ts = NOW - days.astype(np.float64) * 86400.0
    return mat, ts


def _store_from_splits(mat, ts, splits, deleted=()):
    store = SegmentedCorpusStore(dim=mat.shape[1])
    start = 0
    for size in splits:
        store.append(np.arange(start, start + size), mat[start:start + size],
                     ts[start:start + size], normalized=True)
        start += size
    assert start == mat.shape[0]
    if len(deleted):
        store.delete(deleted)
    return store


def _stub_lexical(ids, scores):
    """A LexicalFn returning fixed BM25-style hits (already minmaxed)."""
    def fn(text, pool):
        return (np.asarray(ids[:pool], dtype=np.int64),
                np.asarray(scores[:pool], dtype=np.float32))
    return fn


LEX_IDS = [7, 12, 55, 102, 168, 229, 3]  # 3 is tombstoned in one combo
LEX_SCORES = [1.0, 0.9, 0.7, 0.5, 0.3, 0.2, 0.1]
LEXICAL = _stub_lexical(LEX_IDS, LEX_SCORES)

TOKENS = ("similar:how the retrieval system works decay:14 "
          "suppress:website landing page pool:40")


# -- 1. w=1.0 bit-identity ---------------------------------------------------


@pytest.mark.parametrize("engine", BACKENDS)
@pytest.mark.parametrize("splits", SEGMENTATIONS,
                         ids=["mono", "two", "three"])
@pytest.mark.parametrize("deleted", TOMBSTONES, ids=["live", "tombs"])
def test_weighted_one_bit_identical(engine, splits, deleted):
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, splits, deleted)
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    base = vc.search(TOKENS, now=NOW, engine=engine)
    fused = vc.search(TOKENS + " keyword:server fuse:weighted,1.0",
                      now=NOW, engine=engine)
    assert [i for i, _ in base] == [i for i, _ in fused]
    # bit-identical scores, not merely close: w=1.0 performs no multiply
    assert [s for _, s in base] == [s for _, s in fused]


def test_weighted_one_plan_contributes_no_bias():
    plan = grammar.parse(TOKENS + " keyword:x fuse:weighted,1.0",
                         EMB, lexical_fn=LEXICAL)
    assert plan.fusion is not None
    assert plan_fusion_bias(plan) is None  # the bit-identity guard
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [230])
    assert fusion_bias_arrays(store, store.segments, [plan]) is None


# -- 2. weighted oracle on all backends --------------------------------------


@pytest.mark.parametrize("engine", BACKENDS)
def test_weighted_matches_host_oracle(engine):
    w = 0.6
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [100, 130], deleted=(3, 104))
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    got = vc.search(TOKENS + f" keyword:server fuse:weighted,{w}",
                    now=NOW, engine=engine)

    # host oracle: w*modulated + (1-w)*minmax(bm25) at lexical rows
    plan = grammar.parse(TOKENS, EMB)
    days_ago = (NOW - ts) / 86400.0
    scores = M.modulate_scores(mat, days_ago, plan) * w
    for cid, s in zip(LEX_IDS, LEX_SCORES):
        scores[cid] += (1.0 - w) * s
    scores[[3, 104]] = -np.inf  # tombstones stay masked
    order = np.argsort(-scores, kind="stable")[:40]
    want = [(int(i), float(scores[i])) for i in order]

    assert [i for i, _ in got] == [i for i, _ in want]
    np.testing.assert_allclose([s for _, s in got], [s for _, s in want],
                               rtol=2e-5, atol=1e-6)


def test_weighted_bias_reranks_lexical_rows_upward():
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [230])
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    base = dict(vc.search(TOKENS, now=NOW))
    fused = dict(vc.search(TOKENS + " keyword:server fuse:weighted,0.5",
                           now=NOW))
    for cid in LEX_IDS:
        if cid in base and cid in fused:
            assert fused[cid] > base[cid] * 0.5 - 1e-6


# -- 3. rrf ------------------------------------------------------------------


def test_rrf_matches_manual_fusion():
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [100, 130], deleted=(3,))
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    got = vc.search(TOKENS + " keyword:server fuse:rrf,30", now=NOW)

    vec = vc.search(TOKENS, now=NOW)
    lex = [i for i in LEX_IDS if i in store]  # tombstoned id 3 drops
    want = M.rrf_fuse([i for i, _ in vec], lex, rrf_k=30)[:40]
    assert [i for i, _ in got] == [i for i, _ in want]
    np.testing.assert_allclose([s for _, s in got], [s for _, s in want])


def test_rrf_respects_candidate_filter():
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [230])
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    cands = list(range(0, 230, 2))  # even ids only
    got = vc.search(TOKENS + " keyword:server fuse:rrf", cands, now=NOW)
    assert got and all(i % 2 == 0 for i, _ in got)  # odd lexical ids clipped


# -- 4. grammar --------------------------------------------------------------


def test_keyword_multiword_accumulation():
    p = grammar.tokenize("keyword:server lifecycle keyword:restart similar:x")
    assert p.keyword == "server lifecycle restart"
    assert p.fuse_mode == "weighted"  # keyword: alone defaults to weighted


def test_keyword_is_a_valid_query_anchor():
    p = grammar.tokenize("keyword:server")
    assert p.similar is None and p.keyword == "server"
    plan = grammar.build_plan(p, EMB, lexical_fn=LEXICAL)
    assert plan.fusion is not None and plan.lexical.ids.size > 0
    assert not plan.query.any()  # zero base query vector


def test_fuse_weight_parsing_and_validation():
    assert grammar.tokenize("keyword:x fuse:weighted,0.25").fuse_weight == 0.25
    assert grammar.tokenize("keyword:x fuse:rrf,17").fuse_k == 17
    for bad in ("fuse:weighted,1.5", "fuse:weighted,nope", "fuse:rrf,0",
                "fuse:median", "fuse:weighted,0.5,9"):
        with pytest.raises(GrammarError):
            grammar.tokenize(f"keyword:x {bad}")


def test_fuse_without_keyword_is_explicit_error():
    with pytest.raises(GrammarError):
        grammar.tokenize("similar:x fuse:weighted,0.5")


def test_rrf_with_diverse_is_explicit_error():
    with pytest.raises(GrammarError):
        grammar.tokenize("similar:x keyword:y fuse:rrf diverse")


def test_keyword_without_resolver_is_explicit_error():
    with pytest.raises(GrammarError):
        grammar.parse("similar:x keyword:y", EMB)  # no lexical_fn anywhere


# -- 5. pool: threading ------------------------------------------------------


def test_lexical_fn_receives_pool_width():
    seen = {}

    def spy(text, pool):
        seen["text"], seen["pool"] = text, pool
        return np.asarray([1], np.int64), np.asarray([1.0], np.float32)

    grammar.parse("similar:x keyword:server restart pool:700", EMB,
                  lexical_fn=spy)
    assert seen == {"text": "server restart", "pool": 700}


# -- 6. SQL surface ----------------------------------------------------------


@pytest.fixture(scope="module")
def db():
    emb = HashEmbedder(64)
    chunks = generate_corpus(n_chunks=600, n_sessions=30, seed=7)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    return conn, emb


@pytest.fixture(scope="module")
def svc(db):
    from repro.serve.retrieval import RetrievalService

    conn, emb = db
    service = RetrievalService(conn, dim=64, embedder=emb,
                               now=1_770_000_000.0)
    yield service
    service.close()


def test_unified_result_contract(svc):
    for sql in (
        "SELECT id, score, snippet FROM keyword('server') LIMIT 5",
        "SELECT id, score, snippet FROM vec_ops('similar:server') LIMIT 5",
        "SELECT id, score, snippet FROM HYBRID_SEARCH('server') LIMIT 5",
        "SELECT id, score, snippet FROM VECTOR_SEARCH('server') LIMIT 5",
    ):
        res = svc.flex_search(sql)
        assert res.ok, (sql, res.error)
        assert res.columns == ["id", "score", "snippet"]
        assert res.rows and all(0.0 <= r[1] <= 1.0 for r in res.rows)
        assert all(r[2] for r in res.rows)  # snippet populated


def test_hybrid_search_sql_is_case_insensitive(svc):
    up = svc.flex_search(
        "SELECT id FROM HYBRID_SEARCH('server restart', 0.6) "
        "ORDER BY score DESC LIMIT 5")
    low = svc.flex_search(
        "SELECT id FROM hybrid_search('server restart', 0.6) "
        "ORDER BY score DESC LIMIT 5")
    assert up.ok and low.ok and up.rows == low.rows


def test_hybrid_search_weight_validation(svc):
    assert not svc.flex_search(
        "SELECT id FROM HYBRID_SEARCH('x', 1.5)").ok
    assert not svc.flex_search(
        "SELECT id FROM HYBRID_SEARCH('x', 'not_a_number')").ok


def test_hybrid_search_differs_from_both_pure_modes(svc):
    hyb = svc.flex_search("SELECT id FROM HYBRID_SEARCH('server restart', 0.5) "
                          "ORDER BY score DESC LIMIT 10")
    vec = svc.flex_search("SELECT id FROM VECTOR_SEARCH('server restart') "
                          "ORDER BY score DESC LIMIT 10")
    kw = svc.flex_search("SELECT id FROM keyword('server restart') "
                         "ORDER BY score DESC LIMIT 10")
    assert hyb.ok and vec.ok and kw.ok
    assert hyb.rows != vec.rows and hyb.rows != kw.rows


def test_fts_special_chars_through_hybrid_path(svc):
    # dots break FTS5 syntax -> fallback quoting must hold on the hybrid leg
    res = svc.flex_search(
        "SELECT id FROM HYBRID_SEARCH('server.lifecycle') LIMIT 5")
    assert res.ok, res.error


def test_fts_query_honors_limit(db):
    from repro.core.materializer import fts_query

    conn, _ = db
    assert len(fts_query(conn, "server", limit=3)) == 3
    assert len(fts_query(conn, "server", limit=50)) > 3


def test_grammar_hybrid_through_vec_ops_sql(svc):
    res = svc.flex_search(
        "SELECT id, score FROM vec_ops("
        "'similar:server lifecycle keyword:restart fuse:weighted,0.7 pool:30')"
        " ORDER BY score DESC")
    assert res.ok, res.error
    assert 0 < len(res.rows) <= 30


# -- 7. serving parity -------------------------------------------------------


def test_sync_facade_with_and_without_serving(db):
    from repro.serve.retrieval import RetrievalService

    conn, emb = db
    service = RetrievalService(conn, dim=64, embedder=emb,
                               now=1_770_000_000.0)
    try:
        tokens = "similar:server lifecycle keyword:restart fuse:weighted,0.6"
        direct = service.search(tokens, k=8)
        assert len(direct) == 8
        service.serving(max_batch=8)  # attach the batched engine
        batched = service.search(tokens, k=8, priority=1)
        assert [i for i, _ in direct] == [i for i, _ in batched]
        np.testing.assert_allclose([s for _, s in direct],
                                   [s for _, s in batched], rtol=2e-5)
        # rrf plans finish on host inside the engine's tail
        rrf_direct = service.cache.search(
            "similar:server keyword:restart fuse:rrf,30",
            now=service.now, engine=service.engine)[:8]
        rrf_batched = service.search(
            "similar:server keyword:restart fuse:rrf,30", k=8)
        assert [i for i, _ in rrf_direct] == [i for i, _ in rrf_batched]
    finally:
        service.close()


def test_finalize_fusion_noop_for_weighted():
    plan = grammar.parse("similar:x keyword:y fuse:weighted,0.5", EMB,
                         lexical_fn=LEXICAL)
    results = [(1, 0.5), (2, 0.25)]
    assert finalize_fusion(plan, results, 2) is results


# -- 8. fuse:filter — FTS hits as a hard Phase-1 candidate set ---------------


def test_fuse_filter_parsing():
    p = grammar.tokenize("similar:x keyword:y fuse:filter")
    assert p.fuse_mode == "filter"
    assert p.fuse_weight == 1.0  # pure-vector ranking within the hits
    p = grammar.tokenize("similar:x keyword:y fuse:filter,0.7")
    assert p.fuse_mode == "filter" and p.fuse_weight == 0.7
    with pytest.raises(GrammarError):
        grammar.tokenize("similar:x keyword:y fuse:filter,1.5")
    with pytest.raises(GrammarError):
        grammar.tokenize("similar:x keyword:y fuse:filter,nope")


def test_filter_candidate_ids_unit():
    plan = grammar.parse("similar:x keyword:k fuse:filter", EMB,
                         lexical_fn=LEXICAL)
    # no SQL filter: the FTS hit set IS the Phase-1 candidate set
    np.testing.assert_array_equal(
        M.filter_candidate_ids(plan, None), plan.lexical.ids)
    # intersection with an existing SQL filter (both stay hard)
    np.testing.assert_array_equal(
        M.filter_candidate_ids(plan, [12, 999, 7]), [7, 12])
    # empty intersection -> EMPTY set, never None (no full-corpus leak)
    out = M.filter_candidate_ids(plan, [999])
    assert out is not None and out.size == 0
    # non-filter plans pass the SQL filter through untouched
    w_plan = grammar.parse("similar:x keyword:k fuse:weighted,0.5", EMB,
                           lexical_fn=LEXICAL)
    assert M.filter_candidate_ids(w_plan, None) is None
    cand = [1, 2, 3]
    assert M.filter_candidate_ids(w_plan, cand) is cand


@pytest.mark.parametrize("engine", BACKENDS)
def test_fuse_filter_matches_candidate_search(engine):
    """fuse:filter == the same plan pre-filtered to the FTS hit ids,
    bit-for-bit: the hit set rides the identical Phase-1 route."""
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [100, 130], deleted=(3, 104))
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    got = vc.search(TOKENS + " keyword:server fuse:filter",
                    now=NOW, engine=engine)
    want = vc.search(TOKENS, candidate_ids=LEX_IDS, now=NOW, engine=engine)
    assert got == want
    assert {i for i, _ in got} <= set(LEX_IDS)
    assert 3 not in {i for i, _ in got}  # tombstones stay dead


def test_fuse_filter_routes_through_prefilter_router():
    """The satellite contract: the lexical hit set hits the
    selectivity-aware router exactly like a SQL pre-filter."""
    from repro.core.backends import PrefilterRouter

    mat, ts = _corpus()
    # sharp hit set (7/230 = 3% < 20% threshold) -> gather-host
    vc = VectorCache(store=_store_from_splits(mat, ts, [230]),
                     embed_fn=EMB, lexical_fn=LEXICAL,
                     prefilter=PrefilterRouter())
    vc.search(TOKENS + " keyword:server fuse:filter", now=NOW,
              engine="fused-numpy")
    assert vc.prefilter.routed_gather == 1
    assert vc.prefilter.routed_masked == 0
    # broad hit set (120/230 = 52%; pool: must not truncate it below the
    # crossover) -> masked-device
    broad = _stub_lexical(list(range(120)),
                          np.linspace(1.0, 0.1, 120).astype(np.float32))
    vc2 = VectorCache(store=_store_from_splits(mat, ts, [230]),
                      embed_fn=EMB, lexical_fn=broad,
                      prefilter=PrefilterRouter())
    vc2.search(TOKENS.replace("pool:40", "pool:200")
               + " keyword:server fuse:filter", now=NOW,
               engine="fused-numpy")
    assert vc2.prefilter.routed_masked == 1
    assert vc2.prefilter.routed_gather == 0


def test_fuse_filter_empty_hits_returns_empty():
    mat, ts = _corpus()
    vc = VectorCache(store=_store_from_splits(mat, ts, [230]),
                     embed_fn=EMB,
                     lexical_fn=_stub_lexical([], []))
    got = vc.search("similar:x keyword:zzz fuse:filter", now=NOW,
                    engine="fused-numpy")
    assert got == []


@pytest.mark.parametrize("engine", ["reference", "fused-numpy"])
def test_fuse_filter_weight_reranks_within_hits(engine):
    """fuse:filter,W with W<1: hard filter to the hit set, then the
    weighted blend re-ranks WITHIN it (host oracle)."""
    w = 0.5
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [230])
    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=LEXICAL)
    got = vc.search(TOKENS + f" keyword:server fuse:filter,{w}",
                    now=NOW, engine=engine)
    plan = grammar.parse(TOKENS, EMB)
    days_ago = (NOW - ts) / 86400.0
    base = M.modulate_scores(mat, days_ago, plan) * w
    full = np.full(mat.shape[0], -np.inf)
    for cid, s in zip(LEX_IDS, LEX_SCORES):
        full[cid] = base[cid] + (1.0 - w) * s
    order = [int(i) for i in np.argsort(-full, kind="stable")
             if np.isfinite(full[i])]
    assert [i for i, _ in got] == order
    np.testing.assert_allclose([s for _, s in got],
                               [full[i] for i in order],
                               rtol=2e-5, atol=1e-6)


# -- 9. multi-keyword lexical pools (dedup + CombSUM) ------------------------


def test_combine_lexical_pools_unit():
    pools = [(np.array([1, 2, 3]), np.array([1.0, 0.5, 0.25], np.float32)),
             (np.array([3, 4]), np.array([1.0, 0.5], np.float32))]
    ids, scores = M.combine_lexical_pools(pools, 10)
    # id 3 matches both clauses: 0.25 + 1.0 = 1.25 tops the list;
    # ids 2 and 4 tie at 0.5 -> first-seen (token order) breaks it
    assert list(ids) == [3, 1, 2, 4]
    np.testing.assert_allclose(
        scores, (np.array([1.25, 1.0, 0.5, 0.5]) - 0.5) / 0.75, rtol=1e-6)
    # truncation to the pool width happens BEFORE renormalization
    ids2, scores2 = M.combine_lexical_pools(pools, 2)
    assert list(ids2) == [3, 1]
    # no hits at all -> empty, typed
    ids3, scores3 = M.combine_lexical_pools(
        [(np.empty(0, np.int64), np.empty(0, np.float32))], 5)
    assert ids3.size == 0 and scores3.size == 0
    assert ids3.dtype == np.int64 and scores3.dtype == np.float32


def test_multi_keyword_tokenize_keeps_clauses():
    p = grammar.tokenize("similar:x keyword:alpha beta keyword:gamma "
                         "fuse:rrf pool:40")
    assert p.keywords == ["alpha beta", "gamma"]
    assert p.keyword == "alpha beta gamma"  # joined display text


def test_multi_keyword_plan_dedups_and_combsums():
    calls = []

    def lex(term, pool):
        calls.append((term, pool))
        if term == "alpha":
            return (np.array([7, 12, 55], np.int64),
                    np.array([1.0, 0.6, 0.2], np.float32))
        return (np.array([55, 102], np.int64),
                np.array([1.0, 0.4], np.float32))

    plan = grammar.parse(
        "similar:x keyword:alpha keyword:beta fuse:weighted,0.5 pool:40",
        EMB, None, lex)
    # one FTS pool per clause, each at the plan's pool width
    assert calls == [("alpha", 40), ("beta", 40)]
    ids = list(plan.lexical.ids)
    assert ids == [55, 7, 12, 102]       # 55: 0.2+1.0 CombSUM tops
    assert len(ids) == len(set(ids))     # overlapping hits deduped
    np.testing.assert_allclose(
        plan.lexical.scores,
        (np.array([1.2, 1.0, 0.6, 0.4]) - 0.4) / 0.8, rtol=1e-6)


def test_multi_keyword_end_to_end():
    mat, ts = _corpus()
    store = _store_from_splits(mat, ts, [230])

    def lex(term, pool):
        if term == "server":
            return (np.asarray(LEX_IDS, np.int64),
                    np.asarray(LEX_SCORES, np.float32))
        return (np.array([12, 77], np.int64),
                np.array([1.0, 0.8], np.float32))

    vc = VectorCache(store=store, embed_fn=EMB, lexical_fn=lex)
    got = vc.search(TOKENS + " keyword:server keyword:restart "
                    "fuse:weighted,0.4", now=NOW, engine="fused-numpy")
    ids = [i for i, _ in got]
    assert len(ids) == len(set(ids))     # no duplicate rows from overlap
    # id 12 matches both clauses -> its fused rank beats the single-clause
    # run of the same query
    single = vc.search(TOKENS + " keyword:server fuse:weighted,0.4",
                       now=NOW, engine="fused-numpy",
                       lexical_fn=_stub_lexical(LEX_IDS, LEX_SCORES))
    assert ids.index(12) < [i for i, _ in single].index(12)
