"""Distributed PEM: row-sharded corpus scoring with local-topk + global merge.

Runs on 8 forced host devices (this script sets the flag BEFORE importing
jax — same pattern as launch/dryrun.py) and verifies the sharded result
against the unsharded oracle, then shows the collective-byte math that makes
this the §Perf "flexvec-1" iteration.

    PYTHONPATH=src python examples/distributed_retrieval.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.pem_sharded import make_pem_topk, pem_topk_reference
from repro.dist.sharding import default_rules

N, D, B, K = 262_144, 128, 16, 500


def main() -> None:
    print(f"== devices: {jax.device_count()} (forced host platform)")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = default_rules(mesh)

    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((N, D)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    corpus = jnp.asarray(corpus)
    days = jnp.asarray(rng.uniform(0, 90, N).astype(np.float32))
    q_pre = jnp.asarray(rng.standard_normal((D, B)).astype(np.float32))
    q_sup = jnp.asarray(-0.5 * rng.standard_normal((D, B)).astype(np.float32))

    sharded = make_pem_topk(mesh, rules, K)
    t0 = time.time()
    idx_s, val_s = jax.block_until_ready(sharded(corpus, days, q_pre, q_sup))
    t_first = time.time() - t0
    t0 = time.time()
    idx_s, val_s = jax.block_until_ready(sharded(corpus, days, q_pre, q_sup))
    t_warm = time.time() - t0

    idx_r, val_r = pem_topk_reference(corpus, days, q_pre, q_sup, K)
    # per-shard vs full-matrix matmul reassociation leaves ~1e-7 score noise;
    # at 262k rows that can swap ADJACENT ranks of fp-tied scores, so compare
    # the candidate sets + values, not the exact order
    idx_s_np, idx_r_np = np.asarray(idx_s), np.asarray(idx_r)
    sets_ok = all(set(idx_s_np[b]) == set(idx_r_np[b]) for b in range(B))
    vals_ok = np.allclose(np.asarray(val_s), np.asarray(val_r), rtol=1e-5)
    ok = sets_ok and vals_ok
    print(f"== sharded == unsharded oracle: {ok} "
          f"(candidate sets equal: {sets_ok}, values rtol=1e-5: {vals_ok})")
    print(f"   first call {t_first*1e3:.1f} ms (compile), warm {t_warm*1e3:.1f} ms")

    shards = 4  # corpus axis = 'data'
    naive = N * B * 4
    ours = shards * K * B * 8 * 2
    print(f"   naive pjit top-k all-gathers the scores: {naive/1e6:.1f} MB")
    print(f"   local-topk union all-gather:             {ours/1e6:.3f} MB "
          f"({naive/ours:.0f}x less collective traffic)")


if __name__ == "__main__":
    main()
