"""End-to-end training driver: ~100M-param LM for a few hundred steps on CPU,
with checkpointing, fault-tolerant resume, and straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--params 100]

(--params 100 builds the ~100M config; the default driver uses ~8M so the
example completes in minutes on 1 CPU core. Both run the same stack.)
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.data.loader import LMDataConfig, SyntheticLMStream
from repro.dist.sharding import default_rules
from repro.models import transformer as T
from repro.models.layers import LMConfig
from repro.train.loop import TrainLoopConfig, Trainer
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def build(params_m: int):
    if params_m >= 100:
        # ~101M params: 12L x d512 (GQA 8/4) x ff2048, vocab 32k
        return LMConfig(name="lm100m", n_layers=12, d_model=512, n_heads=8,
                        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_768,
                        dtype=jnp.float32, q_chunk=128, remat=False)
    # ~8M params: fast CPU demo, same code path
    return LMConfig(name="lm8m", n_layers=4, d_model=192, n_heads=6,
                    n_kv_heads=2, head_dim=32, d_ff=768, vocab=8_192,
                    dtype=jnp.float32, q_chunk=64, remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", type=int, default=8, help="M params (8|100)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build(args.params)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    print(f"== {cfg.name}: {cfg.n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    params = T.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg, rules)
        params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    stream = SyntheticLMStream(
        LMDataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq))
    ckpt_dir = tempfile.mkdtemp(prefix="flexvec_lm_")
    trainer = Trainer(
        jax.jit(step_fn), params, opt, stream,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=50, log_every=10,
                        ckpt_dir=ckpt_dir),
        to_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    resumed = trainer.try_resume()
    print(f"== resume from checkpoint: {resumed}")
    with mesh:
        out = trainer.run()
    for h in out["history"]:
        print(f"   step {h['step']:>4}  loss {h['loss']:.4f}  "
              f"{h['sec_per_step']*1e3:7.1f} ms/step"
              + ("  [straggler]" if h["straggler"] else ""))
    print(f"== final loss {out['final_loss']:.4f} "
          f"(start {out['history'][0]['loss']:.4f}); "
          f"straggler events: {len(out['straggler_events'])}; "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
