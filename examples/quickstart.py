"""Quickstart: build a small corpus, search it with PEM via plain SQL.

    PYTHONPATH=src python examples/quickstart.py
"""

import sqlite3

from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.serve.retrieval import RetrievalService

NOW = 1_770_000_000.0


def main() -> None:
    print("== building a 20k-chunk session-history corpus ...")
    emb = HashEmbedder(128)
    chunks = generate_corpus(n_chunks=20_000, n_sessions=400, seed=0, now=NOW)
    conn = sqlite3.connect(":memory:")
    build_database(conn, chunks, emb)
    svc = RetrievalService(conn, dim=128, embedder=emb, now=NOW)

    print("\n== @orient — the agent's first call (schema discovery)")
    res = svc.flex_search("@orient")
    for section, data in res.rows:
        if section == "shape":
            print("  shape:", data["rows"])

    print("\n== Phase 1+2+3 in one SQL statement (suppression case study)")
    res = svc.flex_search("""
        SELECT v.id, v.score, substr(m.content, 1, 48) AS preview
        FROM vec_ops(
         'similar:how the system works architecture
          diverse
          suppress:website landing page design tagline
          suppress:documentation readme community post',
         'SELECT id FROM messages
          WHERE type = ''assistant'' AND length(content) > 300') v
        JOIN messages m ON v.id = m.id
        ORDER BY v.score DESC LIMIT 5
    """)
    for row in res.rows:
        print(f"  id={row[0]:>6}  score={row[1]:+.3f}  {row[2]}")
    print(f"  ({res.latency_ms:.1f} ms end-to-end)")

    print("\n== hybrid fusion: one call, lexical + vector fused on device")
    res = svc.flex_search("""
        SELECT id, score, snippet FROM HYBRID_SEARCH('server lifecycle', 0.6)
        ORDER BY score DESC LIMIT 3
    """)
    for row in res.rows:
        print(f"  id={row[0]:>6}  fused={row[1]:+.3f}  {row[2][:48]}")

    print("\n== the same fusion as grammar tokens (full PEM stack available)")
    res = svc.flex_search("""
        SELECT id, score FROM vec_ops(
         'similar:server lifecycle debugging
          keyword:server restart fuse:weighted,0.6 decay:30')
        ORDER BY score DESC LIMIT 3
    """)
    for row in res.rows:
        print(f"  id={row[0]:>6}  fused={row[1]:+.3f}")

    print("\n== intersection JOIN: keyword AND semantic must both match")
    res = svc.flex_search("""
        SELECT k.id, k.score, v.score FROM keyword('server') k
        JOIN vec_ops('similar:server lifecycle debugging') v ON k.id = v.id
        ORDER BY v.score DESC LIMIT 3
    """)
    for row in res.rows:
        print(f"  id={row[0]:>6}  bm25={row[1]:.2f}  cosine={row[2]:+.3f}")

    print("\n== explicit error -> agent rewrites and retries (paper §7)")
    bad = svc.flex_search("SELECT v.id FROM vec_ops('decay:not_a_number') v")
    print(f"  error: {bad.error}")
    good = svc.flex_search(
        "SELECT v.id FROM vec_ops('similar:retry decay:7') v LIMIT 1")
    print(f"  retry ok: {good.ok}")


if __name__ == "__main__":
    main()
