"""End-to-end serving driver: pipelined batched PEM retrieval under load.

Simulates a fleet of agents issuing modulated queries against one corpus;
the engine micro-batches them into fused (d, B) scoring panels (the TPU
kernel's layout) and PIPELINES successive batches — the host MMR tail of
batch i overlaps the device scoring pass of batch i+1.  Reports
throughput, latency percentiles, and the scheduler's overlap counter.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import concurrent.futures as cf
import time

import numpy as np

from repro.core.vectorcache import VectorCache
from repro.data.corpus import generate_corpus
from repro.embed import HashEmbedder
from repro.serve.engine import BatchedRetrievalEngine

NOW = 1_770_000_000.0
N_CHUNKS = 100_000
N_REQUESTS = 256


def main() -> None:
    print(f"== embedding a {N_CHUNKS}-chunk corpus ...")
    emb = HashEmbedder(128)
    chunks = generate_corpus(n_chunks=N_CHUNKS, n_sessions=1000, seed=0, now=NOW)
    matrix = emb.embed_batch([c.content for c in chunks])
    cache = VectorCache(
        np.array([c.id for c in chunks]), matrix,
        np.array([c.created_at for c in chunks]), emb,
    )
    engine = BatchedRetrievalEngine(cache, max_batch=32, max_wait_ms=3.0, now=NOW)

    topics = ["server lifecycle", "identity provenance", "rendering pipeline",
              "auth token refresh", "database schema migration"]
    queries = [
        f"similar:{topics[i % len(topics)]} diverse decay:30 "
        f"suppress:website landing page"
        for i in range(N_REQUESTS)
    ]

    print(f"== serving {N_REQUESTS} concurrent modulated queries ...")
    t0 = time.time()
    lats = []

    def client(q):
        t = time.perf_counter()
        results = engine.search(q, 10)
        lats.append((time.perf_counter() - t) * 1e3)
        assert len(results) == 10

    with cf.ThreadPoolExecutor(max_workers=32) as ex:
        list(ex.map(client, queries))
    wall = time.time() - t0
    stats = engine.stats()
    engine.close()

    lat = np.sort(np.asarray(lats))
    print(f"   throughput : {N_REQUESTS / wall:8.1f} queries/s")
    print(f"   wall time  : {wall*1e3:8.1f} ms for {N_REQUESTS} requests")
    print(f"   latency    : p50 {np.percentile(lat, 50):6.1f} ms   "
          f"p99 {np.percentile(lat, 99):6.1f} ms")
    print(f"   batches    : {stats['batches_served']} "
          f"(avg {stats['requests_served'] / stats['batches_served']:.1f} "
          f"queries/batch)")
    print(f"   pipeline   : {stats['overlapped_batches']} batches scored "
          f"while the previous host tail was still finishing")
    print("   (each batch = ONE corpus pass via the fused (d,B) panel — the")
    print("    pem_score kernel layout; see DESIGN.md §2.1)")


if __name__ == "__main__":
    main()
