"""Distributed execution: logical-axis sharding rules + sharded PEM top-k.

``sharding`` maps LOGICAL axis names (batch, heads, corpus, ...) to mesh
axes so model code never hard-codes a mesh layout; ``pem_sharded`` is the
two-stage (local top-k + union merge) distributed retrieval path;
``tuned`` holds the named rule variants the perf hillclimb selects;
``procgroup`` is the cross-PROCESS axis — per-shard segmented stores
behind a shard-replica router, merged with the same exact-union
contract (the million-chunk serving topology).
"""
