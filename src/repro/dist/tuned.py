"""Named sharding-rule variants (the perf hillclimb's tuning axis).

``default``       — FSDP x TP baseline (dist/sharding.py).
``corpus_all``    — flexvec corpus rows over EVERY mesh axis, not just
                    'data': scoring runs on all 256 chips instead of 16
                    (§Perf flexvec-1; 67M chunks -> 134 MB/chip).
``serve_weights`` — MoE expert-FFN columns over 'data' so serving weights
                    are fully resident (EP x TP), eliminating the per-step
                    FSDP all-gather during decode (§Perf qwen3-1).
"""

from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from repro.dist.sharding import ShardingRules, default_rules


def get_rules(name: str, mesh: Mesh) -> ShardingRules:
    """Resolve a rules variant by name for the given mesh."""
    base = default_rules(mesh)
    if name == "default":
        return base
    if name == "corpus_all":
        return _replace(base, corpus=tuple(mesh.axis_names))
    if name == "serve_weights":
        return _replace(base, moe_ff="data")
    raise KeyError(
        f"unknown rules variant {name!r}; known: default, corpus_all, serve_weights"
    )


def _replace(rules: ShardingRules, **updates) -> ShardingRules:
    merged = dict(rules.rules)
    merged.update(updates)
    return dataclasses.replace(rules, rules=merged)
