"""Cross-process shard groups: million-chunk retrieval on one box.

``repro.dist.pem_sharded`` distributes the PEM pass across jax mesh
devices inside ONE process.  This module is the other axis the paper's
production story needs: a :class:`ProcessGroup` that partitions the
corpus across OS processes (or threads, or inline workers), each shard
owning its own :class:`~repro.core.segments.SegmentedCorpusStore` — so
per-shard scoring-resident memory, not one process's host RAM, is the
binding constraint at 1M+ chunks.

Design:

* :class:`ShardWorker` — one shard replica.  Owns a segmented store plus
  a registered numpy backend and answers ``local_pass`` batches: the
  full segmented device pass (:func:`score_select_segments`, candidate
  mask panels, hybrid score bias) over ITS rows only, returning per-plan
  top-``width`` candidates in chunk-id space (plus pool embeddings for
  diverse plans).  Workers never import jax — the fused-numpy backend is
  pure BLAS, so a forked worker starts in milliseconds.
* ``dtype="f32b"`` workers score simple (no-filter, no-lexical) plans
  with a BLOCKED single-stream pass: cache-sized f32 row blocks hit one
  fused ``(d, 2B)`` query panel GEMM, so the corpus streams from RAM
  ONCE per query instead of once per direction — the latency win the
  ``scale_1m`` bench records (the sub-packing-threshold GEMM kernel also
  skips OpenBLAS's A-matrix packing copy).  ``dtype="bf16"`` workers
  instead keep a packed :func:`~repro.core.segments.pack_bf16` code
  matrix of their live rows and run the same blocked pass through a
  decode step — HALF the resident scoring bytes, the right trade where
  memory bandwidth (not elementwise decode throughput) is the binding
  constraint.  Filtered / hybrid plans fall back to the exact f32 path
  on both.
* :class:`ProcessGroup` — the coordinator/router.  Fans a batch of plans
  out to one replica per shard, then merges with the SAME exact-union
  contract as ``union_merge_topk``: every shard's local top-``width``
  provably contains its share of the global top-``width``, and the merge
  re-sorts by ``(score desc, global insertion rank asc)`` — the
  insertion rank IS the monolithic store's row order (absent
  compaction), so the merged ranking, tie order included, is
  bit-identical to a monolithic fused-numpy
  :meth:`~repro.core.vectorcache.VectorCache.search_plan` over the same
  rows (pinned in tests/test_procgroup.py).  Diverse plans merge their
  oversample pools and finish with the :func:`mmr_host` oracle at the
  coordinator; ``fuse:rrf`` fuses at the coordinator exactly like
  :func:`finalize_fusion`.

One honest caveat about "bit-identical": BLAS GEMM scores the last
``n mod M_block`` rows of a matrix with a tail microkernel whose
accumulation order differs from the full-block kernel by 1-2 ulp, so a
row's score bits depend (only) on whether it lands in a full M-block.
Full-block rows are bit-stable under ANY row partition — verified
empirically: random row subsets reproduce the full pass exactly whenever
the subset count is block-aligned.  Per-shard scores therefore match the
monolith exactly when every sealed slice's row count is a multiple of
the M-block (32 covers the common kernels); otherwise up to
``M_block - 1`` tail rows per sealed matrix may differ in the last ulp —
rankings agree except for those rows' boundary ties.  The parity suite
pins the aligned contract; at million-chunk scale slices are block-sized
anyway.  The same ulp effect is why the selectivity router's gather path
(a tiny scratch matrix) is only ulp-close, not bit-equal, to the masked
path.

Transports: ``inline`` (serial in-process calls — the deterministic
default for tests), ``thread`` (one fan-out thread per replica; BLAS
releases the GIL, so shards genuinely overlap and nothing is copied),
``process`` (one OS process per replica, fork-preferred, length-prefixed
pickle over a ``multiprocessing.Pipe``).  The merge math is transport-
independent; parity suites run the same cases across all three.
"""

from __future__ import annotations

import concurrent.futures as cf
import functools
import multiprocessing as mp
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import modulations as M
from repro.core.journal import StoreJournal
from repro.core.backends import (fusion_bias_arrays, get_backend, mmr_host,
                                 score_select_segments, selection_width,
                                 top_idx)
from repro.core.segments import (SECONDS_PER_DAY, SegmentedCorpusStore,
                                 gather_ids, gather_rows, pack_bf16,
                                 unpack_bf16)

__all__ = ["ShardWorker", "ProcessGroup"]

_TRANSPORTS = ("inline", "thread", "process")
_DTYPES = ("f32", "f32b", "bf16")

# blocked-pass row-block defaults: f32b wants L2-resident blocks (the
# small-kernel GEMM never packs, so the only traffic is the one stream);
# bf16 amortizes its decode scratch over bigger blocks
_BLOCK_DEFAULTS = {"f32b": 1536, "bf16": 16384, "f32": 16384}


class ShardWorker:
    """One shard replica: a segmented store + a numpy scoring backend.

    ``local_pass`` is the whole per-shard pipeline — candidate mask
    panel, hybrid bias scatter, fused score->select, exact per-segment
    union merge — restricted to this shard's rows, so the coordinator's
    cross-shard merge composes with the intra-shard one the same way
    ``union_merge_topk`` composes across devices.
    """

    def __init__(
        self,
        shard_id: int,
        dim: int,
        *,
        engine: str = "fused-numpy",
        dtype: str = "f32",
        block: Optional[int] = None,
        replica: int = 0,
        journal_dir: Optional[str] = None,
        fsync: bool = True,
    ) -> None:
        if dtype not in _DTYPES:
            raise ValueError(f"dtype must be one of {_DTYPES}, got {dtype!r}")
        self.shard_id = int(shard_id)
        self.replica = int(replica)
        if journal_dir is not None:
            # each replica owns its own journal subdir, so every replica
            # recovers its shard slice independently after a crash
            self.store = SegmentedCorpusStore.open(
                os.path.join(journal_dir,
                             f"shard{self.shard_id}-r{self.replica}"),
                dim, fsync=fsync)
        else:
            self.store = SegmentedCorpusStore(dim)
        self.backend = get_backend(engine)
        self.dtype = dtype
        self.block = int(block) if block else _BLOCK_DEFAULTS[dtype]
        self.passes = 0
        self.last_pass_ms = 0.0
        self.total_pass_ms = 0.0
        # one blocked pass = ONE trip of this shard's corpus through RAM,
        # whether it served one query or a whole cohort — the counter the
        # cohort-throughput scenario pins (Q queries, one stream)
        self.corpus_streams = 0
        self.cohort_passes = 0   # blocked passes that served >1 plan
        self.cohort_plans = 0    # plans served by those cohort passes
        # (store version, codes, global rows, timestamps) — rebuilt lazily
        # on mutation, like the VectorCache live view
        self._packed: Optional[Tuple] = None
        # the f32b analogue: (version, f32 live rows, global rows, ts)
        self._livef32: Optional[Tuple] = None

    # -- mutations ------------------------------------------------------------

    def append(
        self,
        ids: np.ndarray,
        matrix: np.ndarray,
        timestamps: Optional[np.ndarray] = None,
        *,
        normalized: bool = False,
    ) -> int:
        """Seal this shard's slice of a group append; returns live rows."""
        self.store.append(ids, matrix, timestamps, normalized=normalized)
        return self.store.n_live

    def delete(self, ids: Sequence[int]) -> int:
        return self.store.delete(ids)

    def compact(self, min_live_fraction: float = 1.0) -> int:
        return self.store.compact(min_live_fraction)

    # -- durability -----------------------------------------------------------

    def live_ids(self) -> np.ndarray:
        """This replica's live chunk ids (coordinator reconciliation)."""
        with self.store.lock:
            segs = self.store.segments
            if not segs:
                return np.empty(0, dtype=np.int64)
            return np.concatenate([s.ids[s.live_mask] for s in segs])

    def checkpoint(self) -> int:
        """Snapshot + rotate this replica's journal (no-op unjournaled)."""
        if self.store.journal is None:
            return 0
        self.store.checkpoint()
        return self.store.checkpoints

    def close(self) -> None:
        if self.store.journal is not None:
            self.store.journal.close()

    # -- scoring --------------------------------------------------------------

    def local_pass(
        self,
        plans: Sequence[M.ModulationPlan],
        ks: Sequence[int],
        now: float,
        candidate_sets: Optional[Sequence[Optional[np.ndarray]]] = None,
    ) -> List[Dict[str, Any]]:
        """Score ``plans`` over this shard; per-plan top-``width`` results.

        Returns one dict per plan: ``ids`` (chunk ids, merged local
        order), ``scores`` (descending, local ties by row order),
        ``elig`` (this shard's eligible-row count for the plan — the
        coordinator sums these to pin global selection widths exactly),
        and for diverse plans ``pool`` (the f32 pool embeddings, row-
        aligned with ``ids``, for the coordinator's ``mmr_host`` finish).
        """
        t0 = time.perf_counter()
        nplans = len(plans)
        with self.store.lock:
            segs = self.store.segments
            panels = None
            if candidate_sets is not None and any(
                    c is not None for c in candidate_sets):
                panels, _ = self.store.candidate_mask_panel(
                    candidate_sets, segs)
            elig = np.zeros(nplans, dtype=np.int64)
            if panels is None:
                elig[:] = sum(s.live_count for s in segs)
            else:
                for panel in panels:
                    if panel is not None:
                        elig += np.count_nonzero(panel, axis=0)
            if self._fast_ok(plans, panels):
                sel = self._fast_pass(segs, plans, ks, now)
            else:
                bias = fusion_bias_arrays(self.store, segs, plans)
                sel = score_select_segments(
                    self.backend, segs, plans, ks, now=now,
                    candidate_masks=panels, score_bias=bias)
        out: List[Dict[str, Any]] = []
        for j, ((gidx, gv), plan) in enumerate(zip(sel, plans)):
            entry: Dict[str, Any] = {
                "ids": gather_ids(segs, gidx),
                "scores": np.asarray(gv, dtype=np.float32),
                "elig": int(elig[j]),
            }
            if plan.diverse is not None:
                entry["pool"] = (gather_rows(segs, gidx) if gidx.size else
                                 np.zeros((0, self.store.dim), np.float32))
            out.append(entry)
        dt = (time.perf_counter() - t0) * 1e3
        self.passes += 1
        self.last_pass_ms = dt
        self.total_pass_ms += dt
        return out

    def _fast_ok(self, plans, panels) -> bool:
        """The blocked pass serves only the plain shapes (no Phase-1
        panel, no lexical bias); everything else takes the exact f32
        path off the same store."""
        return (self.dtype in ("f32b", "bf16") and panels is None
                and all(p.lexical is None for p in plans))

    def _packed_view(self, segs):
        """(codes, global_rows, timestamps) over this shard's LIVE rows,
        cached per store version — the bf16 analogue of the live view."""
        ver = self.store.version
        if self._packed is not None and self._packed[0] == ver:
            return self._packed[1:]
        codes_parts: List[np.ndarray] = []
        row_parts: List[np.ndarray] = []
        ts_parts: List[np.ndarray] = []
        has_ts = bool(segs) and segs[0].timestamps is not None
        off = 0
        for s in segs:
            if s.n_rows and s.live_count:
                if s.n_dead:
                    live = np.flatnonzero(s.live_mask)
                    codes_parts.append(pack_bf16(s.matrix[live]))
                    if has_ts:
                        ts_parts.append(s.timestamps[live])
                else:
                    live = np.arange(s.n_rows, dtype=np.int64)
                    codes_parts.append(pack_bf16(s.matrix))
                    if has_ts:
                        ts_parts.append(s.timestamps)
                row_parts.append(live + off)
            off += s.n_rows
        if codes_parts:
            codes = np.concatenate(codes_parts)
            rows = np.concatenate(row_parts)
            ts = np.concatenate(ts_parts) if has_ts else None
        else:
            codes = np.zeros((0, self.store.dim), dtype=np.uint16)
            rows = np.zeros(0, dtype=np.int64)
            ts = None
        self._packed = (ver, codes, rows, ts)
        return codes, rows, ts

    def _live_view(self, segs):
        """(f32 rows, global rows, timestamps) over this shard's LIVE
        rows, cached per store version — the ``f32b`` blocked pass's
        input.  The common shape (one sealed slice, no tombstones) is a
        zero-copy view of the segment matrix; multi-segment or
        tombstoned shards pay one gather per store version."""
        ver = self.store.version
        if self._livef32 is not None and self._livef32[0] == ver:
            return self._livef32[1:]
        mat_parts: List[np.ndarray] = []
        row_parts: List[np.ndarray] = []
        ts_parts: List[np.ndarray] = []
        has_ts = bool(segs) and segs[0].timestamps is not None
        off = 0
        for s in segs:
            if s.n_rows and s.live_count:
                if s.n_dead:
                    live = np.flatnonzero(s.live_mask)
                    mat_parts.append(s.matrix[live])
                    if has_ts:
                        ts_parts.append(s.timestamps[live])
                else:
                    live = np.arange(s.n_rows, dtype=np.int64)
                    mat_parts.append(s.matrix)
                    if has_ts:
                        ts_parts.append(s.timestamps)
                row_parts.append(live + off)
            off += s.n_rows
        if not mat_parts:
            mat = np.zeros((0, self.store.dim), dtype=np.float32)
            rows = np.zeros(0, dtype=np.int64)
            ts = None
        elif len(mat_parts) == 1:  # np.concatenate always copies
            mat, rows = mat_parts[0], row_parts[0]
            ts = ts_parts[0] if has_ts else None
        else:
            mat = np.concatenate(mat_parts)
            rows = np.concatenate(row_parts)
            ts = np.concatenate(ts_parts) if has_ts else None
        self._livef32 = (ver, mat, rows, ts)
        return mat, rows, ts

    def _fast_pass(self, segs, plans, ks, now):
        """Blocked single-stream pass over the live rows: ONE trip of the
        corpus through RAM serves every plan in the call.

        Q == 1 keeps the original shape — one ``(d, 2)`` panel GEMM per
        cache-resident block (pre column scaled by decay, plus the sup
        column).  Q > 1 is COHORT mode: the block loop moves outermost
        and every plan scores the SAME resident block with its own
        ``(d, 2)`` panel before the stream advances, so the corpus
        streams from RAM once per cohort instead of once per query.  The
        cohort deliberately does NOT widen the GEMM to ``(d, 2Q)``: BLAS
        per-column bits depend on the panel width (and on ragged tail
        shapes), so a wide panel could not be bit-identical to the
        serial pass — reordering the loops keeps every plan's GEMM call
        (operand shapes, block boundaries, accumulation order) exactly
        the serial pass's, which is what makes cohort rankings
        bit-identical to Q serial queries.  The block is L2-resident, so
        plan 2..Q hit cache, not RAM.  ``bf16`` decodes each packed
        block into the f32 scratch ONCE per cohort (decode amortizes
        across Q the same way the stream does)."""
        if self.dtype == "bf16":
            codes, rows, ts = self._packed_view(segs)
            n = int(codes.shape[0])
        else:
            mat, rows, ts = self._live_view(segs)
            n = int(mat.shape[0])
        nplans = len(plans)
        empty = (np.empty(0, np.int64), np.empty(0, np.float32))
        if n == 0:
            return [empty for _ in plans]
        days = None
        if any(p.decay is not None for p in plans):
            if ts is None:
                raise ValueError(
                    "decay: modulation requires per-chunk timestamps")
            days = np.maximum(
                (now - ts) / SECONDS_PER_DAY, 0.0).astype(np.float32)
        q_pre, q_sup = M.fold_plans(plans)
        block = max(1, self.block)
        scratch = (np.empty((min(block, n), self.store.dim), dtype=np.uint32)
                   if self.dtype == "bf16" else None)
        self.corpus_streams += 1  # one stream serves the whole call
        if nplans == 1:
            plan0 = plans[0]
            qcat = np.ascontiguousarray(
                np.concatenate([q_pre, q_sup], axis=1), dtype=np.float32)
            col1 = np.empty(n, dtype=np.float32)
            for s in range(0, n, block):
                e = min(n, s + block)
                f = (unpack_bf16(codes[s:e], out=scratch[: e - s])
                     if scratch is not None else mat[s:e])
                res = f @ qcat
                out = res[:, 0]
                if plan0.decay is not None:
                    out *= 1.0 / (
                        1.0 + days[s:e] / plan0.decay.half_life_days)
                out += res[:, 1]
                col1[s:e] = out
            cols = [col1]
        else:
            self.cohort_passes += 1
            self.cohort_plans += nplans
            # per-plan contiguous (d, 2) panels — pairs[j] is exactly the
            # qcat the serial pass would build for plan j alone
            pairs = np.ascontiguousarray(
                np.stack([q_pre.T, q_sup.T], axis=2), dtype=np.float32)
            # the decay factor column is shared within a half-life group,
            # so the combine vectorizes across the whole cohort in the
            # common uniform-half-life case and degrades to per-plan rows
            # only for genuinely mixed cohorts
            hl_groups: Dict[Optional[float], List[int]] = {}
            for j, p in enumerate(plans):
                hl = (None if p.decay is None
                      else float(p.decay.half_life_days))
                hl_groups.setdefault(hl, []).append(j)
            bm = min(block, n)
            rb = np.empty((nplans, bm, 2), dtype=np.float32)
            tmp = np.empty((nplans, bm), dtype=np.float32)
            # plan-major scores: per-plan top-k reads a contiguous row
            # instead of paying a strided copy per column
            scores = np.empty((nplans, n), dtype=np.float32)
            for s in range(0, n, block):
                e = min(n, s + block)
                m = e - s
                f = (unpack_bf16(codes[s:e], out=scratch[:m])
                     if scratch is not None else mat[s:e])
                for j in range(nplans):
                    np.matmul(f, pairs[j], out=rb[j, :m])
                pre, sup = rb[:, :m, 0], rb[:, :m, 1]
                out = scores[:, s:e]
                for hl, js in hl_groups.items():
                    if hl is None:
                        for j in js:
                            np.add(pre[j], sup[j], out=out[j])
                        continue
                    dec = 1.0 / (1.0 + days[s:e] / hl)
                    if len(js) == nplans:
                        np.multiply(pre, dec, out=tmp[:, :m])
                        np.add(tmp[:, :m], sup, out=out)
                    else:
                        for j in js:
                            np.multiply(pre[j], dec, out=tmp[j, :m])
                            np.add(tmp[j, :m], sup[j], out=out[j])
            cols = list(scores)
        sel = []
        for j, (plan, k) in enumerate(zip(plans, ks)):
            w = selection_width(plan, min(int(k), n), n)
            if w == 0:
                sel.append(empty)
                continue
            col = cols[j]
            idx = top_idx(col, w)
            sel.append((rows[idx], col[idx]))
        return sel

    # -- introspection --------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Per-shard memory + latency row (``ProcessGroup.stats()``)."""
        st = self.store.stats()
        matrix_bytes = sum(s.matrix.nbytes for s in self.store.segments)
        codes_bytes = (int(self._packed[1].nbytes)
                       if self._packed is not None else 0)
        if self.dtype == "f32b" and self._livef32 is not None:
            scoring_bytes = int(self._livef32[1].nbytes)
        elif self.dtype == "bf16" and codes_bytes:
            scoring_bytes = codes_bytes
        else:
            scoring_bytes = int(matrix_bytes)
        out = {
            "shard": self.shard_id,
            "dtype": self.dtype,
            "rows": st["rows"],
            "live": st["live"],
            "segments": st["segments"],
            "matrix_bytes": int(matrix_bytes),
            "codes_bytes": codes_bytes,
            # what a scoring pass actually streams: the packed codes for
            # a warm bf16 worker, the (usually zero-copy) live f32 view
            # for f32b, the f32 segment matrices otherwise
            "scoring_bytes": scoring_bytes,
            "passes": self.passes,
            "last_pass_ms": round(self.last_pass_ms, 3),
            "total_pass_ms": round(self.total_pass_ms, 3),
            "corpus_streams": self.corpus_streams,
            "cohort_passes": self.cohort_passes,
            "cohort_plans": self.cohort_plans,
        }
        for key in ("checkpoints", "recovered_records", "journal_bytes"):
            if key in st:
                out[key] = st[key]
        return out


# -- transports ---------------------------------------------------------------


class _LocalClient:
    """In-process replica (the ``inline`` and ``thread`` transports —
    thread parallelism lives in the group's fan-out pool, not here)."""

    def __init__(self, shard_id: int, replica: int, dim: int,
                 opts: Dict[str, Any]) -> None:
        self.worker = ShardWorker(shard_id, dim, replica=replica, **opts)

    def call(self, method: str, *args, **kwargs):
        return getattr(self.worker, method)(*args, **kwargs)

    def close(self) -> None:
        self.worker.close()


def _worker_loop(conn, shard_id: int, replica: int, dim: int,
                 opts: Dict[str, Any]) -> None:
    """Child-process server: one ShardWorker, pickle-RPC over a Pipe.
    Never imports jax — the numpy backends resolve without it."""
    worker = ShardWorker(shard_id, dim, replica=replica, **opts)
    try:
        while True:
            msg = conn.recv()
            if msg is None:
                break
            method, args, kwargs = msg
            try:
                conn.send((True, getattr(worker, method)(*args, **kwargs)))
            except Exception as e:  # ship the failure, keep serving
                conn.send((False, f"{type(e).__name__}: {e}"))
    except (EOFError, KeyboardInterrupt):
        pass
    finally:
        worker.close()
        conn.close()


class _ProcessClient:
    """One OS-process replica behind a Pipe (fork-preferred: the corpus
    arrays and imported modules are shared copy-on-write at start)."""

    def __init__(self, shard_id: int, replica: int, dim: int,
                 opts: Dict[str, Any]) -> None:
        method = ("fork" if "fork" in mp.get_all_start_methods()
                  else mp.get_start_method(allow_none=False))
        ctx = mp.get_context(method)
        self._conn, child = ctx.Pipe()
        self._proc = ctx.Process(
            target=_worker_loop, args=(child, shard_id, replica, dim, opts),
            daemon=True)
        self._proc.start()
        child.close()
        self._lock = threading.Lock()  # one in-flight RPC per replica

    def call(self, method: str, *args, **kwargs):
        with self._lock:
            self._conn.send((method, args, kwargs))
            ok, res = self._conn.recv()
        if not ok:
            raise RuntimeError(f"shard worker failed: {res}")
        return res

    def close(self) -> None:
        try:
            with self._lock:
                self._conn.send(None)
            self._proc.join(timeout=5.0)
        except (OSError, ValueError):
            pass
        finally:
            try:
                self._conn.close()
            except OSError:
                pass
            if self._proc.is_alive():
                self._proc.terminate()


# -- the coordinator ----------------------------------------------------------


class ProcessGroup:
    """Shard-replica router: partition, fan out, merge exactly.

    Rows are dealt round-robin across ``n_shards`` at append time (so any
    append pattern stays balanced) and every id's GLOBAL insertion rank
    is recorded — that rank is the monolithic store's row order, which is
    the monolithic merge's tie rule, so the coordinator's
    ``lexsort((ranks, -scores))`` reproduces the monolithic stable sort
    bit for bit.  ``replicas`` > 1 keeps identical copies of every shard
    and round-robins queries across them (each replica applies every
    mutation, so any replica can serve any query).

    Exactness contract (the cross-shard analogue of ``union_merge_topk``):
    each shard returns its top-``min(width, local_eligible)`` candidates,
    the merged valid count is therefore exactly ``min(width,
    total_eligible)``, and diverse pools finish with the same
    :func:`mmr_host` oracle / ``fuse:rrf`` with the same
    :func:`finalize_fusion` recipe the monolithic host tail runs.
    Shard-local compaction is allowed but may reorder exact ties at the
    selection-width boundary relative to a never-compacted monolith (the
    parity suites pin the uncompacted contract).
    """

    def __init__(
        self,
        dim: int,
        n_shards: int = 4,
        *,
        replicas: int = 1,
        transport: str = "inline",
        dtype: str = "f32",
        engine: str = "fused-numpy",
        block: Optional[int] = None,
        journal_dir: Optional[str] = None,
        fsync: bool = True,
    ) -> None:
        if transport not in _TRANSPORTS:
            raise ValueError(
                f"transport must be one of {_TRANSPORTS}, got {transport!r}")
        if n_shards < 1 or replicas < 1:
            raise ValueError("n_shards and replicas must be >= 1")
        self.dim = int(dim)
        self.n_shards = int(n_shards)
        self.replicas = int(replicas)
        self.transport = transport
        self.dtype = dtype
        self.journal_dir = None if journal_dir is None else str(journal_dir)
        opts = {"engine": engine, "dtype": dtype, "block": block}
        if self.journal_dir is not None:
            os.makedirs(self.journal_dir, exist_ok=True)
            opts["journal_dir"] = self.journal_dir
            opts["fsync"] = fsync
        mk = _ProcessClient if transport == "process" else _LocalClient
        self._clients = [[mk(s, r, dim, opts) for r in range(self.replicas)]
                         for s in range(self.n_shards)]
        self._pool = (None if transport == "inline" else cf.ThreadPoolExecutor(
            self.n_shards * self.replicas,
            thread_name_prefix="flexvec-shard"))
        self._rank: Dict[int, int] = {}      # id -> global insertion order
        self._shard_of: Dict[int, int] = {}  # LIVE id -> owning shard
        self._row_counter = 0
        self._has_ts: Optional[bool] = None
        self._rr = 0
        self._lock = threading.Lock()
        self.searches = 0
        self.last_fanout_ms = 0.0
        self.last_merge_ms = 0.0
        # replica-aware failover: a replica whose TRANSPORT dies (pipe
        # EOF/OSError — not an application error, which propagates) is
        # marked dead and the call retries the shard's survivors;
        # ``failovers`` counts query calls served by a non-preferred
        # replica because the preferred one was (or just went) dead
        self._dead = [[False] * self.replicas for _ in range(self.n_shards)]
        self._fail_lock = threading.Lock()
        self.failovers = 0
        self._closed = False
        # coordinator journal: group-level append/delete records (row ->
        # shard routing + insertion ranks) so open() rebuilds the merge
        # bookkeeping without rescanning every shard
        self.journal = (None if self.journal_dir is None else StoreJournal(
            os.path.join(self.journal_dir, "coordinator"), fsync=fsync))
        self.checkpoints = 0
        self.recovered_records = 0
        self.reconciled_drops = 0
        if self.journal is not None:
            self._recover()

    # -- lifecycle ------------------------------------------------------------

    @classmethod
    def build(
        cls,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
        **kwargs,
    ) -> "ProcessGroup":
        """Group over an existing corpus (the serve-layer attach path)."""
        matrix = np.asarray(matrix, dtype=np.float32)
        group = cls(dim=matrix.shape[1] if matrix.ndim == 2 else 0, **kwargs)
        group.append(ids, matrix, timestamps, normalized=normalized)
        return group

    @classmethod
    def open(cls, journal_dir: str, dim: int, **kwargs) -> "ProcessGroup":
        """Recover a journaled group: every shard replica reopens its
        store from its own journal subdir, the coordinator replays its
        group-level journal to rebuild the routing/rank maps, and rows
        caught in the crash window (fanned out but never coordinator-
        acknowledged, or the reverse for deletes) are reconciled away.
        ``n_shards``/``replicas``/``dtype`` must match the writer's."""
        return cls(dim, journal_dir=journal_dir, **kwargs)

    def _recover(self) -> None:
        """Coordinator recovery: snapshot + delta replay, then reconcile
        the routing maps against what the shard stores actually hold.

        The acknowledgement order is shards-first (each worker journals
        WAL-first inside its own ``append``), coordinator journal second.
        So after a crash either side may be ahead by one un-acked
        mutation; the coordinator journal is the source of truth for what
        was ACKED, and both directions converge to it:

        * a row live on a shard but absent from the coordinator map was
          never acknowledged -> tombstone it on that replica;
        * a row the coordinator maps but some replica lacks was hit by an
          un-acked delete -> drop it from the map (and from any replica
          that still holds it, via the same orphan pass).
        """
        snap = self.journal.load_snapshot()
        if snap is not None:
            self._rank = {int(k): int(v) for k, v in snap["rank"].items()}
            self._shard_of = {int(k): int(v)
                              for k, v in snap["shard_of"].items()}
            self._row_counter = int(snap["row_counter"])
            self._has_ts = snap["has_ts"]
        after = int(snap["seq"]) if snap is not None else -1
        records = list(self.journal.replay(after_seq=after))
        self.journal.truncate_torn_tail()
        for rec in records:
            p = rec.payload
            if rec.kind == "group_append":
                base = int(p["base"])
                for j, (cid, s) in enumerate(zip(p["ids"], p["shards"])):
                    self._rank[int(cid)] = base + j
                    self._shard_of[int(cid)] = int(s)
                self._row_counter = max(self._row_counter,
                                        base + len(p["ids"]))
                self._has_ts = bool(p["has_ts"])
            elif rec.kind == "group_delete":
                for cid in p["ids"]:
                    self._shard_of.pop(int(cid), None)
        self.recovered_records = len(records)
        # reconcile: coordinator map vs the recovered shard stores
        coord: List[Set[int]] = [set() for _ in range(self.n_shards)]
        for cid, s in self._shard_of.items():
            coord[s].add(cid)
        live = [[{int(i) for i in self._clients[s][r].call("live_ids")}
                 for r in range(self.replicas)]
                for s in range(self.n_shards)]
        ghosts: Set[int] = set()
        for s in range(self.n_shards):
            for r in range(self.replicas):
                ghosts |= coord[s] - live[s][r]
        for cid in ghosts:
            self._shard_of.pop(cid, None)
        dropped: Set[int] = set(ghosts)
        for s in range(self.n_shards):
            keep = coord[s] - ghosts
            for r in range(self.replicas):
                orphans = live[s][r] - keep
                if orphans:
                    dropped |= orphans
                    self._clients[s][r].call(
                        "delete",
                        np.asarray(sorted(orphans), dtype=np.int64))
        self.reconciled_drops = len(dropped)

    def checkpoint(self) -> int:
        """Snapshot the coordinator maps AND every shard replica's store,
        rotating all journals — the next :meth:`open` replays only the
        records written since.  Returns coordinator checkpoints so far."""
        if self.journal is None:
            return 0
        calls = [functools.partial(self._mutation_call, s, r, "checkpoint")
                 for s in range(self.n_shards)
                 for r in range(self.replicas)]
        self._fanout(calls)
        with self._lock:
            self.journal.write_snapshot({
                "rank": dict(self._rank),
                "shard_of": dict(self._shard_of),
                "row_counter": self._row_counter,
                "has_ts": self._has_ts,
            })
            self.checkpoints += 1
        return self.checkpoints

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._pool is not None:
            self._pool.shutdown(wait=False)
        if self.journal is not None:
            self.journal.close()
        for row in self._clients:
            for client in row:
                client.close()

    def __enter__(self) -> "ProcessGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- corpus mutations -----------------------------------------------------

    @property
    def n_live(self) -> int:
        return len(self._shard_of)

    def append(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
    ) -> int:
        """Deal rows round-robin across shards (every replica appends its
        shard's slice); rows keep their global insertion rank."""
        ids_arr = np.asarray(ids, dtype=np.int64)
        matrix = np.asarray(matrix, dtype=np.float32)
        if matrix.ndim != 2 or matrix.shape[0] != ids_arr.shape[0]:
            raise ValueError(
                f"matrix shape {matrix.shape} inconsistent with "
                f"{len(ids_arr)} ids")
        if ids_arr.size == 0:
            return 0
        ts = (np.asarray(timestamps, dtype=np.float64)
              if timestamps is not None else None)
        if ts is not None and ts.shape[0] != ids_arr.shape[0]:
            raise ValueError("timestamps misaligned with ids")
        with self._lock:
            if self._has_ts is not None and self._has_ts != (ts is not None):
                raise ValueError(
                    "timestamp presence must match the existing group "
                    f"(group has timestamps: {self._has_ts})")
            uniq, counts = np.unique(ids_arr, return_counts=True)
            dupes = [int(i) for i in uniq[counts > 1]]
            dupes += [int(i) for i in ids_arr if int(i) in self._shard_of]
            if dupes:
                raise ValueError(
                    f"append: ids already live in the group: {dupes[:10]}"
                    + ("..." if len(dupes) > 10 else ""))
            shard = (self._row_counter
                     + np.arange(ids_arr.size, dtype=np.int64)) % self.n_shards
            calls = []
            for s in range(self.n_shards):
                rows = np.flatnonzero(shard == s)
                if rows.size == 0:
                    continue
                part = (ids_arr[rows], np.ascontiguousarray(matrix[rows]),
                        None if ts is None else ts[rows])
                for r in range(self.replicas):
                    calls.append(functools.partial(
                        self._mutation_call, s, r, "append", *part,
                        normalized=normalized))
            self._fanout(calls)
            # shards ack first (each worker journals WAL-first); the
            # coordinator record IS the group-level acknowledgement —
            # open() drops shard rows that never reached this line
            if self.journal is not None:
                self.journal.append_record("group_append", {
                    "ids": [int(i) for i in ids_arr],
                    "shards": [int(s_) for s_ in shard],
                    "base": int(self._row_counter),
                    "has_ts": ts is not None,
                })
            for j, cid in enumerate(ids_arr):
                self._rank[int(cid)] = self._row_counter + j
                self._shard_of[int(cid)] = int(shard[j])
            self._row_counter += int(ids_arr.size)
            self._has_ts = ts is not None
        return int(ids_arr.size)

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone ids on their owning shards (all replicas); returns
        rows newly tombstoned.  Unknown ids are ignored (non-strict)."""
        with self._lock:
            by_shard: Dict[int, List[int]] = {}
            for cid in ids:
                s = self._shard_of.get(int(cid))
                if s is not None:
                    by_shard.setdefault(s, []).append(int(cid))
            if not by_shard:
                return 0
            calls = []
            bases = []  # (shard, index of its first replica's result)
            for s, victims in by_shard.items():
                arr = np.asarray(victims, dtype=np.int64)
                bases.append(len(calls))
                for r in range(self.replicas):
                    calls.append(functools.partial(
                        self._mutation_call, s, r, "delete", arr))
            results = self._fanout(calls)
            if self.journal is not None:
                self.journal.append_record("group_delete", {
                    "ids": [cid for victims in by_shard.values()
                            for cid in victims]})
            for victims in by_shard.values():
                for cid in victims:
                    del self._shard_of[cid]
            # per shard: the first SURVIVING replica's count (dead
            # replicas return None)
            return int(sum(
                next((results[b + r] for r in range(self.replicas)
                      if results[b + r] is not None), 0)
                for b in bases))

    def compact(self, min_live_fraction: float = 1.0) -> int:
        """Shard-local GC on every replica; returns segments folded
        (first surviving replica per shard)."""
        calls = [functools.partial(self._mutation_call, s, r, "compact",
                                   min_live_fraction)
                 for s in range(self.n_shards)
                 for r in range(self.replicas)]
        results = self._fanout(calls)
        return int(sum(
            next((results[s * self.replicas + r]
                  for r in range(self.replicas)
                  if results[s * self.replicas + r] is not None), 0)
            for s in range(self.n_shards)))

    # -- search ---------------------------------------------------------------

    def search_plan(
        self,
        plan: M.ModulationPlan,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        k: Optional[int] = None,
    ) -> List[Tuple[int, float]]:
        """Single-plan mirror of ``VectorCache.search_plan`` (pool-width
        ranking unless ``k`` narrows it)."""
        ks = None if k is None else [k]
        (out,) = self.search_plan_batch(
            [plan], [candidate_ids], now=now, ks=ks)
        return out

    def search_plan_batch(
        self,
        plans: Sequence[M.ModulationPlan],
        candidate_sets: Optional[Sequence[Optional[Sequence[int]]]] = None,
        *,
        now: Optional[float] = None,
        ks: Optional[Sequence[int]] = None,
    ) -> List[List[Tuple[int, float]]]:
        """Fan a plan cohort out to one replica per shard, merge exactly.

        ``candidate_sets[j]`` is plan ``j``'s Phase-1 candidate id set
        (None = full corpus) — heterogeneous filters ride each shard's
        (n, B) mask panel, same as the batched engine.  ``ks[j]`` is the
        final candidate count (default ``min(plan.pool, n_live)``, the
        direct-path contract).
        """
        nplans = len(plans)
        ref = time.time() if now is None else now
        if candidate_sets is None:
            candidate_sets = [None] * nplans
        if len(candidate_sets) != nplans:
            raise ValueError("candidate_sets misaligned with plans")
        cands: List[Optional[np.ndarray]] = []
        for plan, c in zip(plans, candidate_sets):
            # fuse:filter promotes the lexical hit set to the Phase-1
            # candidate set, intersecting any SQL filter — identical to
            # the VectorCache.search_plan routing
            c = M.filter_candidate_ids(plan, c)
            if c is not None and not isinstance(c, np.ndarray):
                c = np.asarray(list(c), dtype=np.int64)
            cands.append(c)
        n_live = self.n_live
        ks_eff = ([min(p.pool, n_live) for p in plans] if ks is None
                  else [min(int(k), n_live) for k in ks])
        with self._lock:
            r = self._rr
            self._rr = (self._rr + 1) % self.replicas
        self.searches += 1
        t0 = time.perf_counter()
        # the whole plan cohort ships to ONE replica per shard in ONE RPC,
        # so each shard's corpus streams once per cohort (see _fast_pass);
        # a dead replica fails over to the shard's survivors
        calls = [functools.partial(self._call_failover, s, r, "local_pass",
                                   list(plans), ks_eff, ref, cands)
                 for s in range(self.n_shards)]
        parts = self._fanout(calls)
        t1 = time.perf_counter()
        self.last_fanout_ms = (t1 - t0) * 1e3

        results: List[List[Tuple[int, float]]] = []
        for j, (plan, k) in enumerate(zip(plans, ks_eff)):
            ids = np.concatenate([p[j]["ids"] for p in parts])
            vals = np.concatenate([p[j]["scores"] for p in parts])
            if ids.size == 0:
                results.append([])
                continue
            elig = int(sum(p[j]["elig"] for p in parts))
            ranks = np.fromiter((self._rank[int(i)] for i in ids),
                                np.int64, ids.size)
            # primary: score descending; ties: insertion rank ascending —
            # exactly the monolithic merge's stable sort over row order
            order = np.lexsort((ranks, -vals))
            if plan.diverse is not None:
                w = selection_width(plan, min(k, elig), elig)
                order = order[:w]
                kf = max(0, min(k, int(order.size)))
                if kf == 0:
                    results.append([])
                    continue
                pool_ids = ids[order]
                pool_vals = vals[order]
                pool_emb = np.concatenate(
                    [p[j]["pool"] for p in parts])[order]
                sel = mmr_host(pool_emb, pool_vals, kf, plan.diverse.lam)
                out = [(int(i), float(v))
                       for i, v in zip(pool_ids[sel], pool_vals[sel])]
            else:
                order = order[:k]
                out = [(int(i), float(v))
                       for i, v in zip(ids[order], vals[order])]
            results.append(self._finalize_rrf(plan, out, k, cands[j]))
        self.last_merge_ms = (time.perf_counter() - t1) * 1e3
        return results

    def _finalize_rrf(self, plan, results, k, cand):
        """Coordinator-side ``finalize_fusion``: identical recipe, with
        live-membership resolved from the group's id->shard index."""
        f = plan.fusion
        if f is None or f.mode != "rrf" or plan.lexical is None:
            return results
        lex = np.asarray(plan.lexical.ids, np.int64)
        if cand is not None:
            lex = lex[np.isin(lex, cand)]
        lex_ids = [int(i) for i in lex if int(i) in self._shard_of]
        fused = M.rrf_fuse([i for i, _ in results], lex_ids, f.rrf_k)
        return [(int(i), float(s)) for i, s in fused[:max(0, k)]]

    # -- plumbing -------------------------------------------------------------

    #: a replica whose transport raises one of these is DEAD (the pipe
    #: closed under it); application errors ship as (False, msg) and
    #: surface as RuntimeError, which propagates — never fails over
    _TRANSPORT_ERRORS = (EOFError, OSError)

    def _mark_dead(self, s: int, r: int) -> None:
        with self._fail_lock:
            self._dead[s][r] = True
        try:
            self._clients[s][r].close()
        except Exception:
            pass

    def _call_failover(self, s: int, r: int, method: str, *args, **kwargs):
        """Query-path call: try the preferred replica ``r``, fail over
        across the shard's survivors on transport death.  Raises only
        when the shard has NO surviving replica."""
        last: Optional[BaseException] = None
        for attempt in range(self.replicas):
            rr = (r + attempt) % self.replicas
            if self._dead[s][rr]:
                continue
            try:
                res = self._clients[s][rr].call(method, *args, **kwargs)
            except self._TRANSPORT_ERRORS as e:
                self._mark_dead(s, rr)
                last = e
                continue
            if attempt:  # served by a survivor, not the preferred replica
                with self._fail_lock:
                    self.failovers += 1
            return res
        raise RuntimeError(
            f"shard {s}: no surviving replicas"
            + (f" (last transport error: {last!r})" if last else ""))

    def _mutation_call(self, s: int, r: int, method: str, *args, **kwargs):
        """Mutation-path call: every LIVE replica applies the mutation;
        a dead one is skipped (returns None — it can never serve a query
        again, so missing the write is safe).  Raises only when the death
        leaves the shard with zero survivors: the shard's rows would be
        gone, which no retry can hide."""
        if self._dead[s][r]:
            return None
        try:
            return self._clients[s][r].call(method, *args, **kwargs)
        except self._TRANSPORT_ERRORS:
            self._mark_dead(s, r)
            if not any(not d for d in self._dead[s]):
                raise RuntimeError(f"shard {s}: no surviving replicas")
            return None

    def _fanout(self, thunks):
        if self._pool is None:
            return [t() for t in thunks]
        futs = [self._pool.submit(t) for t in thunks]
        return [f.result() for f in futs]

    def stats(self) -> Dict[str, Any]:
        """Topology + per-shard memory/latency rows (every live replica),
        plus the failover ledger and per-shard row skew (round-robin
        dealing assumes uniform rows; deletes can unbalance shards, and
        the slowest — biggest — shard bounds every fan-out)."""
        shard_rows = []
        live_per_shard: List[int] = []
        streams = 0
        for s in range(self.n_shards):
            first: Optional[Dict[str, Any]] = None
            for r_i in range(self.replicas):
                if self._dead[s][r_i]:
                    continue
                try:
                    row = dict(self._clients[s][r_i].call("stats"))
                except self._TRANSPORT_ERRORS:
                    self._mark_dead(s, r_i)
                    continue
                row["replica"] = r_i
                shard_rows.append(row)
                if first is None:
                    first = row
            live_per_shard.append(0 if first is None else int(first["live"]))
            streams += 0 if first is None else int(
                first.get("corpus_streams", 0))
        max_live = max(live_per_shard, default=0)
        min_live = min(live_per_shard, default=0)
        journal = ({} if self.journal is None else {
            "checkpoints": self.checkpoints,
            "recovered_records": self.recovered_records,
            "reconciled_drops": self.reconciled_drops,
            "journal_bytes": self.journal.journal_bytes,
        })
        return {
            "n_shards": self.n_shards,
            "replicas": self.replicas,
            "transport": self.transport,
            "dtype": self.dtype,
            "live": self.n_live,
            "rows": self._row_counter,
            "searches": self.searches,
            "last_fanout_ms": round(self.last_fanout_ms, 3),
            "last_merge_ms": round(self.last_merge_ms, 3),
            "failovers": self.failovers,
            "dead_replicas": sum(d for row in self._dead for d in row),
            "row_skew": {
                "max_live": int(max_live),
                "min_live": int(min_live),
                "spread": int(max_live - min_live),
                "ratio": round(max_live / min_live, 3) if min_live else None,
            },
            "corpus_streams": streams,
            "shards": shard_rows,
            **journal,
        }
