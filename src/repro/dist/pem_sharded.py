"""Sharded PEM scoring + top-k: the two-stage distributed retrieval path.

The naive pjit lowering of ``top_k(scores)`` over a row-sharded corpus
all-gathers the full (N, B) score panel before selecting.  This module's
``make_pem_topk`` is the shard_map formulation: every shard scores its own
corpus rows, selects a LOCAL top-k, and only the (shards * k, B) candidate
union crosses the interconnect — ``shards*k*B / (N*B)`` of the naive
collective traffic (the §Perf "flexvec-4" two_stage iteration).

Exactness: brute-force scoring is preserved (Bruch, *Foundations of Vector
Retrieval*: flat top-k is exact); the union of per-shard top-k provably
contains the global top-k, so the merge returns exactly the unsharded
result (fp reassociation of the per-shard matmuls aside).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.modulations import DEFAULT_DECAY_HALF_LIFE
from repro.dist.sharding import ShardingRules


def pem_topk_reference(
    corpus: jax.Array,      # (N, d) row-major chunk embeddings
    days: jax.Array,        # (N,) age in days
    q_pre: jax.Array,       # (d, B) pre-decay direction panel
    q_sup: jax.Array,       # (d, B) suppress panel
    k: int,
    *,
    half_life: float = DEFAULT_DECAY_HALF_LIFE,
) -> Tuple[jax.Array, jax.Array]:
    """Unsharded oracle: full-panel fused scoring + global top-k.

    Returns ``(indices, values)`` each (B, k), descending by score — the
    contract every sharded/fused lowering must reproduce exactly.
    """
    decay = 1.0 / (1.0 + days / half_life)
    scores = decay[:, None] * (corpus @ q_pre) + corpus @ q_sup  # (N, B)
    v, i = jax.lax.top_k(scores.T, k)
    return i, v


def union_merge_topk(
    v: jax.Array,       # (B, k_local) per-shard local top-k values
    gi: jax.Array,      # (B, k_local) matching GLOBAL row indices
    axes,               # mesh axis name(s) the corpus rows shard over
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Union merge, inside shard_map: gather every shard's local top-k
    candidates (shard-major order so equal scores keep the reference's
    smallest-global-index tie rule), then one top-k over the
    (B, shards*k_local) union.  Returns ``(indices, values)``, each
    (B, min(k, shards*k_local)) — the union provably contains the global
    top-k, so the merge is exact.

    Shared by :func:`make_pem_topk` and the ``sharded`` ExecutionBackend's
    fused ``score_select`` stage (repro/core/backends.py).
    """
    cand_v = jax.lax.all_gather(v, axes)              # (shards, B, k_l)
    cand_i = jax.lax.all_gather(gi, axes)
    b = v.shape[0]
    union = cand_v.shape[0] * cand_v.shape[-1]        # shards * k_local
    cand_v = jnp.swapaxes(cand_v, 0, 1).reshape(b, union)
    cand_i = jnp.swapaxes(cand_i, 0, 1).reshape(b, union)
    vk, pos = jax.lax.top_k(cand_v, min(k, union))
    ik = jnp.take_along_axis(cand_i, pos, axis=1)
    return ik, vk


def union_merge_topk_payload(
    v: jax.Array,       # (B, k_local) per-shard local top-k values
    gi: jax.Array,      # (B, k_local) matching GLOBAL row indices
    pe: jax.Array,      # (B, k_local, d) matching row PAYLOAD (embeddings)
    axes,               # mesh axis name(s) the corpus rows shard over
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`union_merge_topk` carrying a per-candidate PAYLOAD — the
    pool-row embeddings each shard gathered from its OWN row slice.

    The shard-local gather is the point: a diverse (MMR) tail needs the
    merged pool's embeddings, and gathering them after the merge reads
    the full replicated row space — O(N) traffic that grows with corpus
    size.  Gathering ``pe = matrix[i]`` inside the shard (O(n_local))
    and all-gathering it alongside the candidates keeps the collective
    at ``shards * k_local * (2 + d)`` elements, independent of N.

    The payload rides the SAME top-k permutation as the indices, so
    ``pk[b, j] == matrix[ik[b, j]]`` element-for-element and any
    consumer (the fused MMR tail) sees bit-identical inputs to the
    replicated-gather formulation.  Returns ``(indices, values,
    payload)``, each (B, min(k, union), ...).
    """
    cand_v = jax.lax.all_gather(v, axes)              # (shards, B, k_l)
    cand_i = jax.lax.all_gather(gi, axes)
    cand_p = jax.lax.all_gather(pe, axes)             # (shards, B, k_l, d)
    b = v.shape[0]
    union = cand_v.shape[0] * cand_v.shape[-1]        # shards * k_local
    d = cand_p.shape[-1]
    cand_v = jnp.swapaxes(cand_v, 0, 1).reshape(b, union)
    cand_i = jnp.swapaxes(cand_i, 0, 1).reshape(b, union)
    cand_p = jnp.swapaxes(cand_p, 0, 1).reshape(b, union, d)
    vk, pos = jax.lax.top_k(cand_v, min(k, union))
    ik = jnp.take_along_axis(cand_i, pos, axis=1)
    pk = jnp.take_along_axis(cand_p, pos[..., None], axis=1)
    return ik, vk, pk


def make_pem_topk(mesh: Mesh, rules: ShardingRules, k: int, raw: bool = False,
                  *, half_life: float = DEFAULT_DECAY_HALF_LIFE):
    """Build the shard_map'd corpus-row-sharded score -> local top-k -> merge.

    The corpus rows shard over ``rules.rules["corpus"]`` (mesh axes); query
    panels replicate.  ``raw=True`` returns the bare shard-mapped function
    for embedding inside a larger jitted graph (flexvec's two_stage step);
    ``raw=False`` returns it jitted for direct calls.

    Requires N divisible by the corpus shard count (callers pad the row
    grid — see ``FlexvecArch.build``).
    """
    axes = rules.rules.get("corpus")
    if axes is None:
        axes = ()
    elif isinstance(axes, str):
        axes = (axes,)
    else:
        axes = tuple(axes)
    axis_sizes = [mesh.shape[a] for a in axes]

    def sharded_topk(corpus, days, q_pre, q_sup):
        n_local = corpus.shape[0]
        # linear shard index in row-block order (major-first, matching the
        # PartitionSpec layout of P(("a", "b"), None) on dim 0)
        shard = jnp.int32(0)
        for a, size in zip(axes, axis_sizes):
            shard = shard * size + jax.lax.axis_index(a)

        decay = 1.0 / (1.0 + days / half_life)
        scores = decay[:, None] * (corpus @ q_pre) + corpus @ q_sup  # (n_l, B)

        k_local = min(k, n_local)
        v, i = jax.lax.top_k(scores.T, k_local)          # (B, k_local)
        gi = i + shard * n_local                          # global row ids

        if not axes:
            return gi, v

        return union_merge_topk(v, gi, axes, k)

    corpus_axes = axes if axes else None
    fn = shard_map(
        sharded_topk,
        mesh=mesh,
        in_specs=(P(corpus_axes, None), P(corpus_axes), P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False,
    )
    return fn if raw else jax.jit(fn)
