"""Logical-axis sharding rules (GSPMD layer).

Model/config code names LOGICAL axes ("batch", "heads", "corpus", ...);
a :class:`ShardingRules` maps each logical axis to zero or more MESH axes.
The same model code then runs unchanged on a 1x1 CPU mesh (smoke tests),
the 16x16 single-pod mesh, or the 2x16x16 multi-pod mesh — only the rules
change.  This is the minformer/scaling-book idiom: specs are *derived*,
never written inline at call sites.

Vocabulary (every logical axis any spec in the tree may name):

    batch, seq, stack, embed, act_embed, heads, kv_heads, ff, moe_ff,
    expert, vocab            — LM family (FSDP x TP layout)
    nodes, edges             — GNN row sharding
    candidates, table_rows   — recsys corpus / embedding tables
    corpus                   — flexvec retrieval row sharding
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# A logical axis maps to: no mesh axis (replicate), one mesh axis, or a
# tuple of mesh axes (the dim is divided over their product, major-first).
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mesh + {logical axis -> mesh axes} mapping."""

    mesh: Mesh
    rules: Dict[str, MeshAxes]

    # -- lookup ------------------------------------------------------------

    def _axes(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        if name not in self.rules:
            raise KeyError(
                f"unknown logical axis {name!r}; known: {sorted(self.rules)}"
            )
        return self.rules[name]

    def spec(self, *names: Optional[str]) -> PartitionSpec:
        """PartitionSpec for a tensor whose dims carry these logical names.

        ``spec()`` (no args) is fully replicated; ``None`` entries are
        replicated dims.  Passing ``if_divisible(...)`` results is the
        idiomatic divisibility-guarded form.
        """
        return PartitionSpec(*(self._axes(n) for n in names))

    def size_of(self, name: Optional[str]) -> int:
        """Number of shards the logical axis is divided into (1 = replicated)."""
        axes = self._axes(name)
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def if_divisible(self, name: str, dim: int) -> Optional[str]:
        """``name`` if ``dim`` splits evenly over its mesh axes, else None.

        Input shardings require exact divisibility (e.g. a 49155-row vocab
        cannot shard over 16 — it replicates instead).
        """
        return name if dim % self.size_of(name) == 0 else None


def constrain(x: jax.Array, rules: ShardingRules, *names: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` via logical names (no-op on a 1x1 mesh)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(*names))
    )


def default_rules(mesh: Mesh) -> ShardingRules:
    """The baseline layout: FSDP over the data axes x TP over the model axis.

    On the multi-pod mesh the 'pod' axis joins the data group, so batch and
    FSDP-sharded weight dims divide over pod*data.  The corpus maps to
    'data' only (16 shards on the production mesh) — the hillclimb's
    ``corpus_all`` variant (dist/tuned.py) spreads it over every chip.
    """
    data: MeshAxes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return ShardingRules(
        mesh=mesh,
        rules={
            # LM family --------------------------------------------------
            "batch": data,        # activations: data parallel
            "seq": None,          # decode fallback remaps this (configs/lm.py)
            "stack": None,        # the scanned layer-stack dim
            "embed": data,        # weights: FSDP on d_model
            "act_embed": "model",  # activations: TP on d_model
            "heads": "model",
            "kv_heads": "model",
            "ff": "model",
            "moe_ff": None,       # pure EP+FSDP; 'serve_weights' maps to data
            "expert": "model",
            "vocab": "model",
            # GNN ---------------------------------------------------------
            "nodes": data,
            "edges": data,
            # recsys ------------------------------------------------------
            "candidates": data,
            "table_rows": "model",
            # flexvec retrieval -------------------------------------------
            "corpus": "data",
        },
    )
