"""Token grammar (paper §3.4.2) — deterministic parser from token string to
:class:`~repro.core.modulations.ModulationPlan`.

Grammar (whitespace-delimited; prefix tokens open a clause, bare words attach
to the open clause's text; bare keywords close it):

    similar:TEXT...      query text (multi-word until next token)
    suppress:TEXT...     suppression direction (repeatable, stacks additively)
    decay:N              N-day half-life (float)
    centroid:id1,id2     example chunk ids (comma separated)
    from:TEXT... to:TEXT trajectory endpoints
    diverse              MMR selection (bare keyword)
    pool:N               candidate pool size (default 500)
    cluster:K            STRUCTURAL (§3.2): k-means label column
    central              STRUCTURAL (§3.2): similarity-centrality column
    keyword:TEXT...      lexical (FTS5/BM25) leg of hybrid fusion
                         (repeatable; pools dedup + CombSUM-combine)
    fuse:weighted,W      hybrid: W*vector + (1-W)*minmax(bm25) (W in [0,1])
    fuse:rrf,K           hybrid: reciprocal-rank fusion with constant K
    fuse:filter[,W]      hybrid: FTS hits become a HARD Phase-1 candidate
                         set (router crossover applies to the lexical
                         leg); W defaults to 1.0 = pure-vector ranking

Tokens may appear in ANY order; execution order is fixed (modulations.py).
``keyword:`` without ``fuse:`` defaults to ``fuse:weighted,0.5``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import modulations as M

EmbedFn = Callable[[str], np.ndarray]
ResolveIdsFn = Callable[[Sequence[int]], np.ndarray]  # ids -> (m, d) embeds
# keyword text + pool width -> (ids desc-by-bm25, minmax scores in [0,1])
LexicalFn = Callable[[str, int], Tuple[np.ndarray, np.ndarray]]

_PREFIXES = ("similar:", "suppress:", "decay:", "centroid:", "from:", "to:",
             "pool:", "cluster:", "keyword:", "fuse:")
_KEYWORDS = ("diverse", "central")


class GrammarError(ValueError):
    """Raised on malformed token strings; surfaced to the agent via MCP."""


@dataclasses.dataclass
class ParsedTokens:
    """Intermediate, embedder-independent parse (pure text -> structure)."""

    similar: Optional[str] = None
    suppress: List[str] = dataclasses.field(default_factory=list)
    decay: Optional[float] = None
    centroid_ids: Optional[List[int]] = None
    from_text: Optional[str] = None
    to_text: Optional[str] = None
    diverse: bool = False
    pool: int = M.DEFAULT_POOL
    cluster: Optional[int] = None   # structural: k-means label column
    central: bool = False           # structural: centrality column
    keyword: Optional[str] = None   # lexical leg of hybrid fusion (joined)
    # one entry per keyword: clause — each token resolves its OWN FTS
    # pool and the pools combine (dedup + CombSUM) at plan build
    keywords: List[str] = dataclasses.field(default_factory=list)
    fuse_mode: Optional[str] = None  # "weighted" | "rrf" | "filter"
    fuse_weight: float = M.DEFAULT_FUSE_WEIGHT
    fuse_k: int = M.DEFAULT_RRF_K


def tokenize(token_string: str) -> ParsedTokens:
    """Parse the whitespace token grammar into :class:`ParsedTokens`."""
    parsed = ParsedTokens()
    # (kind, accumulated words) for the clause currently being extended.
    open_clause: Optional[Tuple[str, List[str]]] = None

    def close() -> None:
        nonlocal open_clause
        if open_clause is None:
            return
        kind, words = open_clause
        text = " ".join(words).strip()
        if not text:
            raise GrammarError(f"empty text for token '{kind}:'")
        if kind == "similar":
            parsed.similar = text
        elif kind == "suppress":
            parsed.suppress.append(text)
        elif kind == "from":
            parsed.from_text = text
        elif kind == "to":
            parsed.to_text = text
        elif kind == "keyword":
            # each keyword: clause keeps its own FTS query (pools dedup
            # and combine at plan build); `keyword` stays the joined
            # text for display/back-compat
            parsed.keywords.append(text)
            parsed.keyword = (
                f"{parsed.keyword} {text}" if parsed.keyword else text
            )
        open_clause = None

    for raw in token_string.split():
        matched_prefix = next((p for p in _PREFIXES if raw.startswith(p)), None)
        if matched_prefix is not None:
            close()
            kind = matched_prefix[:-1]
            rest = raw[len(matched_prefix):]
            if kind in ("similar", "suppress", "from", "to", "keyword"):
                open_clause = (kind, [rest] if rest else [])
            elif kind == "fuse":
                _parse_fuse(parsed, rest)
            elif kind == "decay":
                try:
                    parsed.decay = float(rest) if rest else M.DEFAULT_DECAY_HALF_LIFE
                except ValueError as e:
                    raise GrammarError(f"decay: expects a number, got {rest!r}") from e
                if parsed.decay <= 0:
                    raise GrammarError("decay: half-life must be positive")
            elif kind == "centroid":
                try:
                    parsed.centroid_ids = [int(x) for x in rest.split(",") if x]
                except ValueError as e:
                    raise GrammarError(
                        f"centroid: expects comma-separated ids, got {rest!r}"
                    ) from e
                if not parsed.centroid_ids:
                    raise GrammarError("centroid: needs at least one id")
            elif kind == "pool":
                try:
                    parsed.pool = int(rest)
                except ValueError as e:
                    raise GrammarError(f"pool: expects an integer, got {rest!r}") from e
                if parsed.pool <= 0:
                    raise GrammarError("pool: must be positive")
            elif kind == "cluster":
                try:
                    parsed.cluster = int(rest)
                except ValueError as e:
                    raise GrammarError(f"cluster: expects an integer, got {rest!r}") from e
                if parsed.cluster <= 0:
                    raise GrammarError("cluster: must be positive")
        elif raw in _KEYWORDS:
            close()
            if raw == "diverse":
                parsed.diverse = True
            elif raw == "central":
                parsed.central = True
        else:
            if open_clause is None:
                # Bare words before any prefix token belong to similar:
                # (agent convenience: 'vec_ops(\'auth tokens diverse\')').
                open_clause = ("similar", [raw])
            else:
                open_clause[1].append(raw)
    close()

    if (parsed.from_text is None) != (parsed.to_text is None):
        raise GrammarError("from:/to: must be used together")
    if parsed.fuse_mode is not None and parsed.keyword is None:
        raise GrammarError("fuse: requires a keyword: clause")
    if parsed.keyword is not None and parsed.fuse_mode is None:
        parsed.fuse_mode = "weighted"  # keyword: alone -> default fusion
    if parsed.fuse_mode == "rrf" and parsed.diverse:
        raise GrammarError(
            "diverse cannot combine with fuse:rrf (MMR needs fused scores "
            "before selection; use fuse:weighted instead)"
        )
    if (
        parsed.similar is None
        and parsed.from_text is None
        and parsed.centroid_ids is None
        and parsed.keyword is None
    ):
        raise GrammarError(
            "query needs at least one of similar:, from:/to:, centroid:, "
            "or keyword:"
        )
    return parsed


def _parse_fuse(parsed: ParsedTokens, rest: str) -> None:
    """Parse ``fuse:weighted[,W]`` / ``fuse:rrf[,K]`` / ``fuse:filter[,W]``
    into ``parsed``.  ``filter`` makes the lexical hit set a hard Phase-1
    candidate set; its default weight is 1.0 (pure-vector ranking within
    the hits) rather than the blended default."""
    parts = rest.split(",") if rest else [""]
    mode = parts[0]
    if mode not in ("weighted", "rrf", "filter"):
        raise GrammarError(
            f"fuse: expects 'weighted[,W]', 'rrf[,K]' or 'filter[,W]', "
            f"got {rest!r}"
        )
    parsed.fuse_mode = mode
    if mode == "filter":
        parsed.fuse_weight = 1.0
    if len(parts) > 2:
        raise GrammarError(f"fuse: too many parameters in {rest!r}")
    if len(parts) == 2:
        param = parts[1]
        if mode in ("weighted", "filter"):
            try:
                parsed.fuse_weight = float(param)
            except ValueError as e:
                raise GrammarError(
                    f"fuse:{mode} expects a number, got {param!r}"
                ) from e
            if not 0.0 <= parsed.fuse_weight <= 1.0:
                raise GrammarError(
                    f"fuse:{mode} weight must be in [0, 1], got "
                    f"{parsed.fuse_weight}"
                )
        else:
            try:
                parsed.fuse_k = int(param)
            except ValueError as e:
                raise GrammarError(
                    f"fuse:rrf expects an integer, got {param!r}"
                ) from e
            if parsed.fuse_k <= 0:
                raise GrammarError("fuse:rrf constant must be positive")


def build_plan(
    parsed: ParsedTokens,
    embed: EmbedFn,
    resolve_ids: Optional[ResolveIdsFn] = None,
    lexical_fn: Optional[LexicalFn] = None,
) -> M.ModulationPlan:
    """Bind a :class:`ParsedTokens` to an embedder -> executable plan.

    ``lexical_fn`` resolves a ``keyword:`` clause to BM25 hits at build
    time (symmetric with ``resolve_ids`` for ``centroid:``); it receives
    the parsed ``pool:`` width so the lexical stage is never silently
    truncated below the requested candidate pool.
    """
    d = None
    if parsed.similar is not None:
        query = M.l2_normalize(np.asarray(embed(parsed.similar), dtype=np.float32))
        d = query.shape[-1]
    else:
        # Pure-trajectory / pure-centroid query: zero base query vector.
        probe = embed(parsed.from_text or "")
        d = np.asarray(probe).shape[-1]
        query = np.zeros(d, dtype=np.float32)

    centroid = None
    if parsed.centroid_ids is not None:
        if resolve_ids is None:
            raise GrammarError("centroid: requires an id resolver")
        examples = np.asarray(resolve_ids(parsed.centroid_ids), dtype=np.float32)
        if examples.ndim != 2 or examples.shape[0] == 0:
            raise GrammarError("centroid: ids resolved to no embeddings")
        centroid = M.CentroidSpec(examples=examples)

    trajectory = None
    if parsed.from_text is not None:
        a = M.l2_normalize(np.asarray(embed(parsed.from_text), dtype=np.float32))
        b = M.l2_normalize(np.asarray(embed(parsed.to_text), dtype=np.float32))
        trajectory = M.TrajectorySpec(direction=b - a)

    suppress = tuple(
        M.SuppressSpec(
            direction=M.l2_normalize(np.asarray(embed(text), dtype=np.float32))
        )
        for text in parsed.suppress
    )

    decay = M.DecaySpec(half_life_days=parsed.decay) if parsed.decay is not None else None
    diverse = M.DiverseSpec() if parsed.diverse else None

    fusion = None
    lexical = None
    if parsed.keyword is not None:
        if lexical_fn is None:
            raise GrammarError(
                "keyword: requires a lexical (FTS) resolver — query through "
                "the materializer / RetrievalService, or pass lexical_fn"
            )
        fusion = M.FusionSpec(
            mode=parsed.fuse_mode or "weighted",
            weight=parsed.fuse_weight,
            rrf_k=parsed.fuse_k,
        )
        # one FTS pool per keyword: clause; multi-clause plans dedup
        # overlapping hits and CombSUM-combine instead of concatenating
        tokens = parsed.keywords or [parsed.keyword]
        if len(tokens) == 1:
            lex_ids, lex_scores = lexical_fn(tokens[0], parsed.pool)
        else:
            lex_ids, lex_scores = M.combine_lexical_pools(
                [lexical_fn(t, parsed.pool) for t in tokens], parsed.pool)
        lexical = M.LexicalHits(
            ids=np.asarray(lex_ids, dtype=np.int64),
            scores=np.asarray(lex_scores, dtype=np.float32),
        )

    return M.ModulationPlan(
        query=query,
        centroid=centroid,
        trajectory=trajectory,
        decay=decay,
        suppress=suppress,
        diverse=diverse,
        pool=parsed.pool,
        cluster=parsed.cluster,
        central=parsed.central,
        keyword=parsed.keyword,
        fusion=fusion,
        lexical=lexical,
    )


def parse(
    token_string: str,
    embed: EmbedFn,
    resolve_ids: Optional[ResolveIdsFn] = None,
    lexical_fn: Optional[LexicalFn] = None,
) -> M.ModulationPlan:
    """tokenize + build_plan in one call (the VectorCache entry point)."""
    return build_plan(tokenize(token_string), embed, resolve_ids, lexical_fn)
