"""Structural operators (paper §3.2): per-query clustering and centrality.

Because Phase 2 operates on a numpy array, the operator surface extends
beyond scoring: these compute over the SELECTED candidate set and expose
results as additional temp-table columns for Phase 3 composition
('cluster:K' and 'central' tokens). The paper introduces these but does
not evaluate them; here they are first-class and tested.
"""

from __future__ import annotations

import numpy as np


def kmeans_labels(embeds: np.ndarray, k: int, iters: int = 10,
                  seed: int = 0) -> np.ndarray:
    """Deterministic Lloyd k-means on L2-normalized rows -> (n,) int32.

    k-means++-style farthest-first init (deterministic: starts from the
    first row) keeps clusters stable across runs for the same pool."""
    n = embeds.shape[0]
    k = max(1, min(k, n))
    centers = np.empty((k, embeds.shape[1]), np.float32)
    centers[0] = embeds[0]
    for c in range(1, k):
        sim = np.max(embeds @ centers[:c].T, axis=1)
        centers[c] = embeds[int(np.argmin(sim))]      # farthest point
    labels = np.zeros(n, np.int32)
    for _ in range(iters):
        labels = np.argmax(embeds @ centers.T, axis=1).astype(np.int32)
        for c in range(k):
            mask = labels == c
            if mask.any():
                v = embeds[mask].mean(axis=0)
                centers[c] = v / max(float(np.linalg.norm(v)), 1e-9)
    return labels


def centrality(embeds: np.ndarray) -> np.ndarray:
    """Degree centrality in the candidate similarity graph: mean cosine of
    each candidate to the rest of the pool. (n,) float32 in [-1, 1]."""
    n = embeds.shape[0]
    if n <= 1:
        return np.zeros(n, np.float32)
    sim = embeds @ embeds.T
    return ((sim.sum(axis=1) - 1.0) / (n - 1)).astype(np.float32)
