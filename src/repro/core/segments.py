"""Segmented corpus store: append-only segments + tombstones (live corpora).

The paper's VectorCache holds the corpus embedding matrix as ONE immutable
array, so any mutation means a full re-upload / re-normalize / re-trace.
Production vector stores treat ingest as first-class (pgai keeps embeddings
continuously in sync with table mutations; the vector-database survey
[Ma et al. 2023] names segment-based storage with tombstoning as the
standard design for mutable collections).  This module is that design:

* :class:`CorpusSegment` — a SEALED batch of rows (ids, L2-normalized
  matrix, timestamps) plus a tombstone bitmask.  The arrays never change
  after sealing (device caches key on array identity); only tombstone bits
  flip.
* :class:`SegmentedCorpusStore` — an ordered list of segments with a
  global id -> (segment, row) index.  ``append`` seals a new segment,
  ``delete`` flips tombstones, ``compact`` merges small/sparse segments
  into a fresh sealed segment.

Scoring stays exact: every backend scores each segment independently
(tombstones masked to -inf before selection) and the per-segment top-k
merge (``repro.core.backends.score_select_segments``) reproduces the
monolithic result bit-for-bit — the same two-stage union-merge shape
``repro.dist.pem_sharded`` uses across device shards, applied across
segments.  A monolithic corpus is just a one-segment store.

Global row addressing: a row is identified by its offset in the
concatenation of ALL segment rows (tombstoned rows included, so offsets
never shift under deletes).  :func:`gather_rows` / :func:`gather_ids`
resolve global rows against a segment-list snapshot.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import modulations as M
from repro.core.journal import (
    FaultPlan,
    JournalRecord,
    StoreJournal,
    recover_pending,
)

__all__ = [
    "CorpusSegment",
    "CompactionPolicy",
    "SegmentedCorpusStore",
    "segment_offsets",
    "gather_rows",
    "gather_ids",
    "gather_days",
    "pack_bf16",
    "unpack_bf16",
]

SECONDS_PER_DAY = 86400.0


def pack_bf16(matrix: np.ndarray) -> np.ndarray:
    """float32 rows -> bfloat16 bit patterns stored as uint16.

    bfloat16 is the TOP 16 bits of the IEEE float32 layout (same exponent
    range, 7 mantissa bits), so packing is one shift — no scale factors,
    no codebook — and halves the bytes a scoring pass has to stream.  On
    the bandwidth-bound million-chunk corpus that byte halving IS the
    speedup (the matmul is memory-bound); :mod:`repro.dist.procgroup`
    shard workers score blocked bf16 panels with this layout.  Truncation
    (round-toward-zero) keeps pack deterministic and order-free.
    """
    m = np.ascontiguousarray(matrix, dtype=np.float32)
    return (m.view(np.uint32) >> np.uint32(16)).astype(np.uint16)


def unpack_bf16(codes: np.ndarray, out: Optional[np.ndarray] = None) -> np.ndarray:
    """uint16 bf16 codes -> float32, exact bit-pattern restoration.

    The inverse shift of :func:`pack_bf16`: every decoded float32 is
    EXACTLY the bf16 value (low mantissa bits zero), so decode is
    lossless given the codes and repeated decodes are bit-identical.
    ``out`` accepts a reusable (same-shape) uint32 scratch buffer so a
    blocked scoring loop never reallocates; the returned array is a view
    of it.
    """
    codes = np.asarray(codes, dtype=np.uint16)
    if out is None:
        out = np.empty(codes.shape, dtype=np.uint32)
    np.left_shift(codes, np.uint32(16), out=out, casting="unsafe")
    return out.view(np.float32)


@dataclasses.dataclass(eq=False)  # identity equality: fields hold arrays
class CorpusSegment:
    """One sealed batch of corpus rows.

    ``ids``/``matrix``/``timestamps`` are immutable after sealing — the
    device-resident matrix caches key on ``id(matrix)``, so a warm segment
    never re-uploads.  Deletes only flip ``tombstones`` bits (and bump
    ``n_dead``); the dead rows are masked to -inf at scoring time and
    physically dropped at :meth:`SegmentedCorpusStore.compact`.
    """

    seg_id: int
    ids: np.ndarray                       # (n,) int64 chunk ids
    matrix: np.ndarray                    # (n, d) float32, L2-normalized
    timestamps: Optional[np.ndarray]      # (n,) float64 unix seconds, or None
    tombstones: np.ndarray                # (n,) bool, True = deleted
    n_dead: int = 0

    @property
    def n_rows(self) -> int:
        return int(self.ids.shape[0])

    @property
    def live_count(self) -> int:
        return self.n_rows - self.n_dead

    @property
    def live_fraction(self) -> float:
        return self.live_count / self.n_rows if self.n_rows else 0.0

    @property
    def live_mask(self) -> np.ndarray:
        """Fresh (n,) bool array, True = live (a copy: safe to ship off)."""
        return ~self.tombstones

    def days_ago(self, now: float) -> Optional[np.ndarray]:
        """Per-row age in days at ``now`` (None when timestamps absent)."""
        if self.timestamps is None:
            return None
        return np.maximum(
            (now - self.timestamps) / SECONDS_PER_DAY, 0.0
        ).astype(np.float32)


def segment_offsets(segments: Sequence[CorpusSegment]) -> np.ndarray:
    """(S+1,) cumulative row starts: segment i spans [off[i], off[i+1])."""
    off = np.zeros(len(segments) + 1, dtype=np.int64)
    for i, seg in enumerate(segments):
        off[i + 1] = off[i] + seg.n_rows
    return off


def _locate(segments: Sequence[CorpusSegment], global_rows: np.ndarray):
    off = segment_offsets(segments)
    gidx = np.asarray(global_rows, dtype=np.int64)
    seg_idx = np.searchsorted(off, gidx, side="right") - 1
    return seg_idx, gidx - off[seg_idx]


def gather_rows(
    segments: Sequence[CorpusSegment], global_rows: np.ndarray
) -> np.ndarray:
    """Embedding rows for global row offsets (order-preserving gather)."""
    gidx = np.asarray(global_rows, dtype=np.int64)
    if gidx.size == 0:
        dim = segments[0].matrix.shape[1] if segments else 0
        return np.zeros((0, dim), dtype=np.float32)
    seg_idx, local = _locate(segments, gidx)
    out = np.empty((gidx.size, segments[0].matrix.shape[1]), dtype=np.float32)
    for s in np.unique(seg_idx):
        sel = seg_idx == s
        out[sel] = segments[s].matrix[local[sel]]
    return out


def gather_ids(
    segments: Sequence[CorpusSegment], global_rows: np.ndarray
) -> np.ndarray:
    """Chunk ids for global row offsets (order-preserving gather)."""
    gidx = np.asarray(global_rows, dtype=np.int64)
    if gidx.size == 0:
        return np.zeros(0, dtype=np.int64)
    seg_idx, local = _locate(segments, gidx)
    out = np.empty(gidx.size, dtype=np.int64)
    for s in np.unique(seg_idx):
        sel = seg_idx == s
        out[sel] = segments[s].ids[local[sel]]
    return out


def gather_days(
    segments: Sequence[CorpusSegment], global_rows: np.ndarray, now: float
) -> Optional[np.ndarray]:
    """Per-row age in days at ``now`` for global row offsets (None when the
    segments carry no timestamps — decay plans are rejected upstream)."""
    if not segments or segments[0].timestamps is None:
        return None
    gidx = np.asarray(global_rows, dtype=np.int64)
    if gidx.size == 0:
        return np.zeros(0, dtype=np.float32)
    seg_idx, local = _locate(segments, gidx)
    ts = np.empty(gidx.size, dtype=np.float64)
    for s in np.unique(seg_idx):
        sel = seg_idx == s
        ts[sel] = segments[s].timestamps[local[sel]]
    return np.maximum((now - ts) / SECONDS_PER_DAY, 0.0).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Background-compaction heuristic (ROADMAP follow-on to delta ingest).

    Two pressures, matching how a live store degrades:

    * **liveness** — a segment whose live fraction fell below
      ``min_live_fraction`` wastes score/mask work on dead rows every
      batch; fold it.
    * **segment count** — many small fully-live segments (a stream of
      delta appends) cost one scoring launch + one merge slot each; when
      the store exceeds ``max_segments``, merge the SMALLEST segments
      (fewest rows re-uploaded/re-traced) down to the cap.

    The policy only picks victims; :meth:`SegmentedCorpusStore.maybe_compact`
    folds them under the store lock, so a compaction can never land inside
    a scoring pass (the device pass holds the same lock).  The serving
    scheduler (:mod:`repro.serve.engine`) invokes it in idle gaps between
    batches.
    """

    min_live_fraction: float = 0.7
    max_segments: int = 8

    def should_compact(self, store: "SegmentedCorpusStore") -> bool:
        """Cheap lock-free check the scheduler runs each idle tick; a True
        here is re-validated under the lock by :meth:`victims`."""
        segs = store._segments
        if len(segs) > self.max_segments:
            return True
        return any(s.n_rows and s.live_fraction < self.min_live_fraction
                   for s in segs)

    def victims(self, segments: Sequence[CorpusSegment]) -> List[CorpusSegment]:
        """Segments to fold into one fresh sealed segment (may be empty)."""
        victims = [s for s in segments
                   if s.n_rows and s.live_fraction < self.min_live_fraction]
        # count pressure: folding m victims yields <= 1 merged segment,
        # so keep adding the smallest until the post-fold count fits
        if len(segments) > self.max_segments:
            chosen = set(id(s) for s in victims)
            by_size = sorted((s for s in segments if s.n_rows),
                             key=lambda s: s.n_rows)
            for s in by_size:
                if len(segments) - len(victims) + 1 <= self.max_segments:
                    break
                if id(s) not in chosen:
                    victims.append(s)
                    chosen.add(id(s))
            # keep store order so the merged segment lands predictably
            order = {id(s): i for i, s in enumerate(segments)}
            victims.sort(key=lambda s: order[id(s)])
        return victims if len(victims) > 1 or any(
            s.n_dead for s in victims) else []


class SegmentedCorpusStore:
    """Ordered immutable segments + tombstones + a global id index.

    Thread model: mutations (``append``/``delete``/``compact``) take
    ``self.lock`` internally; readers that need a consistent scoring pass
    (the batched engine, ``VectorCache.search_plan``) hold ``self.lock``
    across snapshot + scoring, so ingest is usable *between* batches
    without torn reads.  ``version`` bumps on every mutation — consumers
    (the VectorCache live view) use it for cheap invalidation.

    Durability: pass ``journal=`` (a :class:`~repro.core.journal.
    StoreJournal`) and every mutation is journaled + fsync'd BEFORE it is
    applied in memory — an acknowledged write survives a crash at any
    point.  :meth:`open` recovers a store from its journal directory
    (snapshot + post-snapshot delta replay, torn-tail tolerant);
    :meth:`checkpoint` writes a fresh snapshot and rotates the journal so
    the next recovery replays only the records since.
    """

    def __init__(self, dim: int, *,
                 journal: Optional[StoreJournal] = None) -> None:
        self.dim = int(dim)
        self._segments: List[CorpusSegment] = []
        self._loc: Dict[int, Tuple[CorpusSegment, int]] = {}
        self.lock = threading.RLock()
        self.version = 0
        self._next_seg_id = 0
        self.appends = 0
        self.deletes = 0
        self.compactions = 0
        self.journal = journal
        self.checkpoints = 0
        self.recovered_records = 0
        self.recovered_pending: List[Tuple[int, str, Optional[float]]] = []
        self.recovered_dead_letters: List[Dict[str, Any]] = []

    # -- introspection -------------------------------------------------------

    @property
    def segments(self) -> Tuple[CorpusSegment, ...]:
        """Snapshot of the segment list (the list itself never mutates in
        place; compact swaps in a new list under the lock)."""
        with self.lock:
            return tuple(self._segments)

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    @property
    def n_rows(self) -> int:
        """Physical rows, tombstoned included."""
        return sum(s.n_rows for s in self._segments)

    @property
    def n_live(self) -> int:
        return sum(s.live_count for s in self._segments)

    @property
    def has_timestamps(self) -> bool:
        segs = self._segments
        return bool(segs) and all(s.timestamps is not None for s in segs)

    def stats(self) -> Dict[str, int]:
        with self.lock:
            out = {
                "segments": self.n_segments,
                "rows": self.n_rows,
                "live": self.n_live,
                "tombstoned": self.n_rows - self.n_live,
                "appends": self.appends,
                "deletes": self.deletes,
                "compactions": self.compactions,
                "version": self.version,
            }
            if self.journal is not None:
                out["checkpoints"] = self.checkpoints
                out["recovered_records"] = self.recovered_records
                out["journal_bytes"] = self.journal.journal_bytes
            return out

    def _fault(self, point: str) -> None:
        """Hit a FaultPlan crash point (no-op without an attached plan)."""
        if self.journal is not None and self.journal.fault_plan is not None:
            self.journal.fault_plan.reach(point)

    # -- mutations -----------------------------------------------------------

    def append(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
    ) -> Optional[CorpusSegment]:
        """Seal ``(ids, matrix, timestamps)`` as a new segment.

        An empty append is a no-op returning None.  Re-appending an id that
        was tombstoned is allowed (the index moves to the new row); a LIVE
        duplicate id is an error.  Timestamp presence must match the rest
        of the store (decay scoring is all-or-nothing).
        """
        ids_arr = np.asarray(ids, dtype=np.int64)
        matrix = np.asarray(matrix, dtype=np.float32)
        if matrix.ndim != 2 or matrix.shape[0] != ids_arr.shape[0]:
            raise ValueError(
                f"matrix shape {matrix.shape} inconsistent with "
                f"{len(ids_arr)} ids"
            )
        if matrix.shape[0] and matrix.shape[1] != self.dim:
            raise ValueError(
                f"segment dim {matrix.shape[1]} != store dim {self.dim}"
            )
        if ids_arr.size == 0:
            return None
        ts = (np.asarray(timestamps, dtype=np.float64)
              if timestamps is not None else None)
        if ts is not None and ts.shape[0] != ids_arr.shape[0]:
            raise ValueError("timestamps misaligned with ids")
        with self.lock:
            if self._segments:
                have_ts = self._segments[0].timestamps is not None
                if have_ts != (ts is not None):
                    raise ValueError(
                        "timestamp presence must match the existing store "
                        f"(store has timestamps: {have_ts})"
                    )
            dupes = [int(i) for i in ids_arr if int(i) in self._loc]
            if dupes:
                raise ValueError(
                    f"append: ids already live in the store: {dupes[:10]}"
                    + ("..." if len(dupes) > 10 else "")
                )
            if not normalized:
                matrix = np.asarray(M.l2_normalize(matrix), dtype=np.float32)
            if self.journal is not None:
                # WAL-first: the POST-normalization matrix is journaled so
                # replay (normalized=True) reseals bit-identical rows
                self.journal.append_record("append", {
                    "seg_id": self._next_seg_id,
                    "ids": ids_arr,
                    "matrix": matrix,
                    "timestamps": ts,
                })
                self._fault("append:post-journal")
            return self._seal(ids_arr, matrix, ts)

    def _seal(
        self,
        ids_arr: np.ndarray,
        matrix: np.ndarray,
        ts: Optional[np.ndarray],
    ) -> CorpusSegment:
        """Seal a validated, normalized batch (caller holds the lock)."""
        seg = CorpusSegment(
            seg_id=self._next_seg_id,
            ids=ids_arr,
            matrix=matrix,
            timestamps=ts,
            tombstones=np.zeros(ids_arr.shape[0], dtype=bool),
        )
        self._next_seg_id += 1
        self._segments = self._segments + [seg]
        for row, cid in enumerate(ids_arr):
            self._loc[int(cid)] = (seg, row)
        self.version += 1
        self.appends += 1
        return seg

    def delete(self, ids: Sequence[int], *, strict: bool = False) -> int:
        """Tombstone ``ids``; returns how many rows were newly tombstoned.

        Unknown (or already-deleted) ids are ignored unless ``strict``.
        """
        with self.lock:
            missing: List[int] = []
            to_flip: List[int] = []
            seen: set = set()
            for cid in ids:
                cid = int(cid)
                if cid in seen or cid not in self._loc:
                    missing.append(cid)
                else:
                    seen.add(cid)
                    to_flip.append(cid)
            if missing and strict:
                raise KeyError(
                    f"delete: ids not live in the store: {missing[:10]}"
                    + ("..." if len(missing) > 10 else "")
                )
            if not to_flip:
                return 0
            if self.journal is not None:
                self.journal.append_record(
                    "delete", {"ids": np.asarray(to_flip, dtype=np.int64)})
                self._fault("delete:post-journal")
            for cid in to_flip:
                seg, row = self._loc.pop(cid)
                seg.tombstones[row] = True
                seg.n_dead += 1
            self.version += 1
            self.deletes += 1
            return len(to_flip)

    def compact(self, min_live_fraction: float = 1.0) -> int:
        """Merge sparse segments: every segment whose live fraction is
        below ``min_live_fraction`` is folded (dead rows dropped) into one
        fresh sealed segment, inserted at the first victim's position.
        Fully-dead segments are simply removed.  Returns the number of
        source segments compacted away.

        ``compact(1.0)`` (the default) rewrites every segment that has ANY
        tombstone — full garbage collection.
        """
        with self.lock:
            victims = [s for s in self._segments
                       if s.n_rows and s.live_fraction < min_live_fraction]
            return self._fold(victims)

    def maybe_compact(self, policy: CompactionPolicy) -> int:
        """Apply ``policy`` if it names victims; returns segments folded.

        Takes the store lock for the victim choice AND the fold, so the
        decision can't race a concurrent append/delete — and since the
        scoring device pass holds the same lock, a compaction triggered
        from the serving scheduler's idle gaps can never land inside a
        scoring pass.
        """
        with self.lock:
            return self._fold(policy.victims(self._segments))

    def _fold(self, victims: List[CorpusSegment]) -> int:
        """Merge ``victims`` (dead rows dropped) into one fresh sealed
        segment at the first victim's position; caller holds the lock."""
        if not victims:
            return 0
        if self.journal is not None:
            # the fold is deterministic given the victims' seg_ids, so the
            # record carries only those; replay redoes the merge itself
            self.journal.append_record("compact", {
                "victims": [s.seg_id for s in victims],
                "merged_seg_id": self._next_seg_id,
            })
            self._fault("compact:post-journal")
        return self._apply_fold(victims)

    def _apply_fold(self, victims: List[CorpusSegment]) -> int:
        keep = [s for s in self._segments if s not in victims]
        first_at = self._segments.index(victims[0])
        insert_at = sum(1 for s in self._segments[:first_at]
                        if s not in victims)
        live_parts = [s for s in victims if s.live_count]
        merged: Optional[CorpusSegment] = None
        if live_parts:
            ids = np.concatenate([s.ids[s.live_mask] for s in live_parts])
            mat = np.concatenate(
                [s.matrix[s.live_mask] for s in live_parts])
            ts = None
            if live_parts[0].timestamps is not None:
                ts = np.concatenate(
                    [s.timestamps[s.live_mask] for s in live_parts])
            merged = CorpusSegment(
                seg_id=self._next_seg_id,
                ids=ids,
                matrix=np.ascontiguousarray(mat),
                timestamps=ts,
                tombstones=np.zeros(ids.shape[0], dtype=bool),
            )
            self._next_seg_id += 1
            for row, cid in enumerate(ids):
                self._loc[int(cid)] = (merged, row)
            keep.insert(insert_at, merged)
        self._segments = keep
        self.version += 1
        self.compactions += 1
        return len(victims)

    # -- durability: open / checkpoint / replay ------------------------------

    @classmethod
    def open(
        cls,
        path: os.PathLike,
        dim: Optional[int] = None,
        *,
        fault_plan: Optional[FaultPlan] = None,
        fsync: bool = True,
    ) -> "SegmentedCorpusStore":
        """Open (or create) a journal-backed store at ``path``.

        Recovery = load the last snapshot (if any) + replay only the
        post-snapshot journal delta; ``recovered_records`` counts the
        replayed records (the O(delta) pin) and a torn/truncated tail
        record is tolerated (replay stops cleanly before it).  Rows that
        were enqueued for background embedding but never embedded
        resurface in ``recovered_pending`` (with any ``recovered_dead_
        letters``) for the vectorizer to re-adopt.  ``dim`` is required
        only for a brand-new (empty) journal directory.
        """
        journal = StoreJournal(path, fault_plan=fault_plan, fsync=fsync)
        snap = journal.load_snapshot()
        after = int(snap["seq"]) if snap is not None else -1
        records = list(journal.replay(after_seq=after))
        journal.truncate_torn_tail()
        if snap is not None:
            if dim is not None and int(snap["dim"]) != int(dim):
                raise ValueError(
                    f"open: dim {dim} != snapshot dim {snap['dim']}")
            store = cls(int(snap["dim"]))
            store._restore_snapshot(snap)
        else:
            if dim is None:
                for rec in records:
                    if rec.kind == "append":
                        dim = int(rec.payload["matrix"].shape[1])
                        break
            if dim is None:
                raise ValueError(
                    "open: empty journal directory needs an explicit dim")
            store = cls(int(dim))
        # journal attaches AFTER replay so re-applied records don't re-journal
        for rec in records:
            store._apply_record(rec)
        store.recovered_records = len(records)
        pending, dead = recover_pending(
            snap, records, set(store._loc.keys()))
        store.recovered_pending = pending
        store.recovered_dead_letters = dead
        store.journal = journal
        return store

    def checkpoint(
        self,
        pending: Sequence[Tuple[int, str, Optional[float]]] = (),
        dead_letters: Sequence[Dict[str, Any]] = (),
    ) -> None:
        """Snapshot the full sealed-segment state and rotate the journal.

        ``pending``/``dead_letters`` carry the vectorizer's not-yet-
        embedded queue into the snapshot (their journal records rotate
        away with everything else).  After a checkpoint, recovery replays
        only records written since — keep calling it periodically and
        recovery stays O(delta).
        """
        if self.journal is None:
            raise RuntimeError("checkpoint: store has no journal attached")
        with self.lock:
            state = {
                "dim": self.dim,
                "next_seg_id": self._next_seg_id,
                "version": self.version,
                "appends": self.appends,
                "deletes": self.deletes,
                "compactions": self.compactions,
                "segments": [
                    {
                        "seg_id": s.seg_id,
                        "ids": s.ids,
                        "matrix": s.matrix,
                        "timestamps": s.timestamps,
                        "tombstones": s.tombstones,
                        "n_dead": s.n_dead,
                    }
                    for s in self._segments
                ],
                "pending": [tuple(r) for r in pending],
                "dead_letters": [dict(d) for d in dead_letters],
            }
            self.journal.write_snapshot(state)
            self.checkpoints += 1

    def _restore_snapshot(self, snap: Dict[str, Any]) -> None:
        with self.lock:
            segs: List[CorpusSegment] = []
            for s in snap["segments"]:
                segs.append(CorpusSegment(
                    seg_id=int(s["seg_id"]),
                    ids=s["ids"],
                    matrix=s["matrix"],
                    timestamps=s["timestamps"],
                    tombstones=s["tombstones"],
                    n_dead=int(s["n_dead"]),
                ))
            self._segments = segs
            self._loc = {}
            for seg in segs:
                for row in np.nonzero(~seg.tombstones)[0]:
                    self._loc[int(seg.ids[row])] = (seg, int(row))
            self._next_seg_id = int(snap["next_seg_id"])
            self.version = int(snap["version"])
            self.appends = int(snap["appends"])
            self.deletes = int(snap["deletes"])
            self.compactions = int(snap["compactions"])

    def _apply_record(self, rec: JournalRecord) -> None:
        """Re-apply one journal record during recovery (journal detached,
        so nothing is re-journaled; replay is deterministic and the
        journaled seg_ids double as a divergence check)."""
        kind, p = rec.kind, rec.payload
        if kind == "append":
            seg = self.append(
                p["ids"], p["matrix"], p["timestamps"], normalized=True)
            if seg is not None and seg.seg_id != int(p["seg_id"]):
                raise ValueError(
                    f"replay divergence: sealed seg_id {seg.seg_id} != "
                    f"journaled {p['seg_id']}")
        elif kind == "delete":
            self.delete(p["ids"])
        elif kind == "compact":
            want = {int(v) for v in p["victims"]}
            with self.lock:
                victims = [s for s in self._segments if s.seg_id in want]
                if len(victims) != len(want):
                    raise ValueError(
                        f"replay divergence: compaction victims {sorted(want)} "
                        f"not all present")
                self._fold(victims)
        elif kind in ("enqueue", "dead_letter"):
            pass  # ingest-queue records; folded in by recover_pending
        else:
            raise ValueError(f"unknown journal record kind {kind!r}")

    # -- id lookups ----------------------------------------------------------

    def __contains__(self, chunk_id: int) -> bool:
        return int(chunk_id) in self._loc

    def embedding_for_id(self, chunk_id: int) -> Optional[np.ndarray]:
        loc = self._loc.get(int(chunk_id))
        if loc is None:
            return None
        seg, row = loc
        return seg.matrix[row]

    def gather_embeddings(
        self, chunk_ids: Sequence[int]
    ) -> Tuple[np.ndarray, List[int]]:
        """Embedding rows for ``chunk_ids`` straight off the id index —
        no live-view materialization (the view concatenates EVERY live row
        just to gather a handful).  Returns ``(rows, missing)`` where
        ``rows`` stacks the found ids' embeddings in request order and
        ``missing`` lists ids not live in the store (non-strict: the
        caller decides whether that is an error)."""
        rows: List[np.ndarray] = []
        missing: List[int] = []
        with self.lock:
            for cid in chunk_ids:
                loc = self._loc.get(int(cid))
                if loc is None:
                    missing.append(int(cid))
                else:
                    seg, row = loc
                    rows.append(seg.matrix[row])
        mat = (np.stack(rows).astype(np.float32, copy=False) if rows
               else np.zeros((0, self.dim), dtype=np.float32))
        return mat, missing

    # -- Phase-1 candidate lookups (the filtered-retrieval batch APIs) -------

    def candidate_masks(
        self,
        candidate_ids: np.ndarray,
        segments: Optional[Sequence[CorpusSegment]] = None,
    ) -> Tuple[List[Optional[np.ndarray]], int]:
        """Batch candidate lookup: id set -> per-segment row bitmasks.

        ``masks[i]`` is a ``(segments[i].n_rows,)`` bool array, True on the
        LIVE rows whose chunk id is in ``candidate_ids`` — candidates ∧
        ¬tombstones, ready to hand to ``score_select``'s ``mask`` argument
        so the warm device-resident segment matrices score with
        non-candidates at -inf instead of gathering a scratch sub-corpus.
        Segments holding no candidate stay ``None`` (skipped entirely by
        the segment driver).  Returns ``(masks, n_matched)``.

        Non-strict by construction: ids unknown to the store — including
        ids tombstoned between the Phase-1 SQL and this lookup — simply
        never set a bit.  The scan is vectorized (``np.isin`` per sealed
        ``ids`` array), so cost is O(corpus), independent of how the ids
        scatter across segments — the selectivity router only takes this
        path when the candidate set is a large fraction of the corpus.
        """
        cand = np.asarray(candidate_ids, dtype=np.int64)
        if segments is None:
            segments = self.segments
        masks: List[Optional[np.ndarray]] = []
        matched = 0
        for seg in segments:
            if cand.size == 0 or seg.n_rows == 0 or not seg.live_count:
                masks.append(None)
                continue
            m = np.isin(seg.ids, cand)
            if seg.n_dead:
                m &= seg.live_mask
            hits = int(np.count_nonzero(m))
            if hits == 0:
                masks.append(None)
            else:
                masks.append(m)
                matched += hits
        return masks, matched

    def candidate_mask_panel(
        self,
        candidate_sets: Sequence[Optional[np.ndarray]],
        segments: Optional[Sequence[CorpusSegment]] = None,
    ) -> Tuple[List[Optional[np.ndarray]], int]:
        """Heterogeneous-filter batch lookup: B candidate sets -> per-
        segment ``(n_rows, B)`` bool PANELS, column ``j`` True on the live
        rows whose chunk id is in ``candidate_sets[j]``.

        The per-plan generalization of :meth:`candidate_masks` — a batch
        whose requests carry B DIFFERENT Phase-1 filters shares one
        batched matmul + masked selection instead of one scoring pass per
        distinct filter.  ``candidate_sets[j] is None`` means request
        ``j`` is UNFILTERED: its column is the plain live mask (all-ones
        minus tombstones), so a mixed filtered/unfiltered cohort never
        splits.  Segments where no filtered column has a hit AND there is
        no unfiltered column stay ``None`` (skipped by the segment
        driver); ``n_matched`` counts the filtered columns' set bits.

        Non-strict exactly like :meth:`candidate_masks`: unknown or
        tombstoned ids never set a bit.  Duplicate ids within a set are
        harmless (``np.isin`` semantics).
        """
        if segments is None:
            segments = self.segments
        sets = [None if c is None else np.asarray(c, dtype=np.int64)
                for c in candidate_sets]
        panels: List[Optional[np.ndarray]] = []
        matched = 0
        for seg in segments:
            if seg.n_rows == 0 or not seg.live_count:
                panels.append(None)
                continue
            live = seg.live_mask
            panel = np.empty((seg.n_rows, len(sets)), dtype=bool)
            hits = 0
            for j, cand in enumerate(sets):
                if cand is None:
                    panel[:, j] = live
                    continue
                col = np.isin(seg.ids, cand)
                if seg.n_dead:
                    col &= live
                panel[:, j] = col
                hits += int(np.count_nonzero(col))
            matched += hits
            if hits == 0 and all(c is not None for c in sets):
                panels.append(None)
            else:
                panels.append(panel)
        return panels, matched

    def locate_rows(
        self,
        candidate_ids: np.ndarray,
        segments: Sequence[CorpusSegment],
    ) -> np.ndarray:
        """Global row offsets (ascending) of the live candidate ids within
        the ``segments`` snapshot — the gather-path counterpart of
        :meth:`candidate_masks`.  O(candidates) via the id index, so a
        highly selective Phase-1 filter resolves without touching the rest
        of the corpus.  Non-strict: unknown/tombstoned ids are dropped, and
        ids living in a segment not part of the snapshot (compacted away
        after it was taken) are dropped too.  Ascending order is the
        canonical tie order — it matches the masked path's segment-major
        merge bit for bit."""
        off = segment_offsets(segments)
        seg_index = {id(s): i for i, s in enumerate(segments)}
        rows: List[int] = []
        with self.lock:
            for cid in np.asarray(candidate_ids, dtype=np.int64):
                loc = self._loc.get(int(cid))
                if loc is None:
                    continue
                i = seg_index.get(id(loc[0]))
                if i is None:
                    continue
                rows.append(int(off[i]) + loc[1])
        rows.sort()
        return np.asarray(rows, dtype=np.int64)

    def score_bias_arrays(
        self,
        ids: np.ndarray,
        values: np.ndarray,
        segments: Optional[Sequence[CorpusSegment]] = None,
    ) -> Tuple[List[Optional[np.ndarray]], int]:
        """Sparse per-id score values -> dense per-segment (n,) float32
        additive-bias arrays aligned with ``segments`` — the hybrid
        lexical leg's ``score_bias`` input for the segmented drivers.

        The scatter resolves through the id index (O(len(ids)), like
        :meth:`locate_rows`), never a corpus scan.  Segments holding no
        scored id stay None (zero bias, nothing allocated).  Non-strict:
        unknown / tombstoned / out-of-snapshot ids are dropped — the
        second return is how many ids actually landed.
        """
        with self.lock:
            if segments is None:
                segments = list(self.segments)
            seg_index = {id(s): i for i, s in enumerate(segments)}
            arrays: List[Optional[np.ndarray]] = [None] * len(segments)
            matched = 0
            for cid, val in zip(np.asarray(ids, dtype=np.int64),
                                np.asarray(values, dtype=np.float32)):
                loc = self._loc.get(int(cid))
                if loc is None:
                    continue
                i = seg_index.get(id(loc[0]))
                if i is None:
                    continue
                if arrays[i] is None:
                    arrays[i] = np.zeros(segments[i].n_rows, np.float32)
                arrays[i][loc[1]] = val
                matched += 1
        return arrays, matched
