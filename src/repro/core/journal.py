"""Write-ahead journal + snapshots: crash-durable segment stores.

The paper's store is memory-only — a process crash loses every append
since startup and recovery means re-embedding / re-ingesting the whole
corpus (O(corpus)).  The vector-database survey (Ma et al., 2023) names
durable storage with *incremental* recovery as the defining gap between
a retrieval kernel and a retrieval system; this module closes it with
the classic WAL shape:

* every mutation is journaled (fsync'd) BEFORE it is applied in memory,
  so an acknowledged write survives a crash at any later point;
* a **snapshot** (atomic tmp + fsync + rename) captures the full sealed-
  segment state plus the journal sequence number it covers, after which
  the journal is rotated — recovery loads the snapshot and replays only
  records with ``seq > snapshot.seq`` (O(delta), not O(corpus));
* the journal's record framing is ``<u32 length, u32 crc32>`` + payload,
  so a **torn tail** (crash mid-write) is detected and tolerated: replay
  stops cleanly at the first truncated/corrupt record instead of
  propagating garbage.

The journal is *generic*: records are ``(seq, kind, payload)`` tuples and
the snapshot body is an opaque dict, so :class:`repro.core.segments.
SegmentedCorpusStore` journals ``append``/``delete``/``compact`` records
while :class:`repro.dist.procgroup.ProcessGroup` reuses the same file
format for its coordinator routing state, and the ingest vectorizer
(:mod:`repro.serve.vectorizer`) journals ``enqueue``/``dead_letter``
records into the owning store's journal so queued-but-not-yet-embedded
rows survive a crash too.

:class:`FaultPlan` is the deterministic fault-injection harness: named
crash/error points (``append:post-journal``, ``compact:post-journal``,
``journal:torn-tail``, ``snapshot:pre-rename``, ...) are threaded through
the store and the vectorizer worker so every recovery path is exercised
by tests rather than luck.
"""

from __future__ import annotations

import dataclasses
import io
import os
import pickle
import struct
import zlib
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "InjectedCrash",
    "FaultPlan",
    "JournalRecord",
    "StoreJournal",
]

_FRAME = struct.Struct("<II")  # payload length, crc32(payload)
_PICKLE_PROTO = 4

JOURNAL_NAME = "journal.wal"
SNAPSHOT_NAME = "snapshot.bin"


class InjectedCrash(RuntimeError):
    """Raised by :meth:`FaultPlan.reach` at the configured crash point.

    Simulates the process dying mid-operation: the exception unwinds out
    of the store/worker WITHOUT any cleanup, leaving the on-disk journal
    exactly as a real crash would.  Tests catch it, drop the in-memory
    store, and recover from disk.
    """


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection for the durability test harness.

    ``crash_at`` names ONE crash point; the first time execution reaches
    it, :class:`InjectedCrash` is raised.  Known points:

    * ``append:post-journal``  — append journaled+fsync'd, segment NOT
      yet sealed in memory (the "post-journal-pre-seal" window);
    * ``delete:post-journal``  — delete journaled, tombstones NOT flipped;
    * ``compact:post-journal`` — compaction journaled, fold NOT applied
      (the "mid-compaction" window: recovery must redo the fold);
    * ``journal:torn-tail``    — the NEXT journal record is written only
      partially (``torn_tail_bytes`` of it) before the crash, exercising
      the length+crc framing's torn-record tolerance;
    * ``snapshot:pre-rename``  — snapshot tmp file written, atomic rename
      NOT done (recovery uses the previous snapshot + full journal);
    * ``snapshot:post-rename`` — snapshot renamed into place, journal NOT
      yet rotated (recovery must skip ``seq <= snapshot.seq`` records);
    * ``vectorizer:post-embed`` — a vectorizer batch embedded but NOT yet
      ingested (recovery re-enqueues the journaled pending rows).

    ``embed_failures`` makes the embedder raise that many times before
    succeeding — the retry/backoff/dead-letter path's error injector
    (consumed via :meth:`take_embed_failure`).  ``fired`` records every
    point reached, so tests can assert the plan actually triggered.
    """

    crash_at: Optional[str] = None
    torn_tail_bytes: Optional[int] = None
    embed_failures: int = 0
    fired: List[str] = dataclasses.field(default_factory=list)

    def reach(self, point: str) -> None:
        """Record reaching ``point``; crash if the plan says so."""
        self.fired.append(point)
        if self.crash_at == point:
            self.crash_at = None  # one-shot: recovery must not re-crash
            raise InjectedCrash(point)

    def tears_next_write(self) -> bool:
        """True when the next journal write should be torn (partial)."""
        return self.crash_at == "journal:torn-tail"

    def take_embed_failure(self) -> bool:
        """Consume one injected embedder failure (True = raise now)."""
        if self.embed_failures > 0:
            self.embed_failures -= 1
            self.fired.append("embed:failure")
            return True
        return False


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One replayed journal record."""

    seq: int
    kind: str
    payload: Dict[str, Any]


class StoreJournal:
    """Per-store write-ahead journal + snapshot pair in one directory.

    Layout: ``<dir>/journal.wal`` (framed records) and
    ``<dir>/snapshot.bin`` (one framed record holding the pickled state
    dict, always complete thanks to the atomic rename).  ``seq`` is a
    monotonic record counter that NEVER resets — snapshot rotation
    filters replay by ``seq``, so a stale journal left behind by a crash
    between snapshot-rename and journal-truncate is harmless.

    Durability knob: ``fsync=False`` skips the per-record fsync (still
    crash-*consistent* via framing, no longer power-fail durable) — used
    by benchmarks to measure the journaling CPU cost separately from the
    disk flush.
    """

    def __init__(
        self,
        path: os.PathLike,
        *,
        fault_plan: Optional[FaultPlan] = None,
        fsync: bool = True,
    ) -> None:
        self.dir = Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.journal_path = self.dir / JOURNAL_NAME
        self.snapshot_path = self.dir / SNAPSHOT_NAME
        self.fault_plan = fault_plan
        self.fsync = fsync
        self.seq = 0                # next seq to assign
        self.records_written = 0
        self.snapshots_written = 0
        self.torn_tail_dropped = 0  # records dropped at replay
        self._clean_end: Optional[int] = None  # byte offset replay trusts
        self._fh: Optional[io.BufferedWriter] = None

    # -- framing -------------------------------------------------------------

    @staticmethod
    def _frame(payload: bytes) -> bytes:
        return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload

    def _open_for_append(self) -> io.BufferedWriter:
        if self._fh is None or self._fh.closed:
            self._fh = open(self.journal_path, "ab")
        return self._fh

    def _sync(self, fh) -> None:
        fh.flush()
        if self.fsync:
            os.fsync(fh.fileno())

    def _sync_dir(self) -> None:
        if not self.fsync:
            return
        try:
            dfd = os.open(self.dir, os.O_RDONLY)
        except OSError:  # pragma: no cover - platform without dir fds
            return
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    # -- the write path ------------------------------------------------------

    def append_record(self, kind: str, payload: Dict[str, Any]) -> int:
        """Frame, write and fsync one record; returns its seq.

        WAL discipline is the CALLER's job: journal first, apply in
        memory second.  A :class:`FaultPlan` with ``journal:torn-tail``
        writes only a prefix of the frame (simulating power loss mid
        ``write(2)``) and then crashes.
        """
        seq = self.seq
        data = pickle.dumps((seq, kind, payload), protocol=_PICKLE_PROTO)
        framed = self._frame(data)
        fh = self._open_for_append()
        plan = self.fault_plan
        if plan is not None and plan.tears_next_write():
            keep = plan.torn_tail_bytes
            if keep is None:
                keep = len(framed) // 2  # mid-payload by default
            keep = max(1, min(len(framed) - 1, int(keep)))
            fh.write(framed[:keep])
            self._sync(fh)
            plan.reach("journal:torn-tail")
            raise AssertionError("torn-tail plan must crash")  # pragma: no cover
        fh.write(framed)
        self._sync(fh)
        self.seq = seq + 1
        self.records_written += 1
        return seq

    # -- the read path -------------------------------------------------------

    def replay(self, after_seq: int = -1) -> Iterator[JournalRecord]:
        """Yield intact records with ``seq > after_seq``, in order.

        Stops cleanly at the first truncated or checksum-corrupt record
        (the torn tail a crash mid-write leaves behind); anything after a
        torn record is untrustworthy and ignored.  Advances ``self.seq``
        past the highest seq seen so subsequent writes keep the monotonic
        ordering.
        """
        if not self.journal_path.exists():
            self._clean_end = 0
            return
        raw = self.journal_path.read_bytes()
        off = 0
        self._clean_end = 0
        while off < len(raw):
            if off + _FRAME.size > len(raw):
                self.torn_tail_dropped += 1
                break
            length, crc = _FRAME.unpack_from(raw, off)
            start = off + _FRAME.size
            end = start + length
            if end > len(raw):
                self.torn_tail_dropped += 1
                break
            payload = raw[start:end]
            if zlib.crc32(payload) != crc:
                self.torn_tail_dropped += 1
                break
            seq, kind, body = pickle.loads(payload)
            off = end
            self._clean_end = off
            if seq >= self.seq:
                self.seq = seq + 1
            if seq > after_seq:
                yield JournalRecord(seq=seq, kind=kind, payload=body)

    def truncate_torn_tail(self) -> None:
        """Drop the torn bytes a crash mid-write left at the journal tail.

        Must run after :meth:`replay` and before any new write — records
        appended AFTER untruncated garbage would be unreachable by the
        next replay (it stops at the first corrupt frame).
        """
        if self._clean_end is None or not self.journal_path.exists():
            return
        if self.journal_path.stat().st_size > self._clean_end:
            with open(self.journal_path, "r+b") as fh:
                fh.truncate(self._clean_end)
                self._sync(fh)

    # -- snapshots -----------------------------------------------------------

    def write_snapshot(self, state: Dict[str, Any]) -> None:
        """Atomically persist ``state`` and rotate the journal.

        ``state`` gains a ``"seq"`` key (the last seq this snapshot
        covers); recovery replays only records after it.  Write order is
        tmp + fsync -> rename -> dir fsync -> truncate journal, with
        crash points between the steps — a crash anywhere leaves either
        the old snapshot + full journal or the new snapshot + a journal
        whose records are filtered out by seq.
        """
        state = dict(state)
        state["seq"] = self.seq - 1
        data = pickle.dumps(state, protocol=_PICKLE_PROTO)
        tmp = self.snapshot_path.with_suffix(".tmp")
        with open(tmp, "wb") as fh:
            fh.write(self._frame(data))
            self._sync(fh)
        plan = self.fault_plan
        if plan is not None:
            plan.reach("snapshot:pre-rename")
        os.replace(tmp, self.snapshot_path)
        self._sync_dir()
        if plan is not None:
            plan.reach("snapshot:post-rename")
        # rotate: all journaled state is now covered by the snapshot
        if self._fh is not None and not self._fh.closed:
            self._fh.close()
            self._fh = None
        with open(self.journal_path, "wb") as fh:
            self._sync(fh)
        self._sync_dir()
        self.snapshots_written += 1

    def load_snapshot(self) -> Optional[Dict[str, Any]]:
        """The last complete snapshot state, or None.

        The rename is atomic, so a present ``snapshot.bin`` is complete;
        the frame crc is still verified (bit rot, partial copies) and a
        corrupt snapshot raises rather than silently recovering empty.
        """
        if not self.snapshot_path.exists():
            return None
        raw = self.snapshot_path.read_bytes()
        if len(raw) < _FRAME.size:
            raise ValueError(f"snapshot {self.snapshot_path} truncated")
        length, crc = _FRAME.unpack_from(raw, 0)
        payload = raw[_FRAME.size:_FRAME.size + length]
        if len(payload) != length or zlib.crc32(payload) != crc:
            raise ValueError(f"snapshot {self.snapshot_path} corrupt")
        state = pickle.loads(payload)
        # resume the monotonic seq PAST the snapshot: after a checkpoint
        # rotates the journal empty, a reopened writer would otherwise
        # restart at seq 0 and its records would be filtered out by the
        # next recovery's ``replay(after_seq=snapshot.seq)``.
        self.seq = max(self.seq, int(state.get("seq", -1)) + 1)
        return state

    # -- introspection -------------------------------------------------------

    @property
    def journal_bytes(self) -> int:
        """Current journal file size (the replay cost proxy)."""
        try:
            return self.journal_path.stat().st_size
        except OSError:
            return 0

    def stats(self) -> Dict[str, int]:
        return {
            "seq": self.seq,
            "records_written": self.records_written,
            "snapshots_written": self.snapshots_written,
            "torn_tail_dropped": self.torn_tail_dropped,
            "journal_bytes": self.journal_bytes,
        }

    def close(self) -> None:
        if self._fh is not None and not self._fh.closed:
            self._fh.close()
        self._fh = None


def recover_pending(
    snapshot: Optional[Dict[str, Any]],
    records: List[JournalRecord],
    live_ids: "set[int]",
) -> Tuple[List[Tuple[int, str, Optional[float]]], List[Dict[str, Any]]]:
    """Reconstruct the not-yet-embedded ingest queue from a journal.

    ``enqueue`` records add rows; a row leaves the pending set when its
    id turns up live in the recovered store (an ``append`` record landed
    after it — the vectorizer embedded it) or a ``dead_letter`` record
    names it.  Returns ``(pending_rows, dead_letters)`` in enqueue order.
    """
    pending: Dict[int, Tuple[int, str, Optional[float]]] = {}
    dead: Dict[int, Dict[str, Any]] = {}
    if snapshot:
        for row in snapshot.get("pending", []):
            pending[int(row[0])] = (int(row[0]), row[1], row[2])
        for dl in snapshot.get("dead_letters", []):
            dead[int(dl["chunk_id"])] = dict(dl)
    for rec in records:
        if rec.kind == "enqueue":
            for row in rec.payload["rows"]:
                pending[int(row[0])] = (int(row[0]), row[1], row[2])
        elif rec.kind == "dead_letter":
            for dl in rec.payload["rows"]:
                dead[int(dl["chunk_id"])] = dict(dl)
                pending.pop(int(dl["chunk_id"]), None)
    out = [row for cid, row in pending.items()
           if cid not in live_ids and cid not in dead]
    return out, list(dead.values())
