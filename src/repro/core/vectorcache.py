"""VectorCache — the production Phase-2 engine (paper §3.4.1).

Holds the corpus embedding matrix in memory (the paper's core requirement),
parses the token grammar, runs the fixed-order modulation pipeline, and
returns the top-``pool`` scored candidates for Phase 3 composition.

Execution is dispatched through the :mod:`repro.core.backends` registry
via the fused ``score_select`` stage — only (pool,)-sized candidate lists
ever come back from the backend (device backends select on device) —
``engine`` accepts any registered backend name (``reference-numpy``,
``fused-numpy``, ``jit-jax``, ``pallas``, ``sharded``; the seed's
``"reference"``/``"fused"`` aliases keep working) or an
:class:`~repro.core.backends.ExecutionBackend` instance.  All backends are
algebraically identical (tested against each other in
tests/test_backends.py).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import (ExecutionBackend, finalize_candidates,
                                 get_backend)

Engine = Union[str, ExecutionBackend]

SECONDS_PER_DAY = 86400.0


class VectorCache:
    """In-memory corpus matrix + token-grammar search (paper VectorCache)."""

    def __init__(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        embed_fn: Optional[grammar.EmbedFn] = None,
        *,
        normalized: bool = False,
    ) -> None:
        self.ids = np.asarray(ids, dtype=np.int64)
        matrix = np.asarray(matrix, dtype=np.float32)
        if matrix.ndim != 2 or matrix.shape[0] != self.ids.shape[0]:
            raise ValueError(
                f"matrix shape {matrix.shape} inconsistent with {len(self.ids)} ids"
            )
        self.matrix = matrix if normalized else np.asarray(M.l2_normalize(matrix))
        self.timestamps = (
            np.asarray(timestamps, dtype=np.float64) if timestamps is not None else None
        )
        self.embed_fn = embed_fn
        self._row_of_id: Dict[int, int] = {int(i): r for r, i in enumerate(self.ids)}
        self.dim = self.matrix.shape[1]

    # -- id <-> row helpers --------------------------------------------------

    def rows_for_ids(self, chunk_ids: Sequence[int]) -> np.ndarray:
        rows = [self._row_of_id[int(i)] for i in chunk_ids if int(i) in self._row_of_id]
        return np.asarray(rows, dtype=np.int64)

    def embeddings_for_ids(self, chunk_ids: Sequence[int]) -> np.ndarray:
        rows = self.rows_for_ids(chunk_ids)
        if rows.size == 0:
            raise grammar.GrammarError(
                f"centroid: none of the ids {list(chunk_ids)[:5]}... exist in the cache"
            )
        return self.matrix[rows]

    # -- the search entry point ----------------------------------------------

    def search(
        self,
        tokens: str,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
        embed_fn: Optional[grammar.EmbedFn] = None,
    ) -> List[Tuple[int, float]]:
        """Run Phase 2: parse tokens, score candidates, select top-pool.

        ``candidate_ids`` is the Phase-1 pre-filter output (None = full
        corpus, the paper's fallback for unstructured corpora). Returns
        ``[(chunk_id, score), ...]`` sorted by descending score — exactly the
        rows the materializer writes to the temp table.
        """
        embedder = embed_fn or self.embed_fn
        if embedder is None:
            raise ValueError("VectorCache.search requires an embed function")
        plan = grammar.parse(tokens, embedder, self.embeddings_for_ids)
        return self.search_plan(plan, candidate_ids, now=now, engine=engine)

    def search_full(
        self,
        tokens: str,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
    ):
        """Like :meth:`search` but also computes the §3.2 STRUCTURAL
        operators (`cluster:K`, `central`) over the selected candidates.
        Returns (column_names, rows) — the materializer's temp-table shape.
        """
        if self.embed_fn is None:
            raise ValueError("VectorCache.search_full requires an embed function")
        plan = grammar.parse(tokens, self.embed_fn, self.embeddings_for_ids)
        base = self.search_plan(plan, candidate_ids, now=now, engine=engine)
        cols = ["id", "score"]
        if plan.cluster is not None:
            cols.append("cluster")
        if plan.central:
            cols.append("central")
        if (plan.cluster is None and not plan.central) or not base:
            return cols, base
        cols = ["id", "score"]
        from repro.core import structural

        sel_rows = self.rows_for_ids([i for i, _ in base])
        embeds = self.matrix[sel_rows]
        extra = []
        if plan.cluster is not None:
            cols.append("cluster")
            extra.append(structural.kmeans_labels(embeds, plan.cluster))
        if plan.central:
            cols.append("central")
            extra.append(structural.centrality(embeds))
        rows = [
            tuple(r) + tuple(float(e[i]) if e.dtype.kind == "f" else int(e[i])
                             for e in extra)
            for i, r in enumerate(base)
        ]
        return cols, rows

    def search_plan(
        self,
        plan: M.ModulationPlan,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
    ) -> List[Tuple[int, float]]:
        sub_rows: Optional[np.ndarray] = None
        if candidate_ids is not None:
            sub_rows = self.rows_for_ids(candidate_ids)
            if sub_rows.size == 0:
                return []
            matrix = self.matrix[sub_rows]
            ids = self.ids[sub_rows]
        else:
            matrix = self.matrix
            ids = self.ids

        days_ago = None
        if plan.decay is not None:
            if self.timestamps is None:
                raise ValueError("decay: requires timestamps in the cache")
            ts = self.timestamps if sub_rows is None else self.timestamps[sub_rows]
            ref = time.time() if now is None else now
            days_ago = np.maximum((ref - ts) / SECONDS_PER_DAY, 0.0).astype(np.float32)

        # Fused score->select: the backend returns only the top-pool
        # candidates (device backends select on device; the full (N,)
        # score array never crosses back to this layer).  MMR diverse
        # plans come back as the oversampled pool and finish host-side.
        k = min(plan.pool, matrix.shape[0])
        backend = get_backend(engine)
        (idx, vals), = backend.score_select(matrix, days_ago, [plan], [k])
        idx, vals = finalize_candidates(matrix, idx, vals, k, plan)
        return [(int(ids[i]), float(v)) for i, v in zip(idx, vals)]
