"""VectorCache — the production Phase-2 engine (paper §3.4.1).

Holds the corpus embeddings in memory (the paper's core requirement) —
now as a :class:`~repro.core.segments.SegmentedCorpusStore` rather than
one monolithic array, so a live corpus (append / tombstone / compact)
never forces a full re-upload or re-trace: a monolithic corpus is just a
one-segment store, and the legacy ``VectorCache(ids, matrix, ts)``
constructor still builds exactly that.

Execution is dispatched through the :mod:`repro.core.backends` registry
via the fused ``score_select`` stage — full-corpus searches route through
:func:`~repro.core.backends.score_select_segments` (per-segment scoring
with on-device tombstone masking + exact union merge), so only
(pool,)-sized candidate lists ever come back from the backend.  Phase-1
pre-filtered searches route through
:func:`~repro.core.backends.score_select_prefiltered`: a selectivity-aware
:class:`~repro.core.backends.PrefilterRouter` picks masked-device scoring
(candidates ∧ live masked to -inf over the SAME warm segment matrices —
zero per-query gather/upload) or host-gathering the candidate rows when
the filter is sharp, bit-identical either way.
``engine`` accepts any registered backend name (``reference-numpy``,
``fused-numpy``, ``jit-jax``, ``pallas``, ``sharded``; the seed's
``"reference"``/``"fused"`` aliases keep working) or an
:class:`~repro.core.backends.ExecutionBackend` instance.  All backends
are algebraically identical (tested against each other in
tests/test_backends.py; segmented-vs-monolithic equivalence is pinned in
tests/test_segments.py).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import (ExecutionBackend, PrefilterRouter,
                                 finalize_fusion,
                                 finalize_segment_candidates, fusion_bias_arrays,
                                 get_backend,
                                 FusedCounters, score_select_prefiltered,
                                 score_select_segments)
from repro.core.segments import SegmentedCorpusStore

Engine = Union[str, ExecutionBackend]


class VectorCache:
    """Segmented in-memory corpus + token-grammar search (paper VectorCache).

    ``ids``/``matrix``/``timestamps`` remain available as properties — the
    LIVE view (tombstoned rows dropped), rebuilt lazily when the store
    version changes and zero-copy for a fully-live single segment — so
    every monolithic consumer (benchmarks, structural operators, Phase-1
    pre-filter sub-corpus scoring) keeps working unchanged.
    """

    def __init__(
        self,
        ids: Sequence[int] = (),
        matrix: Optional[np.ndarray] = None,
        timestamps: Optional[Sequence[float]] = None,
        embed_fn: Optional[grammar.EmbedFn] = None,
        *,
        normalized: bool = False,
        store: Optional[SegmentedCorpusStore] = None,
        prefilter: Optional[PrefilterRouter] = None,
        lexical_fn: Optional[grammar.LexicalFn] = None,
    ) -> None:
        if store is not None:
            if matrix is not None or len(ids):
                raise ValueError("pass either (ids, matrix) or store=, not both")
            self.store = store
        else:
            if matrix is None:
                raise ValueError("VectorCache requires a matrix or a store")
            matrix = np.asarray(matrix, dtype=np.float32)
            if matrix.ndim != 2 or matrix.shape[0] != len(ids):
                raise ValueError(
                    f"matrix shape {matrix.shape} inconsistent with "
                    f"{len(ids)} ids"
                )
            self.store = SegmentedCorpusStore(dim=matrix.shape[1])
            self.store.append(ids, matrix, timestamps, normalized=normalized)
        self.embed_fn = embed_fn
        # keyword: resolver for hybrid fusion — (text, pool) -> (ids,
        # minmax bm25 scores).  RetrievalService wires an FTS5-backed one;
        # None makes keyword: queries raise an explicit GrammarError.
        self.lexical_fn = lexical_fn
        # Phase-1 filtered retrieval: the selectivity-aware router (shared
        # with the batched engine, so direct and batched filtered queries
        # route — and count — identically)
        self.prefilter = prefilter or PrefilterRouter()
        # fused-Phase-2 counters (device MMR vs host pool transfers, panel
        # batches) — shared with the batched engine for the same reason
        self.fused = FusedCounters()
        self._view: Optional[Tuple] = None
        self._view_version = -1

    @property
    def dim(self) -> int:
        return self.store.dim

    # -- live view (monolithic compatibility surface) ------------------------

    def _live_view(self):
        store = self.store
        with store.lock:
            if self._view is not None and self._view_version == store.version:
                return self._view
            segs = [s for s in store.segments if s.live_count]
            if not segs:
                view = (np.zeros(0, np.int64),
                        np.zeros((0, store.dim), np.float32),
                        None, {})
            elif len(segs) == 1 and segs[0].n_dead == 0:
                seg = segs[0]  # zero-copy: the segment IS the view
                view = (seg.ids, seg.matrix, seg.timestamps,
                        {int(i): r for r, i in enumerate(seg.ids)})
            else:
                live = [s.live_mask for s in segs]
                ids = np.concatenate([s.ids[m] for s, m in zip(segs, live)])
                mat = np.concatenate(
                    [s.matrix[m] for s, m in zip(segs, live)])
                ts = None
                if segs[0].timestamps is not None:
                    ts = np.concatenate(
                        [s.timestamps[m] for s, m in zip(segs, live)])
                view = (ids, mat, ts,
                        {int(i): r for r, i in enumerate(ids)})
            self._view = view
            self._view_version = store.version
            return view

    @property
    def ids(self) -> np.ndarray:
        return self._live_view()[0]

    @property
    def matrix(self) -> np.ndarray:
        return self._live_view()[1]

    @property
    def timestamps(self) -> Optional[np.ndarray]:
        return self._live_view()[2]

    @property
    def _row_of_id(self) -> Dict[int, int]:
        return self._live_view()[3]

    # -- ingest / delete (the live-corpus entry points) ----------------------

    def ingest(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
    ):
        """Append a batch as one new sealed segment (warm segments keep
        their device residency and compiled plans). Returns the segment."""
        return self.store.append(ids, matrix, timestamps,
                                 normalized=normalized)

    def delete(self, ids: Sequence[int], *, strict: bool = False) -> int:
        """Tombstone chunks; only the touched segments' masks change."""
        return self.store.delete(ids, strict=strict)

    def compact(self, min_live_fraction: float = 1.0) -> int:
        """Merge sparse segments (see SegmentedCorpusStore.compact)."""
        return self.store.compact(min_live_fraction)

    # -- id <-> row helpers --------------------------------------------------

    def rows_for_ids(
        self, chunk_ids: Sequence[int], *, strict: bool = False
    ) -> np.ndarray:
        """Live-view rows for ``chunk_ids``; unknown ids are dropped, or —
        with ``strict=True`` — raise a KeyError naming the missing ids."""
        row_of_id = self._row_of_id
        rows: List[int] = []
        missing: List[int] = []
        for i in chunk_ids:
            row = row_of_id.get(int(i))
            if row is None:
                missing.append(int(i))
            else:
                rows.append(row)
        if missing and strict:
            raise KeyError(
                f"ids not in the cache: {missing[:10]}"
                + (f" (+{len(missing) - 10} more)" if len(missing) > 10
                   else "")
            )
        return np.asarray(rows, dtype=np.int64)

    def embeddings_for_ids(self, chunk_ids: Sequence[int]) -> np.ndarray:
        # straight off the store's id index under its lock — no live-view
        # materialization (the view concatenates EVERY live row just to
        # gather a handful), and no torn view/version reads while the
        # engine's idle-gap compaction rebuilds segments
        rows, missing = self.store.gather_embeddings(chunk_ids)
        if rows.shape[0] == 0:
            requested = [int(i) for i in chunk_ids]
            raise grammar.GrammarError(
                f"centroid: none of the {len(requested)} requested ids "
                f"exist in the cache (missing: {requested[:10]}"
                + (f" +{len(requested) - 10} more)" if len(requested) > 10
                   else ")")
            )
        return rows

    # -- the search entry point ----------------------------------------------

    def search(
        self,
        tokens: str,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
        embed_fn: Optional[grammar.EmbedFn] = None,
        lexical_fn: Optional[grammar.LexicalFn] = None,
    ) -> List[Tuple[int, float]]:
        """Run Phase 2: parse tokens, score candidates, select top-pool.

        ``candidate_ids`` is the Phase-1 pre-filter output (None = full
        corpus, the paper's fallback for unstructured corpora). Returns
        ``[(chunk_id, score), ...]`` sorted by descending score — exactly the
        rows the materializer writes to the temp table.
        """
        embedder = embed_fn or self.embed_fn
        if embedder is None:
            raise ValueError("VectorCache.search requires an embed function")
        plan = grammar.parse(tokens, embedder, self.embeddings_for_ids,
                             lexical_fn or self.lexical_fn)
        return self.search_plan(plan, candidate_ids, now=now, engine=engine)

    def search_full(
        self,
        tokens: Optional[str] = None,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
        base_search=None,
        lexical_fn: Optional[grammar.LexicalFn] = None,
        plan: Optional[M.ModulationPlan] = None,
    ):
        """Like :meth:`search` but also computes the §3.2 STRUCTURAL
        operators (`cluster:K`, `central`) over the selected candidates.
        Returns (column_names, rows) — the materializer's temp-table shape.

        ``base_search(plan, k)``, when given, produces the base ranking in
        place of :meth:`search_plan` — the materializer uses it to route
        queries through the async batched engine so SQL-surface traffic
        micro-batches and pipelines with everything else.  ``plan`` skips
        parsing entirely (the HYBRID_SEARCH / VECTOR_SEARCH pseudo-calls
        build their plans directly); ``lexical_fn`` overrides the cache's
        keyword resolver (the materializer injects its FTS5-backed one).
        """
        if plan is None:
            if tokens is None:
                raise ValueError("search_full requires tokens or a plan")
            if self.embed_fn is None:
                raise ValueError(
                    "VectorCache.search_full requires an embed function")
            plan = grammar.parse(tokens, self.embed_fn,
                                 self.embeddings_for_ids,
                                 lexical_fn or self.lexical_fn)
        if base_search is not None:
            base = base_search(plan, plan.pool)
        else:
            base = self.search_plan(plan, candidate_ids, now=now,
                                    engine=engine)
        # ONE column-assembly block shared by the early-return and
        # structural paths (they previously each built their own)
        cols = ["id", "score"]
        if plan.cluster is not None:
            cols.append("cluster")
        if plan.central:
            cols.append("central")
        if len(cols) == 2 or not base:
            return cols, base
        from repro.core import structural

        # gather the <=pool selected rows straight off the store's id
        # index — materializing the full live-view matrix for this gather
        # cost O(corpus) per structural query; a racing delete between
        # scoring and this gather just drops the affected rows
        embeds, missing = self.store.gather_embeddings([i for i, _ in base])
        if missing:
            gone = set(missing)
            base = [r for r in base if int(r[0]) not in gone]
            if not base:
                return cols, base
        extra = []
        if plan.cluster is not None:
            extra.append(structural.kmeans_labels(embeds, plan.cluster))
        if plan.central:
            extra.append(structural.centrality(embeds))
        rows = [
            tuple(r) + tuple(float(e[i]) if e.dtype.kind == "f" else int(e[i])
                             for e in extra)
            for i, r in enumerate(base)
        ]
        return cols, rows

    def search_plan(
        self,
        plan: M.ModulationPlan,
        candidate_ids: Optional[Sequence[int]] = None,
        *,
        now: Optional[float] = None,
        engine: Engine = "reference",
    ) -> List[Tuple[int, float]]:
        backend = get_backend(engine)
        ref = time.time() if now is None else now

        # fuse:filter plans promote the lexical FTS hit set to the
        # Phase-1 candidate set (intersecting an existing SQL filter),
        # so the selectivity-aware prefilter router below applies to
        # the lexical leg exactly as to a SQL pre-filter
        candidate_ids = M.filter_candidate_ids(plan, candidate_ids)

        if candidate_ids is not None:
            # Phase-1 pre-filtered query: the selectivity-aware router
            # (self.prefilter) picks masked-device scoring of the warm
            # per-segment matrices vs gathering the candidate rows into a
            # scratch matrix — same device-pass/host-tail split as the
            # full-corpus path, same lock discipline.  Non-strict: ids
            # deleted between the Phase-1 SQL and this pass drop silently.
            with self.store.lock:
                segs = self.store.segments
                n_live = self.store.n_live
                if (plan.decay is not None
                        and not self.store.has_timestamps):
                    raise ValueError("decay: requires timestamps in the cache")
                k = min(plan.pool, n_live)
                bias = fusion_bias_arrays(self.store, segs, [plan])
                selected = score_select_prefiltered(
                    backend, self.store, segs, [plan], [k], candidate_ids,
                    now=ref, router=self.prefilter, counters=self.fused,
                    score_bias=bias)
            (results,) = finalize_segment_candidates(
                segs, [plan], [k], selected,
                mmr_done=backend.device_mmr, counters=self.fused)
            return finalize_fusion(plan, results, k, store=self.store,
                                   candidate_ids=candidate_ids)

        # Full corpus: the two-stage segmented pipeline.  The DEVICE PASS
        # (score_select_segments) runs under the store lock so ingest /
        # delete land between searches, never inside one; the HOST TAIL
        # (finalize_segment_candidates: gather + MMR + id resolution)
        # needs only the immutable segment snapshot, so it runs outside
        # the lock — the same split the async engine pipelines.
        with self.store.lock:
            segs = self.store.segments
            if plan.decay is not None and not self.store.has_timestamps:
                raise ValueError("decay: requires timestamps in the cache")
            n_live = self.store.n_live
            k = min(plan.pool, n_live)
            bias = fusion_bias_arrays(self.store, segs, [plan])
            selected = score_select_segments(
                backend, segs, [plan], [k], now=ref, counters=self.fused,
                score_bias=bias)
        (results,) = finalize_segment_candidates(
            segs, [plan], [k], selected, mmr_done=backend.device_mmr,
            counters=self.fused)
        return finalize_fusion(plan, results, k, store=self.store)
