"""Query materializer (paper contribution #1, §3.2).

``vec_ops()`` and ``keyword()`` are NOT SQLite functions or virtual tables.
They are pseudo-functions recognized here, *before* SQLite sees the query:

1. scan the agent's SQL for pseudo-function calls in FROM/JOIN position
   (a quote-aware scanner, not a full SQL parser — paper §7 Limitations),
2. dispatch each call to its engine (the ``ExecutionBackend`` registry's
   fused score->select stage for ``vec_ops`` — only top-``pool`` candidate
   rows come back from the backend, never full score arrays — FTS5 for
   ``keyword``), running the embedded Phase-1 pre-filter SQL first,
3. write each result to a temp table,
4. rewrite the statement to reference the temp tables,
5. hand the rewritten statement to SQLite (Phase 3 composition).

Failure mode is an explicit ``MaterializeError`` (the agent retries), never
silent misexecution.

Live-corpus ingest (the delta surface): ``INSERT INTO chunks ...`` and
``DELETE FROM chunks ...`` are recognized and routed — the row change
applies to SQLite (``_raw_chunks`` + FTS5 sync), missing embeddings are
computed from ``content`` via the cache's embed function, and the
VectorCache ingests/tombstones the same ids, invalidating nothing but the
touched segment (warm segments keep their device residency and compiled
plans).  Every other write statement stays rejected.
"""

from __future__ import annotations

import dataclasses
import itertools
import re
import sqlite3
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# Monotonic across all Materializer instances sharing a connection: temp
# tables live on the CONNECTION, so names must be process-unique.
_TEMP_IDS = itertools.count(1)

from repro.core import grammar
from repro.core import modulations as M
from repro.core.backends import ExecutionBackend, get_backend
from repro.core.vectorcache import VectorCache

# scanned case-insensitively; the canonical (lowercase) spelling is what
# PseudoCall.func carries
_PSEUDO_FUNCS = ("vec_ops", "vector_search", "keyword", "hybrid_search")
_READONLY_RE = re.compile(r"^\s*(SELECT|WITH)\b", re.IGNORECASE)
# the ingest surface: writes against the `chunks` view ONLY (`\b` keeps
# `_raw_chunks` and friends rejected by the read-only check below)
_INSERT_CHUNKS_RE = re.compile(r"^(\s*INSERT\s+INTO\s+)chunks\b",
                               re.IGNORECASE)
_DELETE_CHUNKS_RE = re.compile(r"^\s*DELETE\s+FROM\s+chunks\b",
                               re.IGNORECASE)


class MaterializeError(RuntimeError):
    """Explicit rewrite/execution failure surfaced to the agent via MCP."""


@dataclasses.dataclass
class PseudoCall:
    func: str            # 'vec_ops' | 'vector_search' | 'keyword' | 'hybrid_search'
    args: List[Union[str, float]]  # decoded string/numeric literal arguments
    start: int           # span of the call in the original SQL text
    end: int


# ---------------------------------------------------------------------------
# Quote-aware scanning
# ---------------------------------------------------------------------------


def _scan_calls(sql: str) -> List[PseudoCall]:
    """Find pseudo-function calls at the top level of the statement.

    Respects single-quoted SQL strings (with '' escapes) so that e.g. a
    pre-filter argument containing ``type = ''assistant''`` does not confuse
    the paren matcher. Nested pseudo-calls inside the *arguments* are not
    expanded (the Phase-1 subquery is plain SQL by construction).
    """
    calls: List[PseudoCall] = []
    low = sql.lower()  # case-insensitive match (HYBRID_SEARCH == hybrid_search)
    i, n = 0, len(sql)
    while i < n:
        c = sql[i]
        if c == "'":
            i = _skip_string(sql, i)
            continue
        matched = None
        for name in _PSEUDO_FUNCS:
            if low.startswith(name, i) and _is_word_boundary(sql, i, len(name)):
                j = i + len(name)
                while j < n and sql[j] in " \t\n":
                    j += 1
                if j < n and sql[j] == "(":
                    matched = (name, j)
                break
        if matched is None:
            i += 1
            continue
        name, open_paren = matched
        close = _match_paren(sql, open_paren)
        args = _split_args(sql[open_paren + 1 : close])
        calls.append(PseudoCall(func=name, args=args, start=i, end=close + 1))
        i = close + 1
    return calls


def _skip_string(sql: str, i: int) -> int:
    """i points at an opening quote; return index just past the string."""
    j = i + 1
    n = len(sql)
    while j < n:
        if sql[j] == "'":
            if j + 1 < n and sql[j + 1] == "'":
                j += 2
                continue
            return j + 1
        j += 1
    raise MaterializeError(f"unterminated string literal at offset {i}")


def _is_word_boundary(sql: str, i: int, length: int) -> bool:
    before_ok = i == 0 or not (sql[i - 1].isalnum() or sql[i - 1] == "_")
    j = i + length
    after_ok = j >= len(sql) or not (sql[j].isalnum() or sql[j] == "_")
    return before_ok and after_ok


def _match_paren(sql: str, open_paren: int) -> int:
    depth = 0
    i = open_paren
    n = len(sql)
    while i < n:
        c = sql[i]
        if c == "'":
            i = _skip_string(sql, i)
            continue
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i
        i += 1
    raise MaterializeError(f"unbalanced parentheses at offset {open_paren}")


def _split_args(body: str) -> List[Union[str, float]]:
    """Split top-level comma-separated literal arguments and decode.

    String literals decode to str; bare numeric literals (the
    ``HYBRID_SEARCH('q', 0.7)`` weight) decode to float.  Anything else
    stays an explicit error.
    """
    args: List[str] = []
    i, n = 0, len(body)
    depth = 0
    start = 0
    while i < n:
        c = body[i]
        if c == "'":
            i = _skip_string(body, i)
            continue
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        elif c == "," and depth == 0:
            args.append(body[start:i])
            start = i + 1
        i += 1
    tail = body[start:].strip()
    if tail or args:
        args.append(body[start:])
    decoded: List[Union[str, float]] = []
    for a in args:
        a = a.strip()
        if a.startswith("'") and a.endswith("'") and len(a) >= 2:
            decoded.append(a[1:-1].replace("''", "'"))
            continue
        try:
            decoded.append(float(a))
        except ValueError:
            raise MaterializeError(
                "pseudo-function arguments must be string literals "
                f"(or numeric literals), got: {a[:60]!r}"
            ) from None
    return decoded


# ---------------------------------------------------------------------------
# The materializer
# ---------------------------------------------------------------------------


class Materializer:
    """Rewrites agent SQL, dispatching pseudo-functions to their engines."""

    def __init__(
        self,
        conn: sqlite3.Connection,
        cache: Optional[VectorCache] = None,
        *,
        fts_table: str = "chunks_fts",
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "reference",
        serving=None,
    ) -> None:
        self.conn = conn
        self.cache = cache
        self.fts_table = fts_table
        self.now = now
        # resolve through the shared backend registry up front so an unknown
        # engine fails at construction, not mid-rewrite
        self.engine = get_backend(engine)
        # optional async batched engine: when attached, vec_ops base
        # rankings route through it so SQL-surface queries — filtered ones
        # included — micro-batch and pipeline with all other traffic
        # instead of scoring synchronously on this thread
        self.serving = serving

    # -- public API ----------------------------------------------------------

    def execute(
        self, sql: str, params: Sequence = ()
    ) -> Tuple[List[str], List[tuple]]:
        """Full 3-phase execution. Returns (column names, rows).

        ``INSERT INTO chunks`` / ``DELETE FROM chunks`` route to the
        delta-ingest surface (SQLite + FTS + VectorCache stay in sync);
        all other statements must be read-only SELECT/WITH.
        """
        if _INSERT_CHUNKS_RE.match(sql):
            return self._execute_ingest_insert(sql, params)
        if _DELETE_CHUNKS_RE.match(sql):
            return self._execute_ingest_delete(sql, params)
        rewritten = self.rewrite(sql)
        if not _READONLY_RE.match(rewritten):
            raise MaterializeError("only read-only SELECT/WITH statements are allowed")
        try:
            cur = self.conn.execute(rewritten, params)
        except sqlite3.Error as e:
            raise MaterializeError(f"SQL error after rewrite: {e}") from e
        cols = [d[0] for d in cur.description] if cur.description else []
        return cols, cur.fetchall()

    def rewrite(self, sql: str) -> str:
        """Phases 1+2: materialize every pseudo-call, rewrite references."""
        calls = _scan_calls(sql)
        out = []
        pos = 0
        for call in calls:
            table = self._materialize(call)
            out.append(sql[pos : call.start])
            out.append(table)
            pos = call.end
        out.append(sql[pos:])
        return "".join(out)

    # -- dispatch ------------------------------------------------------------

    def _materialize(self, call: PseudoCall) -> str:
        if call.func == "vec_ops":
            return self._materialize_vec_ops(call)
        if call.func == "keyword":
            return self._materialize_keyword(call)
        if call.func == "hybrid_search":
            return self._materialize_hybrid_search(call)
        if call.func == "vector_search":
            return self._materialize_vector_search(call)
        raise MaterializeError(f"unknown pseudo-function {call.func}")

    def _fresh_table(self, prefix: str) -> str:
        name = f"_{prefix}_{next(_TEMP_IDS)}"
        self.conn.execute(f"DROP TABLE IF EXISTS {name}")
        return name

    def _materialize_vec_ops(self, call: PseudoCall) -> str:
        if not 1 <= len(call.args) <= 2:
            raise MaterializeError(
                f"vec_ops expects 1-2 string arguments, got {len(call.args)}"
            )
        tokens = call.args[0]
        if not isinstance(tokens, str):
            raise MaterializeError("vec_ops: token argument must be a string")
        prefilter_sql = None
        if len(call.args) == 2:
            if not isinstance(call.args[1], str):
                raise MaterializeError("vec_ops: pre-filter must be a string")
            prefilter_sql = call.args[1]
        return self._materialize_search("vec_ops", tokens=tokens,
                                        prefilter_sql=prefilter_sql)

    def _materialize_hybrid_search(self, call: PseudoCall) -> str:
        """``HYBRID_SEARCH('query'[, weight])`` — weighted lexical+vector
        fusion sugar: one text drives BOTH legs (``similar:`` through the
        fused device pipeline, ``keyword:`` through FTS5/BM25), fused as
        ``weight*vector + (1-weight)*minmax(bm25)`` on device."""
        if self.cache is None:
            raise MaterializeError("hybrid_search: no VectorCache attached")
        if not 1 <= len(call.args) <= 2:
            raise MaterializeError(
                f"hybrid_search expects ('query'[, weight]), got {len(call.args)} args"
            )
        query = call.args[0]
        if not isinstance(query, str) or not query.strip():
            raise MaterializeError(
                "hybrid_search: first argument must be the query string")
        weight = M.DEFAULT_FUSE_WEIGHT
        if len(call.args) == 2:
            if not isinstance(call.args[1], float):
                raise MaterializeError(
                    "hybrid_search: weight must be a numeric literal")
            weight = call.args[1]
            if not 0.0 <= weight <= 1.0:
                raise MaterializeError(
                    f"hybrid_search: weight must be in [0, 1], got {weight}")
        parsed = grammar.ParsedTokens(similar=query, keyword=query,
                                      fuse_mode="weighted",
                                      fuse_weight=weight)
        return self._materialize_search("hybrid", parsed=parsed, label=query)

    def _materialize_vector_search(self, call: PseudoCall) -> str:
        """``VECTOR_SEARCH('query')`` — pure-vector sugar (plain text, no
        grammar tokens): the hybrid surface's baseline counterpart."""
        if self.cache is None:
            raise MaterializeError("vector_search: no VectorCache attached")
        if len(call.args) != 1 or not isinstance(call.args[0], str) \
                or not call.args[0].strip():
            raise MaterializeError(
                "vector_search expects exactly one query string")
        parsed = grammar.ParsedTokens(similar=call.args[0])
        return self._materialize_search("vector", parsed=parsed,
                                        label=call.args[0])

    def _materialize_search(
        self,
        kind: str,
        *,
        tokens: Optional[str] = None,
        parsed: Optional["grammar.ParsedTokens"] = None,
        prefilter_sql: Optional[str] = None,
        label: Optional[str] = None,
    ) -> str:
        """Shared Phase-1+2 driver behind every retrieval pseudo-call.

        Materializes the unified result contract ``(id, score, snippet
        [, cluster, central])`` — scores min-max normalized over the
        result set (monotone: orderings are unchanged), snippet a content
        prefix resolved by an UPDATE join (never a 1000-parameter INSERT).
        """
        if self.cache is None:
            raise MaterializeError(f"{kind}: no VectorCache attached")
        candidate_ids = None
        if prefilter_sql is not None and prefilter_sql.strip():
            if not _READONLY_RE.match(prefilter_sql):
                raise MaterializeError(f"{kind} pre-filter must be a SELECT")
            try:
                rows = self.conn.execute(prefilter_sql).fetchall()
            except sqlite3.Error as e:
                raise MaterializeError(f"pre-filter SQL failed: {e}") from e
            candidate_ids = [r[0] for r in rows]
            if not candidate_ids:
                # Paper §7: malformed pre-filters returning no rows are an
                # agent error class; we surface an EMPTY result, not a crash.
                table = self._fresh_table(kind)
                self.conn.execute(
                    f"CREATE TEMP TABLE {table} "
                    "(id INTEGER PRIMARY KEY, score REAL, snippet TEXT)"
                )
                return table

        try:
            plan = None
            if parsed is not None:
                plan = grammar.build_plan(
                    parsed, self.cache.embed_fn,
                    self.cache.embeddings_for_ids, self._lexical_scores)
            base_search = None
            if self.serving is not None:
                # hand the parsed plan over so admission skips the
                # duplicate parse+embed of the same tokens
                req_tokens = tokens if tokens is not None else (label or "")
                base_search = (lambda p, k: self.serving.search(
                    req_tokens, k=k, candidate_ids=candidate_ids, plan=p))
            cols, results = self.cache.search_full(
                tokens, candidate_ids, now=self.now, engine=self.engine,
                base_search=base_search, lexical_fn=self._lexical_scores,
                plan=plan,
            )
        except Exception as e:  # grammar errors -> explicit failure
            raise MaterializeError(f"{kind} failed: {e}") from e

        # the unified result-row contract: score min-max normalized,
        # snippet after score, structural columns (§3.2) trailing
        if results:
            norm = M.minmax_normalize(
                np.asarray([r[1] for r in results], np.float32))
            results = [(r[0], float(v)) + tuple(r[2:])
                       for r, v in zip(results, norm)]
        cols = cols[:2] + ["snippet"] + cols[2:]

        table = self._fresh_table(kind)
        decls = {"id": "INTEGER PRIMARY KEY", "score": "REAL",
                 "snippet": "TEXT", "cluster": "INTEGER", "central": "REAL"}
        col_sql = ", ".join(f"{c} {decls[c]}" for c in cols)
        self.conn.execute(f"CREATE TEMP TABLE {table} ({col_sql})")
        ins_cols = [c for c in cols if c != "snippet"]
        ph = ",".join("?" * len(ins_cols))
        self.conn.executemany(
            f"INSERT OR REPLACE INTO {table} ({', '.join(ins_cols)}) "
            f"VALUES ({ph})",
            results,
        )
        # snippet via UPDATE join: immune to SQLite's host-parameter limit
        self.conn.execute(
            f"UPDATE {table} SET snippet = ("
            f"SELECT substr(c.content, 1, 96) FROM _raw_chunks c "
            f"WHERE c.id = {table}.id)"
        )
        return table

    def _materialize_keyword(self, call: PseudoCall) -> str:
        if len(call.args) != 1 or not isinstance(call.args[0], str):
            raise MaterializeError("keyword expects exactly one string argument")
        term = call.args[0]
        table = self._fresh_table("kw")
        self.conn.execute(
            f"CREATE TEMP TABLE {table} "
            "(id INTEGER PRIMARY KEY, score REAL, snippet TEXT)"
        )
        rows = self._fts_query(term)
        if rows:
            # unified contract: min-max normalized scores, same (id,
            # score, snippet) shape as every other retrieval pseudo-call
            norm = M.minmax_normalize(
                np.asarray([r[1] for r in rows], np.float32))
            rows = [(r[0], float(v), r[2]) for r, v in zip(rows, norm)]
        self.conn.executemany(
            f"INSERT OR REPLACE INTO {table} (id, score, snippet) VALUES (?, ?, ?)",
            rows,
        )
        return table

    # -- delta ingest (INSERT/DELETE against the chunks view) ----------------

    def _execute_ingest_insert(
        self, sql: str, params: Sequence
    ) -> Tuple[List[str], List[tuple]]:
        """``INSERT INTO chunks ...`` -> _raw_chunks + FTS + cache segment.

        The statement runs against the base table (column names are the
        base-table ones, e.g. ``created_at``); a temp trigger captures the
        inserted ids whatever the INSERT's shape (VALUES lists, SELECT
        feeds).  Rows arriving without an embedding are embedded from
        ``content``; the batch then seals ONE new VectorCache segment —
        nothing else re-uploads or re-traces.
        """
        if self.cache is None:
            raise MaterializeError("ingest: no VectorCache attached")
        rewritten = _INSERT_CHUNKS_RE.sub(r"\g<1>_raw_chunks", sql, count=1)
        log = f"_ingest_log_{next(_TEMP_IDS)}"
        trig = f"_ingest_tr_{next(_TEMP_IDS)}"
        # everything up to the cache ingest runs inside ONE transaction:
        # any failure rolls the row changes back, so SQLite, FTS and the
        # vector store can never diverge (and the agent's retry of the
        # same INSERT works instead of hitting a PK conflict)
        try:
            self.conn.execute(f"CREATE TEMP TABLE {log} (id INTEGER)")
            self.conn.execute(
                f"CREATE TEMP TRIGGER {trig} AFTER INSERT ON _raw_chunks "
                f"BEGIN INSERT INTO {log} VALUES (new.id); END"
            )
            try:
                self.conn.execute(rewritten, params)
                ids = [r[0] for r in
                       self.conn.execute(f"SELECT id FROM {log}").fetchall()]
            finally:
                self.conn.execute(f"DROP TRIGGER {trig}")
                self.conn.execute(f"DROP TABLE {log}")
            if not ids:
                return ["id"], []
            ph = ",".join("?" * len(ids))
            rows = self.conn.execute(
                f"SELECT id, content, created_at, embedding FROM _raw_chunks "
                f"WHERE id IN ({ph}) ORDER BY id", ids
            ).fetchall()
            # queued-worker path: with a serving engine carrying a
            # background vectorizer, rows WITHOUT embeddings enqueue for
            # batch embedding in the scheduler's idle gaps (the INSERT
            # returns after enqueue — no inline embedder round-trip on the
            # SQL path); rows WITH embeddings still seal a segment now.
            # Without a vectorizer the legacy inline embed applies.
            vectorize = (self.serving is not None
                         and getattr(self.serving, "vectorizer", None)
                         is not None)
            ready: List[tuple] = []
            queued: List[Tuple[int, str, Optional[float]]] = []
            blob_updates = []
            emb_rows: List[np.ndarray] = []
            for cid, content, created, blob in rows:
                if blob is not None:
                    emb_rows.append(np.frombuffer(
                        blob, dtype=np.float32, count=self.cache.dim))
                    ready.append((cid, content, created))
                elif vectorize:
                    queued.append((cid, content or "", created))
                else:
                    if self.cache.embed_fn is None:
                        raise MaterializeError(
                            "ingest: rows without embeddings need an embed "
                            "function on the cache"
                        )
                    vec = np.asarray(self.cache.embed_fn(content or ""),
                                     dtype=np.float32)
                    emb_rows.append(vec)
                    blob_updates.append((vec.tobytes(), cid))
                    ready.append((cid, content, created))
            if blob_updates:
                self.conn.executemany(
                    "UPDATE _raw_chunks SET embedding = ? WHERE id = ?",
                    blob_updates,
                )
            # external-content FTS5 needs explicit sync (queued rows too:
            # the lexical leg serves them before their embedding lands)
            self.conn.executemany(
                f"INSERT INTO {self.fts_table} (rowid, content) "
                f"VALUES (?, ?)",
                [(r[0], r[1] or "") for r in rows],
            )
            if ready:
                emb = np.stack(emb_rows).astype(np.float32, copy=False)
                self.cache.ingest(
                    [r[0] for r in ready], emb,
                    [r[2] or 0.0 for r in ready]
                    if self.cache.store.has_timestamps
                    or not self.cache.store.n_segments else None,
                )
            if queued:
                # LAST step before commit: a full queue (backpressure)
                # rolls the whole INSERT back, and nothing fallible runs
                # after the rows are journaled as accepted
                try:
                    self.serving.enqueue_ingest(queued)
                except RuntimeError as e:
                    raise MaterializeError(
                        f"ingest enqueue failed: {e}") from e
        except (sqlite3.Error, ValueError) as e:
            self.conn.rollback()
            raise MaterializeError(f"ingest INSERT failed: {e}") from e
        except MaterializeError:
            self.conn.rollback()
            raise
        self.conn.commit()
        return ["id"], [(r[0],) for r in rows]

    def _execute_ingest_delete(
        self, sql: str, params: Sequence
    ) -> Tuple[List[str], List[tuple]]:
        """``DELETE FROM chunks [WHERE ...]`` -> rows out of SQLite + FTS,
        tombstones into the VectorCache (only the touched segments' masks
        change — no re-upload, no re-trace, no view rebuild elsewhere)."""
        from repro.sqlio.schema import delete_chunks

        m = _DELETE_CHUNKS_RE.match(sql)
        predicate = sql[m.end():]  # WHERE clause, view column names work
        try:
            ids = [r[0] for r in self.conn.execute(
                f"SELECT id FROM chunks {predicate}", params).fetchall()]
        except sqlite3.Error as e:
            raise MaterializeError(f"ingest DELETE failed: {e}") from e
        removed = delete_chunks(self.conn, ids, fts_table=self.fts_table)
        if self.cache is not None and removed:
            self.cache.delete(removed)
        if removed and self.serving is not None:
            vec = getattr(self.serving, "vectorizer", None)
            if vec is not None:
                # a row may still be queued for background embedding: the
                # DELETE must not let the worker resurrect it later
                vec.queue.discard(removed)
        return ["id"], [(i,) for i in removed]

    def _fts_query(self, term: str, limit: int = M.DEFAULT_POOL) -> List[tuple]:
        """FTS5 BM25 with automatic fallback quoting for special chars.

        ``limit`` comes from the plan's ``pool:`` width on the hybrid path
        (formerly a hardcoded 500 that silently truncated wide pools).
        """
        return fts_query(self.conn, term, limit=limit,
                         fts_table=self.fts_table)

    def _lexical_scores(self, term: str, limit: int) -> Tuple[np.ndarray, np.ndarray]:
        """``grammar.LexicalFn``: keyword text + pool width -> BM25 hits.

        Returns ``(ids desc-by-bm25, min-max normalized scores in [0,1])``
        — the lexical leg every ``keyword:`` / ``HYBRID_SEARCH`` plan built
        through this materializer fuses on device.
        """
        rows = self._fts_query(term, limit=limit)
        if not rows:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.float32))
        ids = np.asarray([r[0] for r in rows], dtype=np.int64)
        scores = M.minmax_normalize(
            np.asarray([r[1] for r in rows], np.float32))
        return ids, scores


def fts_query(
    conn: sqlite3.Connection,
    term: str,
    limit: int = M.DEFAULT_POOL,
    fts_table: str = "chunks_fts",
) -> List[tuple]:
    """FTS5 BM25 query: ``(rowid, -bm25 rank, snippet)`` desc by rank.

    Module-level so serving-layer lexical resolvers (RetrievalService) can
    share the exact quoting/fallback semantics without a Materializer.
    """
    fts = fts_table
    sql = (
        f"SELECT rowid, -bm25({fts}) AS rank, "
        f"snippet({fts}, -1, '[', ']', '…', 12) "
        f"FROM {fts} WHERE {fts} MATCH ? ORDER BY rank DESC LIMIT ?"
    )
    try:
        return conn.execute(sql, (term, int(limit))).fetchall()
    except sqlite3.OperationalError:
        # Fallback quoting (paper Appendix B): dots/operators in the term
        # break FTS5 syntax; quote each whitespace token and retry.
        quoted = " ".join(f'"{t}"' for t in term.split())
        try:
            return conn.execute(sql, (quoted, int(limit))).fetchall()
        except sqlite3.OperationalError as e:
            raise MaterializeError(f"keyword: FTS5 rejected {term!r}: {e}") from e
