"""ExecutionBackend — the single engine-dispatch seam (all Phase-2 paths).

Before this module, engine selection was three divergent mechanisms:
string dispatch inside ``VectorCache.search_plan``, hand-rolled fused
matmuls in ``BatchedRetrievalEngine._serve``, and pass-through strings in
``Materializer``/``RetrievalService``.  Now every consumer resolves a
backend from ONE registry and calls the same two primitives:

    score(matrix, days_ago, plan)         -> (N,)   one request
    score_panel(matrix, days_ago, plans)  -> (N, B) a micro-batch

plus the shared :func:`select_candidates` (top-k / MMR oversample) so the
batched and direct paths rank identically.  Registered backends:

    reference-numpy  paper-faithful, one matvec per direction (Table 1)
    fused-numpy      folded two-matvec formulation (one corpus stream)
    jit-jax          the fused formulation jitted through XLA
    pallas           the fused TPU kernel (interpret mode off-TPU)
    sharded          shard_map row-sharded scoring over the local devices

All are algebraically identical on the composed plan grammar; the
equivalence suite (tests/test_backends.py) pins each against the
reference oracle.  Later scaling PRs (multi-host, async, cache tiering)
plug in here via :func:`register_backend`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import modulations as M

__all__ = [
    "ExecutionBackend",
    "get_backend",
    "register_backend",
    "list_backends",
    "select_candidates",
]


def _require_days(plan: M.ModulationPlan, days_ago: Optional[np.ndarray]) -> None:
    if plan.decay is not None and days_ago is None:
        raise ValueError("decay: modulation requires per-chunk timestamps")


def _decay_column(days_ago: np.ndarray, half_life: float) -> np.ndarray:
    return 1.0 / (1.0 + days_ago / half_life)


class ExecutionBackend:
    """One Phase-2 scoring implementation.

    Subclasses implement :meth:`score_panel`; :meth:`score` defaults to the
    single-column case.  Scores are returned as host numpy arrays — the
    selection stage (top-k / MMR) is host-side in every serving path.
    """

    name: str = "?"

    def score(
        self,
        matrix: np.ndarray,
        days_ago: Optional[np.ndarray],
        plan: M.ModulationPlan,
    ) -> np.ndarray:
        return self.score_panel(matrix, days_ago, [plan])[:, 0]

    def score_panel(
        self,
        matrix: np.ndarray,
        days_ago: Optional[np.ndarray],
        plans: Sequence[M.ModulationPlan],
    ) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ExecutionBackend {self.name}>"


class ReferenceNumpyBackend(ExecutionBackend):
    """Paper-faithful: one matvec per direction, exactly Table 1."""

    name = "reference-numpy"

    def score(self, matrix, days_ago, plan):
        return np.asarray(M.modulate_scores(matrix, days_ago, plan))

    def score_panel(self, matrix, days_ago, plans):
        cols = [self.score(matrix, days_ago, p) for p in plans]
        return np.stack(cols, axis=1)


class FusedNumpyBackend(ExecutionBackend):
    """Folded two-matvec formulation: the corpus matrix streams once.

    scores[:, j] = decay_j * (M @ q_pre[:, j]) + M @ q_sup[:, j]
    with per-request decay half-lives applied column-wise.
    """

    name = "fused-numpy"

    def score(self, matrix, days_ago, plan):
        return np.asarray(M.fused_modulate_scores(matrix, days_ago, plan))

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        q_pre, q_sup = M.fold_plans(plans)
        base = matrix @ q_pre                           # ONE pass (N, B)
        sup = matrix @ q_sup
        out = np.empty_like(base)
        for j, plan in enumerate(plans):
            col = base[:, j]
            if plan.decay is not None:
                col = col * _decay_column(days_ago, plan.decay.half_life_days)
            out[:, j] = col + sup[:, j]
        return out


class JitJaxBackend(ExecutionBackend):
    """The fused formulation jitted through XLA (CPU/GPU/TPU portable).

    Per-request decay folds into a (N, B) factor panel; half_life=inf makes
    the factor exactly 1.0 for no-decay columns, so one jitted graph serves
    every plan mix without recompiling on plan structure.
    """

    name = "jit-jax"

    def __init__(self) -> None:
        self._fn = None
        self._mat_src: Optional[np.ndarray] = None
        self._mat_dev = None

    def _device_matrix(self, matrix: np.ndarray):
        """Cache the device-resident corpus (it is immutable across calls;
        re-uploading ~123 MB per micro-batch would dominate the matmul)."""
        if self._mat_src is not matrix:
            import jax.numpy as jnp

            self._mat_dev = jnp.asarray(matrix, jnp.float32)
            self._mat_src = matrix
        return self._mat_dev

    def _build(self):
        import jax

        @jax.jit
        def fused(matrix, q_pre, q_sup, days, half_lives):
            decay = 1.0 / (1.0 + days[:, None] / half_lives[None, :])
            return decay * (matrix @ q_pre) + matrix @ q_sup

        return fused

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        if self._fn is None:
            self._fn = self._build()
        q_pre, q_sup = M.fold_plans(plans)
        half = np.asarray(
            [p.decay.half_life_days if p.decay is not None else np.inf
             for p in plans],
            dtype=np.float32,
        )
        n = matrix.shape[0]
        days = (np.zeros(n, np.float32) if days_ago is None
                else np.asarray(days_ago, np.float32))
        return np.asarray(
            self._fn(self._device_matrix(matrix), q_pre, q_sup, days, half)
        )


class PallasBackend(ExecutionBackend):
    """The fused TPU kernel (``repro.kernels.pem_score``).

    Off-TPU the kernel runs in Pallas interpret mode (the same path the
    kernel tests validate).  The kernel takes one decay column per call, so
    requests group by half-life and each group scores in one kernel launch.
    """

    name = "pallas"

    def score_panel(self, matrix, days_ago, plans):
        import jax
        import jax.numpy as jnp

        from repro.kernels.pem_score.ops import pem_score

        for p in plans:
            _require_days(p, days_ago)
        q_pre, q_sup = M.fold_plans(plans)
        interpret = jax.default_backend() != "tpu"
        mat = jnp.asarray(matrix, jnp.float32)
        out = np.empty((matrix.shape[0], len(plans)), np.float32)

        groups: Dict[Optional[float], List[int]] = {}
        for j, plan in enumerate(plans):
            hl = plan.decay.half_life_days if plan.decay is not None else None
            groups.setdefault(hl, []).append(j)
        for hl, cols in groups.items():
            decay = None
            if hl is not None:
                decay = jnp.asarray(_decay_column(days_ago, hl), jnp.float32)
            res = pem_score(
                mat,
                jnp.asarray(q_pre[:, cols]),
                jnp.asarray(q_sup[:, cols]),
                decay,
                interpret=interpret,
            )
            out[:, cols] = np.asarray(res)
        return out


class ShardedBackend(ExecutionBackend):
    """shard_map row-sharded scoring over every locally visible device.

    The corpus rows split across a 1-D device mesh; each shard computes its
    slice of the fused score panel and the sharded output reassembles on
    the host.  On one device this degenerates to the jit path; on a real
    mesh it is the scoring stage of ``repro.dist.pem_sharded`` (which adds
    the local-top-k union merge for the selection side).
    """

    name = "sharded"

    def __init__(self) -> None:
        self._fn = None
        self._n_shards = None
        self._mat_src: Optional[np.ndarray] = None
        self._mat_dev = None

    def _build(self):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("shards",))

        def local(matrix, q_pre, q_sup, days, half_lives):
            decay = 1.0 / (1.0 + days[:, None] / half_lives[None, :])
            return decay * (matrix @ q_pre) + matrix @ q_sup

        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(P("shards", None), P(None, None), P(None, None),
                      P("shards"), P(None)),
            out_specs=P("shards", None),
            check_rep=False,
        )
        return jax.jit(fn), n_dev

    def _device_matrix(self, matrix: np.ndarray, pad: int):
        """Cache the padded device-resident corpus across calls (the matrix
        is immutable; padding depends only on the fixed shard count)."""
        if self._mat_src is not matrix:
            import jax.numpy as jnp

            mat = np.asarray(matrix, np.float32)
            if pad:
                mat = np.pad(mat, ((0, pad), (0, 0)))
            self._mat_dev = jnp.asarray(mat)
            self._mat_src = matrix
        return self._mat_dev

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        if self._fn is None:
            # other threads key on _fn: set _n_shards FIRST so no caller can
            # observe _fn non-None with _n_shards still unset
            fn, n_shards = self._build()
            self._n_shards = n_shards
            self._fn = fn
        q_pre, q_sup = M.fold_plans(plans)
        half = np.asarray(
            [p.decay.half_life_days if p.decay is not None else np.inf
             for p in plans],
            dtype=np.float32,
        )
        n = matrix.shape[0]
        days = (np.zeros(n, np.float32) if days_ago is None
                else np.asarray(days_ago, np.float32))
        # pad the row grid to the shard count, slice the panel back
        pad = (-n) % self._n_shards
        mat = self._device_matrix(matrix, pad)
        if pad:
            days = np.pad(days, (0, pad))
        out = np.asarray(self._fn(mat, q_pre, q_sup, days, half))
        return out[:n]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ExecutionBackend] = {}
_ALIASES = {
    # the seed's public engine strings keep working
    "reference": "reference-numpy",
    "fused": "fused-numpy",
    "jax": "jit-jax",
}


def register_backend(backend: ExecutionBackend) -> ExecutionBackend:
    _REGISTRY[backend.name] = backend
    return backend


register_backend(ReferenceNumpyBackend())
register_backend(FusedNumpyBackend())
register_backend(JitJaxBackend())
register_backend(PallasBackend())
register_backend(ShardedBackend())


def list_backends() -> List[str]:
    """Canonical names of every registered backend."""
    return sorted(_REGISTRY)


def get_backend(engine: Union[str, ExecutionBackend]) -> ExecutionBackend:
    """Resolve an engine name (or pass an ExecutionBackend through)."""
    if isinstance(engine, ExecutionBackend):
        return engine
    name = _ALIASES.get(engine, engine)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {engine!r}; known: {list_backends()} "
            f"(aliases: {sorted(_ALIASES)})"
        ) from None


# ---------------------------------------------------------------------------
# Shared selection (identical ranking on batched and direct paths)
# ---------------------------------------------------------------------------


def top_idx(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the top-k scores, sorted descending (argpartition+sort)."""
    if k >= scores.shape[0]:
        return np.argsort(-scores, kind="stable")
    part = np.argpartition(-scores, k)[:k]
    return part[np.argsort(-scores[part], kind="stable")]


def select_candidates(
    matrix: np.ndarray,
    scores: np.ndarray,
    k: int,
    plan: M.ModulationPlan,
) -> np.ndarray:
    """Top-k (or MMR-diverse) row selection over scored candidates.

    The MMR pool oversamples ``oversample * max(k, plan.pool)`` so a
    small-k request (batched path) and a pool-sized request (direct path)
    draw from the same pool — MMR's greedy selection is prefix-consistent,
    so their rankings agree.
    """
    n = scores.shape[0]
    k = min(k, n)
    if k <= 0:
        return np.empty(0, dtype=np.int64)
    if plan.diverse is not None:
        over = min(plan.diverse.oversample * max(k, plan.pool), n)
        pool_idx = top_idx(scores, over)
        sel = M.mmr_select_np(
            matrix[pool_idx], scores[pool_idx], k, plan.diverse.lam
        )
        return pool_idx[sel]
    return top_idx(scores, k)
