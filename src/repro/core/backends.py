"""ExecutionBackend — the single engine-dispatch seam (all Phase-2 paths).

Before this module, engine selection was three divergent mechanisms:
string dispatch inside ``VectorCache.search_plan``, hand-rolled fused
matmuls in ``BatchedRetrievalEngine._serve``, and pass-through strings in
``Materializer``/``RetrievalService``.  Now every consumer resolves a
backend from ONE registry and calls the same primitives:

    score(matrix, days_ago, plan)                    -> (N,)   one request
    score_panel(matrix, days_ago, plans)             -> (N, B) a micro-batch
    score_select(matrix, days_ago, plans, ks, mask=) -> per-plan top candidates
    score_select_segments(backend, segments, ...)    -> segmented corpus driver
    score_select_prefiltered(backend, store, ...)    -> Phase-1 filtered driver
                                                        (masked-device vs
                                                        gather-host router)
    score_select_filter_panel(backend, store, ...)   -> heterogeneous-filter
                                                        batch via one (N, B)
                                                        mask panel

``score_select`` is the fused score->select stage: it returns ONLY the
top-:func:`selection_width` candidate ``(indices, scores)`` per plan, so
device backends never ship the full (N, B) score panel back to the host —
just (pool,)-sized candidate lists cross the device boundary (Bruch,
*Foundations of Vector Retrieval*: selection-fused scoring is the standard
trick for exact search at scale).  On the device backends the chain now
covers diversity too (:class:`_DeviceMMRMixin`): MMR runs over the
oversampled pool IN the compiled graph (jit-jax/sharded) or through the
``kernels/mmr`` pallas chain, so diverse plans return only the final k and
the pool never crosses the device boundary.  The host finishing stage
(:func:`finalize_candidates`: truncate, or the :func:`mmr_host` oracle over
the oversampled pool) is shared by every host-path consumer, so batched and
direct paths rank identically — device MMR is pinned bit-identical to it.

Registered backends:

    reference-numpy  paper-faithful, one matvec per direction (Table 1)
    fused-numpy      folded two-matvec formulation (one corpus stream)
    jit-jax          fused formulation jitted through XLA + device top-k
    pallas           fused TPU kernel -> topk kernel (two launches, no
                     host hop between score and select)
    sharded          shard_map row-sharded scoring, shard-local top-k +
                     union merge (repro.dist.pem_sharded contract)

The numpy backends keep the host path (full panel + numpy selection) so the
equivalence suites (tests/test_backends.py, tests/test_score_select.py)
stay anchored to the reference oracle.  Device backends compile through a
:class:`PlanCache` (LRU-bounded) keyed on :class:`PlanStructure` — plan
*shape* (batch width, decay present/absent, suppress count bucketed by
padding, top-k width AND corpus row count bucketed to powers of two) — so
distinct query texts with the same structure never retrigger tracing, and
a stream of varying corpus/segment sizes compiles one graph per pow2
bucket, not one per exact row count.

Live corpora (`repro.core.segments`) score through
:func:`score_select_segments`: each segment scores independently (its
tombstones masked to -inf ON DEVICE via ``score_select``'s ``mask``
argument, before selection), per-segment top-k candidates merge on the
host exactly like ``dist/pem_sharded.union_merge_topk`` merges per-shard
candidates, and the result is bit-identical to a monolithic store.  The
per-array device matrix cache (:class:`_DeviceMatrixMixin`) holds one
entry per warm segment, so appending a segment uploads ONLY the delta.

All backends are algebraically identical on the composed plan grammar.
Later scaling PRs (multi-host, async, cache tiering) plug in here via
:func:`register_backend`.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import (Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core import modulations as M

__all__ = [
    "ExecutionBackend",
    "PlanCache",
    "PlanStructure",
    "get_backend",
    "register_backend",
    "list_backends",
    "select_candidates",
    "selection_width",
    "finalize_candidates",
    "score_select_segments",
    "score_select_cohort",
    "score_select_prefiltered",
    "score_select_filter_panel",
    "finalize_segment_candidates",
    "PrefilterRouter",
    "FusedCounters",
    "mmr_host",
    "plan_fusion_bias",
    "fusion_bias_arrays",
    "finalize_fusion",
]

Candidates = Tuple[np.ndarray, np.ndarray]  # (indices, scores), descending


def _require_days(plan: M.ModulationPlan, days_ago: Optional[np.ndarray]) -> None:
    if plan.decay is not None and days_ago is None:
        raise ValueError("decay: modulation requires per-chunk timestamps")


def _decay_column(days_ago: np.ndarray, half_life: float) -> np.ndarray:
    return 1.0 / (1.0 + days_ago / half_life)


def _pow2_bucket(x: int) -> int:
    """0 for x<=0, else the next power of two >= x (trace-bounding pad)."""
    if x <= 0:
        return 0
    return 1 << (x - 1).bit_length()


def _half_lives(plans: Sequence[M.ModulationPlan]) -> np.ndarray:
    """Per-plan half-life column; inf makes the decay factor exactly 1.0."""
    return np.asarray(
        [p.decay.half_life_days if p.decay is not None else np.inf
         for p in plans],
        dtype=np.float32,
    )


def _days_f32(days_ago: Optional[np.ndarray], n: int) -> np.ndarray:
    return (np.zeros(n, np.float32) if days_ago is None
            else np.asarray(days_ago, np.float32))


def _empty_candidates() -> Candidates:
    return np.empty(0, np.int64), np.empty(0, np.float32)


def _slice_candidates(idx, vals, widths: Sequence[int]) -> List[Candidates]:
    """Host tail shared by every device ``score_select``: fetch the
    (B, width) blocks — the ONLY device->host copy — and slice each plan's
    prefix (rows are sorted descending, so the first w are its top-w)."""
    idx = np.asarray(idx)
    vals = np.asarray(vals)
    return [(idx[j, :w].astype(np.int64), vals[j, :w])
            for j, w in enumerate(widths)]


def mmr_host(
    pool_embeds: np.ndarray,
    pool_scores: np.ndarray,
    k: int,
    lam: float,
) -> np.ndarray:
    """Host MMR over an oversampled candidate pool -> selection positions.

    THE oracle every fused device-MMR path (:class:`_DeviceMMRMixin`, the
    ``kernels/mmr`` pallas chain) is pinned bit-identical against, and the
    fallback the numpy backends keep.  The single call site of
    ``modulations.mmr_select_np`` — :func:`finalize_candidates` and
    :func:`select_candidates` both finish diversity here.
    """
    return M.mmr_select_np(pool_embeds, pool_scores, k, lam)


@dataclasses.dataclass
class FusedCounters:
    """Fused-Phase-2 observability (``RetrievalService.stats()["fused"]``).

    ``device_mmr`` counts diverse plans finished by on-device MMR — the
    oversample pool never crossed to the host.  ``host_pool_transfers``
    counts diverse plans that DID ship their pool back for the
    :func:`mmr_host` oracle; a regression back to host MMR shows up here
    before it shows up as latency.  ``panel_batches`` counts batched
    (N, B) mask-panel passes that served a heterogeneous-filter cohort in
    ONE device scoring pass instead of one per distinct filter.  Benign
    int bumps, same convention as the store's counters.
    """

    device_mmr: int = 0
    host_pool_transfers: int = 0
    panel_batches: int = 0

    def stats(self) -> Dict[str, int]:
        return {
            "device_mmr": self.device_mmr,
            "host_pool_transfers": self.host_pool_transfers,
            "panel_batches": self.panel_batches,
        }


# -1e30 stands in for -inf inside traced MMR bodies (0 * -inf is NaN; the
# kernels/mmr chain uses the same sentinel, see kernels/mmr/kernel.py NEG)
_MMR_NEG = -1e30


def _device_mmr_trace(emb, rel, lams, pool_w, k: int):
    """Traced batched MMR over a top-k pool (pure ``jax.lax``, runs inside
    any jitted graph — the portable equivalent of the pallas kernel).

    ``emb`` (B, W, d) pool embeddings, ``rel`` (B, W) relevance descending,
    ``lams`` (B,) per-plan lambda — 1.0 is PURE relevance, whose greedy
    selection is the identity permutation, so non-diverse columns ride the
    same graph unchanged — and ``pool_w`` (B,) TRUE pool widths: positions
    past them (static-width padding, -inf masked slots) pin to the NEG
    sentinel and can never be argmaxed while real rows remain.  Returns
    (B, k) int32 selection positions, the same greedy argmax of
    ``lam*rel - (1-lam)*max_sim`` as :func:`mmr_host` with matching
    first-occurrence tie-breaking.
    """
    import jax
    import jax.numpy as jnp

    bsz, w, _ = emb.shape
    iota = jnp.arange(w)[None, :]
    rel = jnp.maximum(rel, _MMR_NEG)  # -inf -> sentinel: 0*rel stays finite
    valid = iota < pool_w[:, None]
    rel = jnp.where(valid, rel, _MMR_NEG)

    # precompute the pool gram matrix ONCE: the loop body then gathers a
    # row of S instead of running two (W, d) einsums per pick — one big
    # matmul replaces 2k tiny ones (>20x on the k=500 headline pool)
    S = jnp.einsum("bwd,bvd->bwv", emb, emb)

    def body(i, carry):
        max_sim, taken, out = carry
        penalty = jnp.where(max_sim <= _MMR_NEG * 0.5, 0.0, max_sim)
        mmr = lams[:, None] * rel - (1.0 - lams[:, None]) * penalty
        # mask invalid slots AFTER the blend: lam=0 zeroes the rel term,
        # so padded positions need an unconditional NEG, not just NEG rel
        mmr = jnp.where(jnp.logical_and(valid, ~taken), mmr, _MMR_NEG)
        j = jnp.argmax(mmr, axis=1)
        sim = jnp.take_along_axis(S, j[:, None, None], axis=1)[:, 0, :]
        max_sim = jnp.maximum(max_sim, sim)
        taken = jnp.logical_or(taken, iota == j[:, None])
        out = out.at[:, i].set(j.astype(jnp.int32))
        return max_sim, taken, out

    init = (jnp.full((bsz, w), _MMR_NEG, jnp.float32),
            jnp.zeros((bsz, w), bool),
            jnp.zeros((bsz, k), jnp.int32))
    _, _, out = jax.lax.fori_loop(0, k, body, init)
    return out


def _panel_inputs(plans, structure: "PlanStructure", use_mmr: bool):
    """Runtime panel inputs padded to ``structure.batch`` — a panel
    structure pow2-buckets the batch, so padded columns carry zero
    queries / inf half-life / lam 1.0 and slice away on the host."""
    q_pre, q_sup = M.fold_plans(plans)
    half = _half_lives(plans)
    lams = np.asarray(
        [float(p.diverse.lam) if (use_mmr and p.diverse is not None) else 1.0
         for p in plans], np.float32)
    bpad = structure.batch - len(plans)
    if bpad:
        q_pre = np.pad(q_pre, ((0, 0), (0, bpad)))
        q_sup = np.pad(q_sup, ((0, 0), (0, bpad)))
        half = np.pad(half, (0, bpad), constant_values=np.inf)
        lams = np.pad(lams, (0, bpad), constant_values=1.0)
    return q_pre, q_sup, half, lams


def _expand_bias(
    score_bias: np.ndarray, n_rows: int, batch: int, nplans: int
) -> np.ndarray:
    """Canonical (n_rows, batch) float32 additive-bias panel for the
    device callers: a shared (n,) bias broadcasts across plans, an (n, B)
    panel keeps its columns; row/batch padding is zero (no-op bias)."""
    b = np.asarray(score_bias, np.float32)
    if b.ndim == 1:
        b = np.repeat(b[:, None], nplans, axis=1)
    out = np.zeros((n_rows, batch), np.float32)
    out[:b.shape[0], :b.shape[1]] = b
    return out


def _pool_widths(widths, mask, n: int, batch: int) -> np.ndarray:
    """Per-plan TRUE pool widths (padded to ``batch``): each plan's
    selection width clamped to its eligible-row count, so static top-k
    padding and -inf masked slots can never enter a fused-MMR pool."""
    if mask is None:
        live = np.full(len(widths), n, dtype=np.int64)
    elif mask.ndim == 2:
        live = np.count_nonzero(mask, axis=0).astype(np.int64)
    else:
        live = np.full(len(widths), int(np.count_nonzero(mask)),
                       dtype=np.int64)
    pw = np.minimum(np.asarray(widths, np.int64), live)
    if batch > len(widths):
        pw = np.pad(pw, (0, batch - len(widths)))
    return pw.astype(np.int32)


# ---------------------------------------------------------------------------
# Plan structure + compiled-plan cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanStructure:
    """The trace-relevant *shape* of a scoring micro-batch.

    Two batches with the same structure lower to the same specialized
    graph: query texts, embedding values, and half-life magnitudes are
    runtime data, never trace constants.  Suppress count, top-k width AND
    the corpus row count are bucketed (padded up to powers of two) so the
    number of distinct traces stays bounded as requests — and segment
    sizes — vary.
    """

    batch: int            # B — number of plans folded into the panel
    n_rows: int           # DEVICE row count: corpus rows pow2-bucketed
    has_decay: bool       # decay factor branch present in the graph
    suppress_bucket: int  # max suppress count, padded to a power of two
    width: int            # static top-k width (pow2-bucketed, <= n_rows)
    mmr_k: int = 0        # in-graph MMR step count (pow2; 0 = no MMR tail)
    panel: bool = False   # (N, B) per-plan mask panel; batch pow2-bucketed
    bias: bool = False    # additive (N, B) score-bias panel (hybrid fusion)

    # NOTE on suppress_bucket: with the folded (q_pre, q_sup) formulation
    # only 0-vs-nonzero changes the lowered graph (the second matmul drops
    # out); the pow2 buckets keep the key future-proof for unfused panel
    # formulations where the direction count IS a shape.  NOTE on n_rows:
    # it is the pow2 ROW BUCKET — device backends zero-pad the corpus up
    # to it and mask the padding to -inf, so Phase-1 pre-filtered
    # sub-corpora and store segments of varying size share one compiled
    # executable per bucket instead of one per exact row count (the
    # per-segment PlanCache would otherwise grow with every append).

    # NOTE on mmr_k/panel: the diverse-on-device tail (a fori_loop of
    # mmr_k steps) and the 2-D mask panel change the lowered graph, so
    # both are structural.  mmr_k pow2-buckets the requested k and batch
    # pow2-buckets the panel width, so a stream of varying diverse ks /
    # cohort sizes compiles one graph per bucket — neither path retraces
    # per query.

    @classmethod
    def of(
        cls,
        plans: Sequence[M.ModulationPlan],
        widths: Sequence[int],
        n_rows: int,
        *,
        ks: Optional[Sequence[int]] = None,
        device_mmr: bool = False,
        panel: bool = False,
        bias: bool = False,
        cohort: bool = False,
    ) -> "PlanStructure":
        """``cohort=True`` pow2-buckets the BATCH axis even without a
        mask panel — the multi-query cohort path's trace bound: a stream
        of varying admitted-batch sizes (Q = 3, then 5, then 4 ...) pads
        into pow2 query-panel buckets and compiles one graph per bucket
        instead of one per Q (padded columns carry zero queries and are
        never sliced out into results)."""
        max_sup = max((len(p.suppress) for p in plans), default=0)
        w = max(widths, default=0)
        bucket = max(_pow2_bucket(n_rows), 1)
        width = min(max(_pow2_bucket(w), 1), bucket)
        mmr_k = 0
        if device_mmr and ks is not None and any(
                p.diverse is not None for p in plans):
            k_max = max((min(max(k, 0), n_rows) for k in ks), default=0)
            mmr_k = min(max(_pow2_bucket(k_max), 1), width)
        return cls(
            batch=(max(_pow2_bucket(len(plans)), 1) if (panel or cohort)
                   else len(plans)),
            n_rows=bucket,
            has_decay=any(p.decay is not None for p in plans),
            suppress_bucket=_pow2_bucket(max_sup),
            width=width,
            mmr_k=mmr_k,
            panel=panel,
            bias=bias,
        )


class PlanCache:
    """Compiled executables keyed on plan STRUCTURE, not plan content.

    Device backends lower one specialized graph per :class:`PlanStructure`;
    distinct query texts with the same shape hit the cache and never
    retrigger tracing, while a genuinely new shape (e.g. a new
    suppress-count bucket) builds — and traces — exactly once.

    ``jax_traces`` is incremented from INSIDE the traced python bodies, so
    it counts real (re)traces, not just cache misses; tests use it to pin
    the zero-retrace contract.

    The cache is bounded with LRU eviction at ``maxsize``: every hit
    refreshes the entry, so the hot segments' executables stay resident no
    matter how many one-off shapes (odd pre-filter buckets, a burst of
    small delta segments) stream past.  Counters surface through
    ``RetrievalService`` stats via :meth:`stats`.
    """

    def __init__(
        self,
        builder: Callable[[PlanStructure], Callable],
        maxsize: int = 64,
    ) -> None:
        self._builder = builder
        self._fns: "OrderedDict[PlanStructure, Callable]" = OrderedDict()
        self._lock = threading.Lock()
        self.maxsize = maxsize
        self.builds = 0      # cache misses (specialized graphs built)
        self.hits = 0        # cache hits (no build, no trace)
        self.evictions = 0   # LRU evictions (bounded executable retention)
        self.jax_traces = 0  # actual traces, counted from traced bodies

    def get(self, structure: PlanStructure) -> Callable:
        with self._lock:
            fn = self._fns.get(structure)
            if fn is not None:
                self._fns.move_to_end(structure)
                self.hits += 1
                return fn
            self.builds += 1
            fn = self._fns[structure] = self._builder(structure)
            while len(self._fns) > self.maxsize:
                self._fns.popitem(last=False)
                self.evictions += 1
            return fn

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._fns),
                "hits": self.hits,
                "builds": self.builds,
                "evictions": self.evictions,
                "jax_traces": self.jax_traces,
            }

    def __len__(self) -> int:
        return len(self._fns)


class _DeviceMatrixMixin:
    """Per-array device-resident corpus cache (bounded, LRU).

    A segmented store scores one matmul per segment, so the cache holds
    SEVERAL resident arrays at once — keyed on array identity + row
    padding — instead of a single slot: appending a 10k-chunk segment to
    a warm 240k corpus uploads ONLY the new segment while every sealed
    segment stays device-resident.  ``uploads`` counts host->device
    copies; tests pin the only-the-delta ingest contract on it.
    """

    _DEV_CACHE_SIZE = 32

    uploads = 0        # host->device copies performed
    dev_hits = 0       # calls served from the resident cache
    dev_evictions = 0  # LRU evictions

    def _device_matrix(self, matrix: np.ndarray, pad: int = 0):
        cache: "OrderedDict[Tuple[int, int], Tuple[np.ndarray, object]]"
        cache = self.__dict__.setdefault("_dev_cache", OrderedDict())
        key = (id(matrix), pad)
        entry = cache.get(key)
        # the stored source reference guards against id() reuse after gc
        if entry is not None and entry[0] is matrix:
            cache.move_to_end(key)
            self.dev_hits += 1
            return entry[1]
        import jax.numpy as jnp

        mat = np.asarray(matrix, np.float32)
        if pad:
            mat = np.pad(mat, ((0, pad), (0, 0)))
        dev = jnp.asarray(mat)
        cache[key] = (matrix, dev)
        cache.move_to_end(key)
        self.uploads += 1
        while len(cache) > self._DEV_CACHE_SIZE:
            cache.popitem(last=False)
            self.dev_evictions += 1
        return dev

    def _any_device_matrix(self, matrix: np.ndarray):
        """Any resident device copy of ``matrix``, regardless of its row
        padding (padded rows are zero and never indexed below the true row
        count), else a fresh unpadded upload.  The merged-pool MMR gather
        reuses whatever the scoring pass left resident instead of
        re-uploading the segment under a different pad key."""
        cache = self.__dict__.get("_dev_cache")
        if cache:
            for (mid, _pad), (src, dev) in cache.items():
                if mid == id(matrix) and src is matrix:
                    self.dev_hits += 1
                    return dev
        return self._device_matrix(matrix)

    def device_cache_stats(self) -> Dict[str, int]:
        return {
            "entries": len(self.__dict__.get("_dev_cache", ())),
            "uploads": self.uploads,
            "hits": self.dev_hits,
            "evictions": self.dev_evictions,
        }


class _DeviceMMRMixin:
    """Fused on-device MMR for diverse plans (the jax backends).

    Inside ``score_select`` the compiled graph chains
    :func:`_device_mmr_trace` (jit-jax/sharded) or the ``kernels/mmr``
    pallas kernel after top-k, so diverse plans return only the final k
    ``(indices, scores)`` — the oversample pool never crosses the device
    boundary.  For the merged per-segment pool,
    :meth:`mmr_pool_segments` gathers the pool embeddings ON DEVICE from
    the warm resident segment matrices and runs a cached jitted MMR loop
    (pow2-bucketed pool and k, so a stream of varying pool sizes compiles
    a bounded set of graphs).  Every path is pinned bit-identical to the
    :func:`mmr_host` oracle: same greedy argmax, same first-occurrence
    tie-breaking, and the returned scores are the RELEVANCE scores at the
    selected positions (exactly what the host finishing stage returns).
    """

    device_mmr = True
    _MMR_POOL_FNS = 16  # cached merged-pool executables (pow2 buckets)

    def _use_mmr(self, plans, fused_mmr: Optional[bool]) -> bool:
        if not (self.device_mmr if fused_mmr is None else bool(fused_mmr)):
            return False
        return any(p.diverse is not None for p in plans)

    def _pool_mmr_fn(self, pool_bucket: int, k_stat: int):
        import jax

        cache = self.__dict__.setdefault("_mmr_pool_cache", OrderedDict())
        key = (pool_bucket, k_stat)
        fn = cache.get(key)
        if fn is None:
            def pool_mmr(emb, rel, lams, pool_w):
                return _device_mmr_trace(emb, rel, lams, pool_w, k_stat)

            fn = cache[key] = jax.jit(pool_mmr)
            while len(cache) > self._MMR_POOL_FNS:
                cache.popitem(last=False)
        else:
            cache.move_to_end(key)
        return fn

    def _gather_pool_device(self, segments, gidx: np.ndarray):
        """Device-resident (pool, d) embeddings for merged global rows,
        gathered segment-by-segment from the warm resident matrices and
        un-permuted back to merged-pool order."""
        import jax.numpy as jnp

        from repro.core.segments import segment_offsets

        off = segment_offsets(segments)
        seg_idx = np.searchsorted(off, gidx, side="right") - 1
        local = gidx - off[seg_idx]
        order = np.argsort(seg_idx, kind="stable")
        parts = []
        for s in np.unique(seg_idx):
            rows = local[order[seg_idx[order] == s]]
            parts.append(jnp.take(
                self._any_device_matrix(segments[s].matrix),
                jnp.asarray(rows), axis=0))
        emb = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        return jnp.take(emb, jnp.asarray(np.argsort(order, kind="stable")),
                        axis=0)

    def mmr_pool_segments(self, segments, gidx, vals, k: int, lam: float):
        """Device MMR over a MERGED candidate pool (the union-merged
        global rows + scores from the per-segment two-stage shape).
        Returns selection positions into the pool, host int64 —
        bit-identical to ``mmr_host(gather_rows(segments, gidx), vals,
        k, lam)`` without the pool embeddings ever leaving the device.
        """
        pool = int(gidx.size)
        k = max(0, min(int(k), pool))
        if k == 0:
            return np.empty(0, np.int64)
        import jax.numpy as jnp

        emb = self._gather_pool_device(segments,
                                       np.asarray(gidx, np.int64))
        bucket = max(_pow2_bucket(pool), 1)
        k_stat = min(max(_pow2_bucket(k), 1), bucket)
        if bucket != pool:
            emb = jnp.pad(emb, ((0, bucket - pool), (0, 0)))
        rel = np.zeros(bucket, np.float32)
        rel[:pool] = vals
        fn = self._pool_mmr_fn(bucket, k_stat)
        sel = fn(emb[None], rel[None], np.asarray([lam], np.float32),
                 np.asarray([pool], np.int32))
        return np.asarray(sel)[0, :k].astype(np.int64)

    def mmr_pool_segments_batch(self, segments, pools, ks, lams):
        """One padded device call for a COHORT of merged diverse pools.

        ``pools`` is a list of per-plan ``(gidx, vals)`` merged unions,
        ``ks``/``lams`` the matching final counts and MMR lambdas.  Every
        pool pads to the cohort's shared pow2 bucket and the whole (B,
        bucket, d) stack runs through ONE cached ``_pool_mmr_fn``
        executable — one device sync for the batch instead of one per
        diverse plan.  Per-plan results are bit-identical to serial
        :meth:`mmr_pool_segments` calls: the MMR trace is batched over
        independent rows, and pow2 padding never changes a gram dot
        product (the contraction dim is untouched).  Returns per-plan
        selection-position arrays (empty for k == 0 pools).
        """
        import jax.numpy as jnp

        sizes = [int(g.size) for g, _ in pools]
        ks = [max(0, min(int(k), s)) for k, s in zip(ks, sizes)]
        live = [j for j, (s, k) in enumerate(zip(sizes, ks)) if s and k]
        out = [np.empty(0, np.int64)] * len(pools)
        if not live:
            return out
        bucket = max(_pow2_bucket(max(sizes[j] for j in live)), 1)
        k_stat = min(max(_pow2_bucket(max(ks[j] for j in live)), 1), bucket)
        embs, rel = [], np.zeros((len(live), bucket), np.float32)
        for row, j in enumerate(live):
            gidx, vals = pools[j]
            emb = self._gather_pool_device(segments,
                                           np.asarray(gidx, np.int64))
            if bucket != sizes[j]:
                emb = jnp.pad(emb, ((0, bucket - sizes[j]), (0, 0)))
            embs.append(emb)
            rel[row, :sizes[j]] = vals
        fn = self._pool_mmr_fn(bucket, k_stat)
        sel = np.asarray(fn(
            jnp.stack(embs), rel,
            np.asarray([lams[j] for j in live], np.float32),
            np.asarray([sizes[j] for j in live], np.int32)))
        for row, j in enumerate(live):
            out[j] = sel[row, :ks[j]].astype(np.int64)
        return out


# ---------------------------------------------------------------------------
# The backend contract
# ---------------------------------------------------------------------------


class ExecutionBackend:
    """One Phase-2 scoring implementation.

    Subclasses implement :meth:`score_panel`; :meth:`score` defaults to the
    single-column case.  :meth:`score_select` is the fused score->select
    stage — the base implementation is the host path (full panel + numpy
    top-k), which the numpy backends keep so everything stays anchored to
    the reference oracle; device backends override it to select on device
    and return only (pool,)-sized candidate arrays to the host.
    """

    name: str = "?"
    #: True when the backend finishes diverse plans with on-device MMR
    #: inside its fused chain — diverse plans then return the FINAL k, not
    #: the oversample pool (see :class:`_DeviceMMRMixin`)
    device_mmr: bool = False

    def score(
        self,
        matrix: np.ndarray,
        days_ago: Optional[np.ndarray],
        plan: M.ModulationPlan,
    ) -> np.ndarray:
        return self.score_panel(matrix, days_ago, [plan])[:, 0]

    def score_panel(
        self,
        matrix: np.ndarray,
        days_ago: Optional[np.ndarray],
        plans: Sequence[M.ModulationPlan],
    ) -> np.ndarray:
        raise NotImplementedError

    def score_select(
        self,
        matrix: np.ndarray,
        days_ago: Optional[np.ndarray],
        plans: Sequence[M.ModulationPlan],
        ks: Sequence[int],
        *,
        mask: Optional[np.ndarray] = None,
        fused_mmr: Optional[bool] = None,
        score_bias: Optional[np.ndarray] = None,
        cohort: bool = False,
    ) -> List[Candidates]:
        """Fused score->select: per-plan ``(indices, scores)`` of the top
        ``selection_width(plan, k, N)`` candidates, descending by score.

        ``cohort=True`` marks a multi-query cohort call (several admitted
        queries folded into one panel): device backends pow2-bucket the
        batch axis of their :class:`PlanStructure` key so a stream of
        varying cohort sizes compiles one graph per bucket instead of one
        per Q.  The host path has no compiled executables to bucket, so
        the flag is accepted (one signature everywhere) and ignored.

        ``score_bias`` is an optional additive score panel — (N,) shared
        by every plan or (N, B) per-plan — added to the modulated scores
        ON DEVICE before masking and selection (the hybrid lexical leg:
        sparse ``(1-w) * minmax(bm25)`` values, zero elsewhere).  Diverse
        plans run MMR over the BIASED relevance, so fusion happens before
        selection on every path.

        ``ks[j]`` is the final candidate count requested for plan ``j``;
        diverse plans return the oversampled MMR pool (the caller finishes
        with :func:`finalize_candidates`) — UNLESS the backend fuses MMR
        on device (``self.device_mmr``; see :class:`_DeviceMMRMixin`), in
        which case diverse plans come back as the final k, MMR-ordered,
        with relevance scores.  ``fused_mmr`` overrides per call: None
        defers to ``self.device_mmr``, False forces the host-pool
        contract (the equivalence suites and benches use it to compare
        both paths on one backend); the host-path backends ignore it.

        ``mask`` is an optional bool array, True = live — either (N,)
        shared by every plan, or an (N, B) panel giving each plan its OWN
        eligible rows (the heterogeneous-filter batch path).  Masked rows
        score -inf BEFORE selection (tombstoned segment rows never reach a
        candidate list with a real score — device backends apply the mask
        on device).  When fewer than ``w`` rows are eligible, the -inf
        entries trail the result; :func:`score_select_segments` filters
        them.
        """
        panel = self.score_panel(matrix, days_ago, plans)
        n = panel.shape[0]
        out: List[Candidates] = []
        for j, (plan, k) in enumerate(zip(plans, ks)):
            w = selection_width(plan, k, n)
            if w == 0:
                out.append(_empty_candidates())
                continue
            col = panel[:, j]
            if score_bias is not None:
                b = score_bias[:, j] if score_bias.ndim == 2 else score_bias
                col = col + b  # new array: the panel is never mutated
            if mask is not None:
                m = mask[:, j] if mask.ndim == 2 else mask
                col = np.where(m, col, -np.inf)
            idx = top_idx(col, w)
            out.append((idx, col[idx].astype(np.float32, copy=False)))
        return out

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ExecutionBackend {self.name}>"


class ReferenceNumpyBackend(ExecutionBackend):
    """Paper-faithful: one matvec per direction, exactly Table 1."""

    name = "reference-numpy"

    def score(self, matrix, days_ago, plan):
        return np.asarray(M.modulate_scores(matrix, days_ago, plan))

    def score_panel(self, matrix, days_ago, plans):
        cols = [self.score(matrix, days_ago, p) for p in plans]
        return np.stack(cols, axis=1)


class FusedNumpyBackend(ExecutionBackend):
    """Folded two-matvec formulation: the corpus matrix streams once.

    scores[:, j] = decay_j * (M @ q_pre[:, j]) + M @ q_sup[:, j]
    with per-request decay half-lives applied column-wise.
    """

    name = "fused-numpy"

    def score(self, matrix, days_ago, plan):
        return np.asarray(M.fused_modulate_scores(matrix, days_ago, plan))

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        q_pre, q_sup = M.fold_plans(plans)
        out = matrix @ q_pre                            # ONE pass (N, B)
        # decay touches only its own columns (strided but rare); the sup
        # add stays one contiguous vectorized op over the whole panel —
        # a per-column `out[:, j] = col + sup[:, j]` loop costs ~40% of
        # the matmuls again in strided traffic at panel widths
        for j, plan in enumerate(plans):
            if plan.decay is not None:
                out[:, j] *= _decay_column(days_ago, plan.decay.half_life_days)
        out += matrix @ q_sup
        return out


class JitJaxBackend(_DeviceMMRMixin, _DeviceMatrixMixin, ExecutionBackend):
    """The fused formulation jitted through XLA (CPU/GPU/TPU portable).

    Per-request decay folds into a (N, B) factor panel; half_life=inf makes
    the factor exactly 1.0 for no-decay columns, so one jitted graph serves
    every plan mix without recompiling on plan structure.

    :meth:`score_select` fuses ``jax.lax.top_k`` — and, for diverse plans,
    the :func:`_device_mmr_trace` MMR tail — into the jitted graph, so only
    the final (B, k) candidate block leaves the device: never the (N, B)
    score panel, never the MMR oversample pool.  Graphs specialize per
    :class:`PlanStructure` through the :class:`PlanCache` (no-decay plans
    skip the decay factor, suppress-free plans skip the second matmul,
    MMR-free batches skip the selection loop entirely).
    """

    name = "jit-jax"

    def __init__(self) -> None:
        self._fn = None
        self.plan_cache = PlanCache(self._build_select)

    def _build(self):
        import jax

        @jax.jit
        def fused(matrix, q_pre, q_sup, days, half_lives):
            decay = 1.0 / (1.0 + days[:, None] / half_lives[None, :])
            return decay * (matrix @ q_pre) + matrix @ q_sup

        return fused

    def _build_select(self, structure: PlanStructure):
        import jax
        import jax.numpy as jnp

        cache = self.plan_cache

        def fused_select(matrix, q_pre, q_sup, days, half_lives, mask,
                         lams, pool_w, bias):
            cache.jax_traces += 1  # python body runs only while tracing
            scores = matrix @ q_pre
            if structure.has_decay:
                scores = scores * (
                    1.0 / (1.0 + days[:, None] / half_lives[None, :])
                )
            if structure.suppress_bucket:
                scores = scores + matrix @ q_sup
            if structure.bias:
                # hybrid lexical leg: additive fusion before mask/top-k
                scores = scores + bias
            # one mask covers pow2 row padding AND segment tombstones; a
            # panel structure carries one mask column PER PLAN instead
            scores = jnp.where(mask if structure.panel else mask[:, None],
                               scores, -jnp.inf)
            v, i = jax.lax.top_k(scores.T, structure.width)  # (B, width)
            if structure.mmr_k:
                # fused diverse tail: MMR over the (B, width) pool without
                # leaving the graph (non-diverse columns ride along with
                # lam=1.0, which IS top-k order); positions past each
                # plan's true pool re-mask to -inf so downstream filters
                # treat them exactly like unselected top-k padding
                sel = _device_mmr_trace(matrix[i], v, lams, pool_w,
                                        structure.mmr_k)
                i = jnp.take_along_axis(i, sel, axis=1)
                v = jnp.take_along_axis(v, sel, axis=1)
                keep = jnp.arange(structure.mmr_k)[None, :] < pool_w[:, None]
                v = jnp.where(keep, v, -jnp.inf)
            return i, v

        return jax.jit(fused_select)

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        if self._fn is None:
            self._fn = self._build()
        q_pre, q_sup = M.fold_plans(plans)
        n = matrix.shape[0]
        return np.asarray(
            self._fn(self._device_matrix(matrix), q_pre, q_sup,
                     _days_f32(days_ago, n), _half_lives(plans))
        )

    def score_select(self, matrix, days_ago, plans, ks, *, mask=None,
                     fused_mmr=None, score_bias=None, cohort=False):
        for p in plans:
            _require_days(p, days_ago)
        n = matrix.shape[0]
        if n == 0:
            return [_empty_candidates() for _ in plans]
        widths = [selection_width(p, k, n) for p, k in zip(plans, ks)]
        use_mmr = self._use_mmr(plans, fused_mmr)
        panel2d = mask is not None and mask.ndim == 2
        structure = PlanStructure.of(plans, widths, n, ks=ks,
                                     device_mmr=use_mmr, panel=panel2d,
                                     bias=score_bias is not None,
                                     cohort=cohort)
        fn = self.plan_cache.get(structure)
        pad = structure.n_rows - n
        q_pre, q_sup, half_lives, lams = _panel_inputs(plans, structure,
                                                       use_mmr)
        days = np.pad(_days_f32(days_ago, n), (0, pad))
        if panel2d:
            live = np.zeros((structure.n_rows, structure.batch), dtype=bool)
            live[:n, :len(plans)] = mask
        else:
            live = np.zeros(structure.n_rows, dtype=bool)
            live[:n] = True if mask is None else mask
        pool_w = _pool_widths(widths, mask, n, structure.batch)
        # no-bias structures take a dummy (1, 1) input: the traced body
        # never touches it, so the arg shape stays stable per structure
        bias = (_expand_bias(score_bias, structure.n_rows, structure.batch,
                             len(plans))
                if structure.bias else np.zeros((1, 1), np.float32))
        idx, vals = fn(self._device_matrix(matrix, pad), q_pre, q_sup,
                       days, half_lives, live, lams, pool_w, bias)
        # with the fused MMR tail the device returns final-k blocks for
        # every plan (plain plans ride the lam=1.0 identity)
        out_w = ([min(max(k, 0), w) for k, w in zip(ks, widths)]
                 if use_mmr else widths)
        return _slice_candidates(idx, vals, out_w)


class PallasBackend(_DeviceMMRMixin, _DeviceMatrixMixin, ExecutionBackend):
    """The fused TPU kernels (``repro.kernels.pem_score`` + ``topk`` +
    ``mmr``).

    Off-TPU the kernels run in Pallas interpret mode (the same path the
    kernel tests validate).  The scoring kernel takes one decay column per
    call, so requests group by half-life and each group scores in one
    kernel launch; :meth:`score_select` keeps the score panel device-
    resident and feeds it straight into the streaming top-k kernel, then
    chains the ``kernels/mmr`` selection kernel for diverse plans — no
    host hop anywhere in the chain, only final candidates come back.
    """

    name = "pallas"

    def _grouped_panel(self, matrix, days_ago, plans):
        """Device-resident (N, B) score panel, columns in plan order."""
        import jax
        import jax.numpy as jnp

        from repro.kernels.pem_score.ops import pem_score

        q_pre, q_sup = M.fold_plans(plans)
        interpret = jax.default_backend() != "tpu"
        mat = self._device_matrix(matrix)

        groups: Dict[Optional[float], List[int]] = {}
        for j, plan in enumerate(plans):
            hl = plan.decay.half_life_days if plan.decay is not None else None
            groups.setdefault(hl, []).append(j)

        parts = []
        order: List[int] = []
        for hl, cols in groups.items():
            decay = None
            if hl is not None:
                decay = jnp.asarray(_decay_column(days_ago, hl), jnp.float32)
            parts.append(pem_score(
                mat,
                jnp.asarray(q_pre[:, cols]),
                jnp.asarray(q_sup[:, cols]),
                decay,
                interpret=interpret,
            ))
            order.extend(cols)
        panel = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        if order != list(range(len(plans))):
            panel = panel[:, np.argsort(np.asarray(order))]
        return panel, interpret

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        panel, _ = self._grouped_panel(matrix, days_ago, plans)
        return np.asarray(panel)

    def score_select(self, matrix, days_ago, plans, ks, *, mask=None,
                     fused_mmr=None, score_bias=None, cohort=False):
        # the kernels take exact shapes (no executable cache keyed on
        # batch), so the cohort flag has nothing to bucket here
        import jax.numpy as jnp

        from repro.kernels.topk.ops import topk

        for p in plans:
            _require_days(p, days_ago)
        n = matrix.shape[0]
        if n == 0:
            return [_empty_candidates() for _ in plans]
        widths = [selection_width(p, k, n) for p, k in zip(plans, ks)]
        # same pow2 width bucketing as the PlanCache key, one formula
        # (clamped to the real row count: the kernels take exact shapes,
        # there is no compiled-executable cache to bucket rows for)
        w_stat = min(PlanStructure.of(plans, widths, n).width, n)
        panel, interpret = self._grouped_panel(matrix, days_ago, plans)
        if score_bias is not None:
            # hybrid lexical leg: additive fusion on the device-resident
            # panel, before mask/top-k (matches the jitted fused graphs)
            b = jnp.asarray(np.asarray(score_bias, np.float32))
            panel = panel + (b if b.ndim == 2 else b[:, None])
        if mask is not None:
            # tombstones (or each plan's candidate-panel column) drop out
            # on device, before the top-k kernel
            m = jnp.asarray(mask)
            panel = jnp.where(m if m.ndim == 2 else m[:, None],
                              panel, -jnp.inf)
        v, i = topk(panel.T, w_stat, interpret=interpret)
        if not self._use_mmr(plans, fused_mmr):
            return _slice_candidates(i, v, widths)
        # fused diverse tail: the kernels/mmr pallas kernel selects over
        # each diverse plan's device-resident pool — only the final k
        # (with relevance scores) comes back, never the pool
        from repro.kernels.mmr.ops import mmr_select

        pool_w = _pool_widths(widths, mask, n, len(plans))
        mat = self._any_device_matrix(matrix)
        out = _slice_candidates(i, v, widths)
        for j, (p, k) in enumerate(zip(plans, ks)):
            if p.diverse is None:
                continue
            pw = int(pool_w[j])
            kf = min(max(k, 0), pw)
            if kf == 0:
                out[j] = _empty_candidates()
                continue
            pool_i = i[j, :pw]
            sel, _ = mmr_select(mat[pool_i][None], v[j, :pw][None], kf,
                                float(p.diverse.lam), interpret=interpret)
            out[j] = (np.asarray(jnp.take(pool_i, sel[0])).astype(np.int64),
                      np.asarray(jnp.take(v[j, :pw], sel[0])))
        return out

    def mmr_pool_segments(self, segments, gidx, vals, k, lam):
        """Merged-pool MMR through the ``kernels/mmr`` pallas kernel
        (pool pow2-bucketed with NEG-masked padding so the kernel compiles
        a bounded set of shapes)."""
        import jax
        import jax.numpy as jnp

        from repro.kernels.mmr.kernel import NEG
        from repro.kernels.mmr.ops import mmr_select

        pool = int(gidx.size)
        k = max(0, min(int(k), pool))
        if k == 0:
            return np.empty(0, np.int64)
        emb = self._gather_pool_device(segments, np.asarray(gidx, np.int64))
        bucket = max(_pow2_bucket(pool), 1)
        if bucket != pool:
            emb = jnp.pad(emb, ((0, bucket - pool), (0, 0)))
        rel = np.full(bucket, NEG, np.float32)
        rel[:pool] = vals
        sel, _ = mmr_select(emb[None], jnp.asarray(rel)[None], k,
                            float(lam),
                            interpret=jax.default_backend() != "tpu")
        return np.asarray(sel)[0].astype(np.int64)

    def mmr_pool_segments_batch(self, segments, pools, ks, lams):
        """The ``kernels/mmr`` pallas kernel takes a scalar lambda, so a
        heterogeneous-lambda cohort falls back to one kernel launch per
        plan (still zero host pool transfers)."""
        return [self.mmr_pool_segments(segments, g, v, k, lam)
                for (g, v), k, lam in zip(pools, ks, lams)]


class ShardedBackend(_DeviceMMRMixin, _DeviceMatrixMixin, ExecutionBackend):
    """shard_map row-sharded scoring over every locally visible device.

    The corpus rows split across a 1-D device mesh; each shard computes its
    slice of the fused score panel.  :meth:`score_panel` reassembles the
    panel on the host; :meth:`score_select` instead folds the
    ``repro.dist.pem_sharded`` two-stage selection into the graph — each
    shard takes a LOCAL top-k and only the (shards * k, B) candidate union
    crosses the interconnect before the merge, never the (N, B) panel.
    The fused MMR tail for diverse plans runs AFTER the shard_map, on the
    replicated merged union, inside the same jitted graph.
    """

    name = "sharded"

    def __init__(self) -> None:
        self._fn = None
        self._n_shards = None
        self.plan_cache = PlanCache(self._build_select)

    def _build(self):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("shards",))

        def local(matrix, q_pre, q_sup, days, half_lives):
            decay = 1.0 / (1.0 + days[:, None] / half_lives[None, :])
            return decay * (matrix @ q_pre) + matrix @ q_sup

        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(P("shards", None), P(None, None), P(None, None),
                      P("shards"), P(None)),
            out_specs=P("shards", None),
            check_rep=False,
        )
        return jax.jit(fn), n_dev

    def _build_select(self, structure: PlanStructure):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.dist.pem_sharded import (union_merge_topk,
                                            union_merge_topk_payload)

        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("shards",))
        cache = self.plan_cache

        def local(matrix, q_pre, q_sup, days, half_lives, mask, bias):
            cache.jax_traces += 1  # python body runs only while tracing
            n_local = matrix.shape[0]
            shard = jax.lax.axis_index("shards")
            scores = matrix @ q_pre
            if structure.has_decay:
                scores = scores * (
                    1.0 / (1.0 + days[:, None] / half_lives[None, :])
                )
            if structure.suppress_bucket:
                scores = scores + matrix @ q_sup
            if structure.bias:
                # hybrid lexical leg, sharded row-wise like the mask
                scores = scores + bias
            # one mask covers row-grid padding AND segment tombstones, so
            # neither can ever enter the union with a real score; a panel
            # structure shards one mask column PER PLAN instead
            scores = jnp.where(mask if structure.panel else mask[:, None],
                               scores, -jnp.inf)
            k_local = min(structure.width, n_local)
            v, i = jax.lax.top_k(scores.T, k_local)      # (B, k_local)
            gi = i + shard * n_local                      # global row ids
            if structure.mmr_k:
                # shard-local MMR prefix: each shard gathers its OWN
                # candidates' pool embeddings (an O(n_local) gather) and
                # the payload merge ships them with the union — the MMR
                # tail then never touches the replicated row space
                pe = matrix[i]                            # (B, k_l, d)
                return union_merge_topk_payload(v, gi, pe, ("shards",),
                                                structure.width)
            return union_merge_topk(v, gi, ("shards",), structure.width)

        out_specs = ((P(None, None), P(None, None), P(None, None, None))
                     if structure.mmr_k else (P(None, None), P(None, None)))
        inner = shard_map(
            local,
            mesh=mesh,
            in_specs=(P("shards", None), P(None, None), P(None, None),
                      P("shards"), P(None),
                      P("shards", None) if structure.panel else P("shards"),
                      P("shards", None) if structure.bias else P(None, None)),
            out_specs=out_specs,
            check_rep=False,
        )

        def fused_select(matrix, q_pre, q_sup, days, half_lives, mask,
                         lams, pool_w, bias):
            if structure.mmr_k:
                # fused diverse tail over the payload-merged pool: the
                # merged (B, width, d) embeddings arrived with the union
                # (shard-local gathers, O(shards*width*d) collective —
                # independent of corpus size), bit-identical to the old
                # replicated ``matrix[i]`` gather because the payload
                # rode the exact top-k permutation the indices did
                i, v, pe = inner(matrix, q_pre, q_sup, days, half_lives,
                                 mask, bias)
                sel = _device_mmr_trace(pe, v, lams, pool_w,
                                        structure.mmr_k)
                i = jnp.take_along_axis(i, sel, axis=1)
                v = jnp.take_along_axis(v, sel, axis=1)
                keep = jnp.arange(structure.mmr_k)[None, :] < pool_w[:, None]
                v = jnp.where(keep, v, -jnp.inf)
            else:
                i, v = inner(matrix, q_pre, q_sup, days, half_lives, mask,
                             bias)
            return i, v

        return jax.jit(fused_select)

    def score_panel(self, matrix, days_ago, plans):
        for p in plans:
            _require_days(p, days_ago)
        if self._fn is None:
            # other threads key on _fn: set _n_shards FIRST so no caller can
            # observe _fn non-None with _n_shards still unset
            fn, n_shards = self._build()
            self._n_shards = n_shards
            self._fn = fn
        q_pre, q_sup = M.fold_plans(plans)
        n = matrix.shape[0]
        days = _days_f32(days_ago, n)
        # pad the row grid to the shard count, slice the panel back
        pad = (-n) % self._n_shards
        mat = self._device_matrix(matrix, pad)
        if pad:
            days = np.pad(days, (0, pad))
        out = np.asarray(self._fn(mat, q_pre, q_sup, days, _half_lives(plans)))
        return out[:n]

    def score_select(self, matrix, days_ago, plans, ks, *, mask=None,
                     fused_mmr=None, score_bias=None, cohort=False):
        import jax

        for p in plans:
            _require_days(p, days_ago)
        n = matrix.shape[0]
        if n == 0:
            return [_empty_candidates() for _ in plans]
        n_shards = len(jax.devices())
        widths = [selection_width(p, k, n) for p, k in zip(plans, ks)]
        use_mmr = self._use_mmr(plans, fused_mmr)
        panel2d = mask is not None and mask.ndim == 2
        structure = PlanStructure.of(plans, widths, n, ks=ks,
                                     device_mmr=use_mmr, panel=panel2d,
                                     bias=score_bias is not None,
                                     cohort=cohort)
        fn = self.plan_cache.get(structure)
        # row grid: pow2 bucket (the PlanCache key), then up to a shard
        # multiple — derived from the bucket alone, so one trace per bucket
        padded = structure.n_rows + ((-structure.n_rows) % n_shards)
        pad = padded - n
        q_pre, q_sup, half_lives, lams = _panel_inputs(plans, structure,
                                                       use_mmr)
        days = np.pad(_days_f32(days_ago, n), (0, pad))
        if panel2d:
            live = np.zeros((padded, structure.batch), dtype=bool)
            live[:n, :len(plans)] = mask
        else:
            live = np.zeros(padded, dtype=bool)
            live[:n] = True if mask is None else mask
        pool_w = _pool_widths(widths, mask, n, structure.batch)
        mat = self._device_matrix(matrix, pad)
        # bias shards row-wise with the corpus grid; no-bias structures
        # take a replicated dummy the traced body never touches
        bias = (_expand_bias(score_bias, padded, structure.batch,
                             len(plans))
                if structure.bias else np.zeros((1, 1), np.float32))
        idx, vals = fn(mat, q_pre, q_sup, days, half_lives, live, lams,
                       pool_w, bias)
        out_w = ([min(max(k, 0), w) for k, w in zip(ks, widths)]
                 if use_mmr else widths)
        return _slice_candidates(idx, vals, out_w)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ExecutionBackend] = {}
_ALIASES = {
    # the seed's public engine strings keep working
    "reference": "reference-numpy",
    "fused": "fused-numpy",
    "jax": "jit-jax",
}


def register_backend(backend: ExecutionBackend) -> ExecutionBackend:
    _REGISTRY[backend.name] = backend
    return backend


register_backend(ReferenceNumpyBackend())
register_backend(FusedNumpyBackend())
register_backend(JitJaxBackend())
register_backend(PallasBackend())
register_backend(ShardedBackend())


def list_backends() -> List[str]:
    """Canonical names of every registered backend."""
    return sorted(_REGISTRY)


def get_backend(engine: Union[str, ExecutionBackend]) -> ExecutionBackend:
    """Resolve an engine name (or pass an ExecutionBackend through)."""
    if isinstance(engine, ExecutionBackend):
        return engine
    name = _ALIASES.get(engine, engine)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {engine!r}; known: {list_backends()} "
            f"(aliases: {sorted(_ALIASES)})"
        ) from None


# ---------------------------------------------------------------------------
# Shared selection (identical ranking on batched and direct paths)
# ---------------------------------------------------------------------------


def top_idx(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the top-k scores, sorted descending (argpartition+sort).

    Ties break toward the SMALLEST index — the same rule as
    ``jax.lax.top_k`` and the stable merges built on top of this, so the
    numpy and device backends agree bit-for-bit on tied scores and a
    cross-shard merge keyed on global row order reproduces the
    monolithic ranking exactly.  ``argpartition`` alone picks an
    arbitrary member set when ties straddle the k boundary, so the
    boundary value's members are re-resolved by index explicitly (two
    extra O(n) scans, negligible next to the scoring matmul).
    """
    if k >= scores.shape[0]:
        return np.argsort(-scores, kind="stable")
    part = np.argpartition(-scores, k)[:k]
    vstar = scores[part].min()  # the k-th largest value
    strictly = np.flatnonzero(scores > vstar)
    ties = np.flatnonzero(scores == vstar)
    members = np.concatenate([strictly, ties[: k - strictly.size]])
    return members[np.argsort(-scores[members], kind="stable")]


def selection_width(plan: M.ModulationPlan, k: int, n: int) -> int:
    """Candidates a backend must return for (plan, k) over n rows.

    Plain plans need exactly k; diverse plans need the MMR oversample pool
    ``oversample * max(k, plan.pool)`` so a small-k request (batched path)
    and a pool-sized request (direct path) draw from the same pool — MMR's
    greedy selection is prefix-consistent, so their rankings agree.
    """
    k = max(0, min(k, n))
    if k == 0:
        return 0
    if plan.diverse is not None:
        return min(plan.diverse.oversample * max(k, plan.pool), n)
    return k


def finalize_candidates(
    matrix: np.ndarray,
    idx: np.ndarray,
    scores: np.ndarray,
    k: int,
    plan: M.ModulationPlan,
) -> Candidates:
    """Host finishing stage over backend-returned candidates.

    Truncates a plain top-k pool to k, or runs MMR over the oversampled
    pool for diverse plans.  Produces exactly what
    :func:`select_candidates` yields on the full score array (same
    indices, same order), but only ever touches (pool,)-sized inputs.
    """
    k = max(0, min(k, idx.shape[0]))
    if k == 0:
        return idx[:0], scores[:0]
    if plan.diverse is not None:
        sel = mmr_host(matrix[idx], scores, k, plan.diverse.lam)
        return idx[sel], scores[sel]
    return idx[:k], scores[:k]


def score_select_segments(
    backend: Union[str, "ExecutionBackend"],
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
    ks: Sequence[int],
    *,
    now: Optional[float] = None,
    candidate_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    device_mmr: Optional[bool] = None,
    counters: Optional[FusedCounters] = None,
    score_bias: Optional[Sequence[Optional[np.ndarray]]] = None,
    cohort: bool = False,
) -> List[Candidates]:
    """Fused score->select over a SEGMENTED corpus (repro.core.segments).

    This is the DEVICE PASS of the segmented pipeline — the stage that
    touches device memory (per-segment scoring + on-device selection).
    Its host counterpart is :func:`finalize_segment_candidates` (gather +
    truncate/MMR + id resolution), which needs only the immutable segment
    snapshot — never the store lock or the device — so a serving core can
    overlap the host tail of batch *i* with the device pass of batch
    *i+1* (the async engine in :mod:`repro.serve.engine` does exactly
    that).

    Each segment scores independently through ``backend.score_select``
    (its tombstones masked to -inf on device before selection), then the
    per-segment top-k candidates merge on the host — the same two-stage
    union-merge shape ``dist/pem_sharded.union_merge_topk`` applies across
    device shards, applied across segments: every segment's local top-w
    provably contains its share of the global top-w, so the merge is
    exact.  Returns per-plan ``(global_rows, scores)`` where global rows
    offset into the concatenation of ALL segment rows (tombstoned rows
    included, so offsets are stable under deletes); resolve them with
    ``segments.gather_rows`` / ``segments.gather_ids``.

    Tie-breaking matches the monolithic path bit-for-bit: within a
    segment both ``top_idx`` and ``jax.lax.top_k`` prefer the smallest
    row, and the merge's stable sort keeps segment-major order, which IS
    global row order.

    ``ks[j]`` is the final candidate count for plan ``j``; diverse plans
    come back as the oversampled MMR pool (callers finish with
    :func:`finalize_candidates` over gathered candidate embeddings),
    exactly like the monolithic ``score_select`` — UNLESS the backend
    fuses MMR on device (``backend.device_mmr`` and ``device_mmr`` is not
    forced False), in which case EVERY diverse plan is device-finalized:
    the fast path fuses MMR into the scoring graph, and the per-segment
    path runs :meth:`_DeviceMMRMixin.mmr_pool_segments` over the merged
    pool (gathered from the warm resident segment matrices, never the
    host).  Callers can then finish with ``mmr_done=backend.device_mmr``.

    ``candidate_masks`` is the Phase-1 filtered-retrieval hook: per-segment
    bool masks (``SegmentedCorpusStore.candidate_masks``; None = segment
    holds no candidate, skipped entirely) — or per-segment (n, B) PANELS
    (``SegmentedCorpusStore.candidate_mask_panel``) giving each plan its
    own candidate column for heterogeneous-filter batches.  Each mask
    composes with the segment's tombstones — candidates ∧ live score,
    everything else hits -inf ON DEVICE before selection — so a
    pre-filtered query scores the same warm device-resident segment
    matrices as an unfiltered one: zero per-query gather, zero per-query
    upload, plan-cache row buckets unchanged.  Selection widths shrink to
    each plan's eligible-row count, and the union merge is bit-identical
    to host-gathering the candidate rows (in global-row order) and
    scoring them monolithically.

    ``score_bias`` is the hybrid-fusion hook: per-segment additive score
    arrays aligned with ``segments`` (None = zero bias; (n,) shared or
    (n, B) per-plan — ``SegmentedCorpusStore.score_bias_arrays`` /
    :func:`fusion_bias_arrays` build them), added on device before
    masking and selection.  A candidate-mask skip stays a skip: the
    Phase-1 filter is hard, bias only re-ranks eligible rows.
    """
    from repro.core.segments import segment_offsets

    backend = get_backend(backend)
    if candidate_masks is not None and len(candidate_masks) != len(segments):
        raise ValueError("candidate_masks misaligned with segments")
    if score_bias is not None and len(score_bias) != len(segments):
        raise ValueError("score_bias misaligned with segments")
    nplans = len(plans)
    # per-segment eligible mask: candidates ∧ live (None = every row);
    # per-PLAN eligible counts — a (n, B) panel gives every plan its own
    # column, so counts (and selection widths) differ per plan
    scored: List[Tuple[int, object, Optional[np.ndarray], np.ndarray]] = []
    elig = np.zeros(nplans, dtype=np.int64)
    for i, s in enumerate(segments):
        if not s.n_rows or not s.live_count:
            continue
        if candidate_masks is not None:
            cm = candidate_masks[i]
            if cm is None:
                continue
            if cm.ndim == 2:
                m = (cm & s.live_mask[:, None]) if s.n_dead else cm
                c = np.count_nonzero(m, axis=0).astype(np.int64)
                if not c.any():
                    continue
                if int(c.min()) == s.n_rows:
                    m = None  # every plan sees every row: unmasked shape
            else:
                m = (cm & s.live_mask) if s.n_dead else cm
                c1 = int(np.count_nonzero(m))
                if c1 == 0:
                    continue
                if c1 == s.n_rows:
                    m = None  # every row eligible: the unmasked fast shape
                c = np.full(nplans, c1, dtype=np.int64)
        else:
            m = s.live_mask if s.n_dead else None
            c = np.full(nplans, s.live_count, dtype=np.int64)
        scored.append((i, s, m, c))
        elig += c
    if not scored or not nplans:
        return [_empty_candidates() for _ in plans]
    if now is None:
        now = time.time()
    offsets = segment_offsets(segments)
    use_mmr = (backend.device_mmr and device_mmr is not False
               and any(p.diverse is not None for p in plans))

    # fast path: one segment with every row eligible IS the monolithic
    # corpus — same call, same candidates, zero segmentation overhead
    # (device-MMR backends finish diverse plans inside the fused graph)
    if len(scored) == 1 and scored[0][2] is None:
        i, seg, _, c = scored[0]
        n_el = int(c[0])
        out = backend.score_select(
            seg.matrix, seg.days_ago(now), plans,
            [min(k, n_el) for k in ks], fused_mmr=device_mmr,
            score_bias=None if score_bias is None else score_bias[i],
            cohort=cohort)
        if use_mmr and counters is not None:
            counters.device_mmr += sum(
                1 for p, k in zip(plans, ks)
                if p.diverse is not None and min(k, n_el) > 0)
        if offsets[i]:
            out = [(idx + offsets[i], vals) for idx, vals in out]
        return out

    # per-plan GLOBAL selection widths over each plan's ELIGIBLE rows
    # (diverse oversampling applies once, at corpus level; per-segment
    # requests are plain top-w)
    ks_eff = [min(k, int(e)) for k, e in zip(ks, elig)]
    widths = [selection_width(p, ke, int(e))
              for p, ke, e in zip(plans, ks_eff, elig)]
    seg_plans = [dataclasses.replace(p, diverse=None)
                 if p.diverse is not None else p for p in plans]

    parts: List[List[Candidates]] = []
    for i, seg, m, _ in scored:
        sel = backend.score_select(
            seg.matrix, seg.days_ago(now), seg_plans, widths, mask=m,
            score_bias=None if score_bias is None else score_bias[i],
            cohort=cohort)
        parts.append([(idx + offsets[i], vals) for idx, vals in sel])

    merged: List[Candidates] = []
    for j, w in enumerate(widths):
        if w == 0:
            merged.append(_empty_candidates())
            continue
        cat_i = np.concatenate([p[j][0] for p in parts])
        cat_v = np.concatenate([p[j][1] for p in parts])
        live = ~np.isneginf(cat_v)  # mask/padding leakage ends here
        cat_i, cat_v = cat_i[live], cat_v[live]
        order = np.argsort(-cat_v, kind="stable")[:w]
        merged.append((cat_i[order], cat_v[order]))

    if use_mmr:
        # merged-pool fused diverse tail: the union-merged pool equals
        # the monolithic oversample pool, so device MMR over it (pool
        # embeddings gathered from the warm resident segment matrices)
        # is exact — diverse plans leave here final-k, never as a pool.
        # The whole diverse cohort pads into ONE batched device call
        # (mmr_pool_segments_batch) instead of one sync per plan.
        div = [j for j, p in enumerate(plans)
               if p.diverse is not None and merged[j][0].size]
        if div:
            sels = backend.mmr_pool_segments_batch(
                segments, [merged[j] for j in div],
                [min(ks_eff[j], int(merged[j][0].size)) for j in div],
                [plans[j].diverse.lam for j in div])
            for j, sel in zip(div, sels):
                gidx, gv = merged[j]
                merged[j] = (gidx[sel], gv[sel])
            if counters is not None:
                counters.device_mmr += 1
    return merged


def score_select_cohort(
    backend: Union[str, "ExecutionBackend"],
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
    ks: Sequence[int],
    *,
    now: Optional[float] = None,
    candidate_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    device_mmr: Optional[bool] = None,
    counters: Optional[FusedCounters] = None,
    score_bias: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> List[Candidates]:
    """Cohort-panel score->select: one device pass for a MULTI-QUERY batch.

    ``plans`` here is a cohort — one plan per admitted query, folded into
    one fused ``(d, 2·Q)`` query panel so each segment matrix streams
    through device memory once per cohort instead of once per query.
    Execution is :func:`score_select_segments` with ``cohort=True``, which
    pow2-buckets the BATCH axis of the :class:`PlanStructure` cache key on
    device backends: a stream of varying cohort sizes (Q=3, Q=5, Q=7 …)
    compiles one executable per pow2 bucket, padded columns carry zero
    queries and are sliced away.  Rankings are bit-identical to Q serial
    single-plan calls on the same snapshot — cohort mode reorders loops,
    never arithmetic.  The cross-process analogue (one RPC, one corpus
    stream per shard per cohort) is ``dist.procgroup.ProcessGroup``'s
    ``search_plan_batch``.
    """
    return score_select_segments(
        backend, segments, plans, ks, now=now,
        candidate_masks=candidate_masks, device_mmr=device_mmr,
        counters=counters, score_bias=score_bias, cohort=True)


@dataclasses.dataclass
class PrefilterRouter:
    """Selectivity-aware router for Phase-1 filtered retrieval.

    Two ways to score a pre-filtered sub-corpus, with opposite cost
    shapes (Bruch, *Foundations of Vector Retrieval* §filtered search):

    * **masked-device** — score the warm device-resident segment matrices
      with non-candidates masked to -inf before selection.  Cost is
      O(corpus) but every byte is already on device: zero gather, zero
      upload, plan-cache hits preserved.  Wins when the filter is weak
      (candidates are a large fraction of the corpus).
    * **gather-host** — resolve the candidate rows through the id index
      (O(candidates)), gather them into a scratch matrix and score that.
      Pays a host gather + device upload + (first time) a trace per row
      bucket EVERY query, but touches only candidate rows.  Wins when the
      filter is sharp (a few hundred rows out of a million).

    The router picks per query on REQUESTED selectivity — unique
    candidate count over live rows — against the crossover threshold.
    ``mask_threshold`` seeds it statically (the measured crossover lives
    in ``BENCH_pem.json``'s ``prefilter_backends`` scenario); with
    ``adaptive`` on, the router then LEARNS the crossover from its own
    recorded timing samples: masked cost is bandwidth-bound in live rows
    (≈ ``a·n_live``), gather cost is linear in candidates
    (≈ ``b·n_candidates``), so masked wins once ``a·n_live ≤
    b·n_candidates`` — i.e. at selectivity ≥ ``a/b``.  Until BOTH arms
    have ``min_samples`` recorded passes the static seed stays in force,
    and the learned value is clamped to [0.01, 0.9] so one degenerate
    timing sample can't pin the router to a single arm.  Counters are
    benign int/float bumps (same convention as the store's) surfaced
    through ``RetrievalService.stats()["prefilter"]``.
    """

    mask_threshold: float = 0.2  # static seed: selectivity where masked wins
    adaptive: bool = True        # learn the crossover from timing samples
    min_samples: int = 5         # per-arm passes before the learned value arms
    routed_masked: int = 0       # queries served by the masked-device path
    routed_gather: int = 0       # queries served by the gather-host path
    routed_panel: int = 0        # queries served by a batched (N, B) panel
    mask_build_ms: float = 0.0   # cumulative candidate-mask build time
    masked_ms: float = 0.0       # cumulative masked-arm scoring time
    masked_rows: int = 0         # cumulative live rows swept by masked passes
    masked_samples: int = 0
    gather_ms: float = 0.0       # cumulative gather-arm scoring time
    gather_rows: int = 0         # cumulative candidate rows gathered+scored
    gather_samples: int = 0
    # routed_* count QUERIES: a batched scoring call serving n folded
    # identical filters bumps by n (score_select_prefiltered's weight=),
    # and a panel pass serving a B-request cohort bumps routed_panel by B

    def record_masked(self, ms: float, n_live: int) -> None:
        if ms >= 0.0 and n_live > 0:
            self.masked_ms += ms
            self.masked_rows += n_live
            self.masked_samples += 1

    def record_gather(self, ms: float, n_candidates: int) -> None:
        if ms >= 0.0 and n_candidates > 0:
            self.gather_ms += ms
            self.gather_rows += n_candidates
            self.gather_samples += 1

    def effective_threshold(self) -> float:
        if (not self.adaptive
                or self.masked_samples < self.min_samples
                or self.gather_samples < self.min_samples
                or not self.masked_rows or not self.gather_rows
                or self.gather_ms <= 0.0):
            return self.mask_threshold
        a = self.masked_ms / self.masked_rows    # ms per live row swept
        b = self.gather_ms / self.gather_rows    # ms per candidate gathered
        return min(max(a / b, 0.01), 0.9)

    def use_masked(self, n_candidates: int, n_live: int) -> bool:
        return (n_live > 0
                and n_candidates >= self.effective_threshold() * n_live)

    def use_panel(
        self,
        candidate_counts: Sequence[Optional[int]],
        n_live: int,
    ) -> bool:
        """The batched-panel arm: serve a heterogeneous-filter cohort with
        ONE (N, B) mask-panel pass when at least two of its distinct
        filter groups would each cost a full-corpus device pass anyway —
        an unfiltered group (``None``) or a filter the masked arm would
        take.  One batched matmul then replaces those passes outright.
        Below that, per-group dispatch stays (sharp filters keep the
        cheap O(candidates) gather path)."""
        if len(candidate_counts) < 2:
            return False
        full = sum(1 for c in candidate_counts
                   if c is None or self.use_masked(int(c), n_live))
        return full >= 2

    def stats(self) -> Dict[str, Union[int, float]]:
        return {
            "threshold": self.mask_threshold,
            "threshold_effective": round(self.effective_threshold(), 4),
            "routed_masked": self.routed_masked,
            "routed_gather": self.routed_gather,
            "routed_panel": self.routed_panel,
            "mask_build_ms": round(self.mask_build_ms, 3),
            "masked_samples": self.masked_samples,
            "gather_samples": self.gather_samples,
        }


def score_select_prefiltered(
    backend: Union[str, "ExecutionBackend"],
    store,
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
    ks: Sequence[int],
    candidate_ids: Sequence[int],
    *,
    now: Optional[float] = None,
    router: Optional[PrefilterRouter] = None,
    weight: int = 1,
    device_mmr: Optional[bool] = None,
    counters: Optional[FusedCounters] = None,
    score_bias: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> List[Candidates]:
    """Device pass for a Phase-1 FILTERED micro-batch (one candidate set
    shared by every plan in the call).  ``weight`` is how many QUERIES
    this call serves (the batched engine folds identical filters into one
    call), so the router's counters stay per-query on every path.

    Routes through ``router`` (masked-device vs gather-host, see
    :class:`PrefilterRouter`) and returns per-plan ``(global_rows,
    scores)`` — the same contract as :func:`score_select_segments`, so
    :func:`finalize_segment_candidates` finishes both filtered and
    unfiltered batches identically.  Callers needing a consistent pass
    hold ``store.lock`` across snapshot + this call, exactly like the
    unfiltered driver.

    Non-strict on both routes: candidate ids deleted between the Phase-1
    SQL and this pass (or never known) are silently dropped —
    ``candidate_masks`` never sets their bit, ``locate_rows`` skips them.
    Duplicates collapse (``np.unique``), and ties break by global row on
    both routes, so the two are bit-identical.
    """
    from repro.core.segments import gather_days, gather_rows

    backend = get_backend(backend)
    # avoid python-int boxing for array inputs (the engine already hands
    # over the canonical unique-sorted array from Request admission; the
    # sortedness check below then skips the redundant O(c log c) sort)
    cand = (candidate_ids if isinstance(candidate_ids, np.ndarray)
            else np.asarray(list(candidate_ids), dtype=np.int64))
    cand = cand.astype(np.int64, copy=False).ravel()
    if cand.size > 1 and not np.all(cand[1:] > cand[:-1]):
        cand = np.unique(cand)
    n_live = sum(s.live_count for s in segments)
    if cand.size == 0 or n_live == 0:
        return [_empty_candidates() for _ in plans]
    if router is None:
        router = PrefilterRouter()
    if now is None:
        now = time.time()

    if router.use_masked(int(cand.size), n_live):
        t0 = time.perf_counter()
        masks, matched = store.candidate_masks(cand, segments)
        router.mask_build_ms += (time.perf_counter() - t0) * 1e3
        router.routed_masked += weight
        if matched == 0:
            return [_empty_candidates() for _ in plans]
        t0 = time.perf_counter()
        out = score_select_segments(
            backend, segments, plans, ks, now=now, candidate_masks=masks,
            device_mmr=device_mmr, counters=counters,
            score_bias=score_bias)
        # adaptive crossover: the masked arm's cost scales with the live
        # rows it sweeps, regardless of how few candidates survive
        router.record_masked((time.perf_counter() - t0) * 1e3, n_live)
        return out

    router.routed_gather += weight
    rows = store.locate_rows(cand, segments)
    if rows.size == 0:
        return [_empty_candidates() for _ in plans]
    t0 = time.perf_counter()
    sub = gather_rows(segments, rows)
    days = gather_days(segments, rows, now)
    ks_eff = [min(k, int(rows.size)) for k in ks]
    sub_bias = (None if score_bias is None
                else _gather_bias(score_bias, segments, rows))
    sel = backend.score_select(sub, days, plans, ks_eff,
                               fused_mmr=device_mmr, score_bias=sub_bias)
    # the gather arm pays resolve+gather+upload+score per candidate row
    router.record_gather((time.perf_counter() - t0) * 1e3, int(rows.size))
    if (counters is not None and backend.device_mmr
            and device_mmr is not False):
        counters.device_mmr += sum(
            1 for p, k in zip(plans, ks_eff)
            if p.diverse is not None and k > 0)
    return [(rows[idx], vals) for idx, vals in sel]


def score_select_filter_panel(
    backend: Union[str, "ExecutionBackend"],
    store,
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
    ks: Sequence[int],
    candidate_sets: Sequence[Optional[Sequence[int]]],
    *,
    now: Optional[float] = None,
    router: Optional[PrefilterRouter] = None,
    counters: Optional[FusedCounters] = None,
    device_mmr: Optional[bool] = None,
    score_bias: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> List[Candidates]:
    """Device pass for a HETEROGENEOUS-filter micro-batch: one plan per
    request, each with its OWN Phase-1 candidate set (None = unfiltered).

    Instead of one scoring pass per distinct filter, builds a per-plan
    (N, B) candidate-mask panel (``SegmentedCorpusStore.
    candidate_mask_panel`` — an unfiltered request rides along as the
    all-live column, so a mixed cohort never splits) and runs ONE batched
    :func:`score_select_segments` pass over the warm segment matrices:
    one matmul + masked selection for the whole cohort.  Returns the same
    per-plan ``(global_rows, scores)`` contract as every other driver,
    and each plan's ranking is bit-identical to dispatching its filter
    through :func:`score_select_prefiltered` on its own.  The batched
    engine consults :meth:`PrefilterRouter.use_panel` first —
    sharp-filter-only cohorts stay on per-group gather dispatch.
    """
    backend = get_backend(backend)
    if now is None:
        now = time.time()
    t0 = time.perf_counter()
    panels, matched = store.candidate_mask_panel(candidate_sets, segments)
    if router is not None:
        router.mask_build_ms += (time.perf_counter() - t0) * 1e3
        router.routed_panel += len(plans)
    if counters is not None:
        counters.panel_batches += 1
    if all(p is None for p in panels):
        return [_empty_candidates() for _ in plans]
    return score_select_segments(
        backend, segments, plans, ks, now=now, candidate_masks=panels,
        device_mmr=device_mmr, counters=counters, score_bias=score_bias)


def _gather_bias(
    bias_arrays: Sequence[Optional[np.ndarray]],
    segments: Sequence,
    rows: np.ndarray,
) -> np.ndarray:
    """Per-segment bias arrays -> bias values at GLOBAL rows (the gather
    route's counterpart of ``gather_rows``: the sub-matrix is scored with
    the matching sub-bias)."""
    from repro.core.segments import segment_offsets

    off = segment_offsets(segments)
    seg_idx = np.searchsorted(off, rows, side="right") - 1
    local = rows - off[seg_idx]
    width = next((a.shape[1] for a in bias_arrays
                  if a is not None and a.ndim == 2), None)
    out = (np.zeros(rows.size, np.float32) if width is None
           else np.zeros((rows.size, width), np.float32))
    for s in np.unique(seg_idx):
        arr = bias_arrays[s]
        if arr is None:
            continue
        take = seg_idx == s
        vals = arr[local[take]]
        if width is not None and vals.ndim == 1:
            vals = np.repeat(vals[:, None], width, axis=1)
        out[take] = vals
    return out


def plan_fusion_bias(
    plan: M.ModulationPlan,
) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """One plan's sparse lexical score contribution: ``(chunk_ids,
    (1-w) * minmax(bm25))`` — or None when nothing rides on device
    (no fusion, RRF mode, empty lexical hits, or w == 1.0: the guard
    that keeps ``fuse:weighted,1.0`` bit-identical to the unfused path).
    ``fuse:filter,W`` plans with W < 1 fuse the same way — the hit set
    is already the Phase-1 candidate set, the bias just re-ranks within
    it.
    """
    f = plan.fusion
    if (f is None or f.mode not in ("weighted", "filter")
            or plan.lexical is None
            or plan.lexical.ids.size == 0 or f.weight == 1.0):
        return None
    vals = ((1.0 - f.weight)
            * np.asarray(plan.lexical.scores, np.float32))
    return plan.lexical.ids, vals.astype(np.float32, copy=False)


def fusion_bias_arrays(
    store,
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
) -> Optional[List[Optional[np.ndarray]]]:
    """Per-segment additive score arrays for a micro-batch's lexical legs
    — the ``score_bias`` input of every segmented driver.  None when no
    plan contributes a device-fused bias; otherwise one entry per
    segment: (n,) for a single-plan call, (n, B) zero-filled panels when
    several plans fuse different keyword queries in one batch.
    """
    per_plan = [plan_fusion_bias(p) for p in plans]
    if all(b is None for b in per_plan):
        return None
    if len(plans) == 1:
        ids, vals = per_plan[0]
        arrays, _ = store.score_bias_arrays(ids, vals, segments)
        return arrays
    out: List[Optional[np.ndarray]] = [None] * len(segments)
    for j, b in enumerate(per_plan):
        if b is None:
            continue
        cols, _ = store.score_bias_arrays(b[0], b[1], segments)
        for i, col in enumerate(cols):
            if col is None:
                continue
            if out[i] is None:
                out[i] = np.zeros((segments[i].n_rows, len(plans)),
                                  np.float32)
            out[i][:, j] = col
    return out


def finalize_fusion(
    plan: M.ModulationPlan,
    results: List[Tuple[int, float]],
    k: int,
    *,
    store=None,
    candidate_ids: Optional[Sequence[int]] = None,
) -> List[Tuple[int, float]]:
    """Host finishing stage for RANK fusion (``fuse:rrf,K``) — a no-op
    for every other plan.  RRF is not linear in scores, so it cannot ride
    the device bias: the device pass runs pure-vector, and this fuses its
    ranked list with the lexical list via ``modulations.rrf_fuse``.

    The lexical ids are clipped to the Phase-1 candidate set (the filter
    stays hard under fusion) and to live store membership (ids deleted
    since the FTS query — or FTS rows the vector store never held — are
    dropped, matching the non-strict prefilter contract).
    """
    f = plan.fusion
    if f is None or f.mode != "rrf" or plan.lexical is None:
        return results
    lex = np.asarray(plan.lexical.ids, np.int64)
    if candidate_ids is not None:
        cand = (candidate_ids if isinstance(candidate_ids, np.ndarray)
                else np.asarray(list(candidate_ids), dtype=np.int64))
        lex = lex[np.isin(lex, cand)]
    if store is not None:
        lex = np.asarray([i for i in lex if int(i) in store], np.int64)
    fused = M.rrf_fuse([i for i, _ in results], [int(i) for i in lex],
                       f.rrf_k)
    return [(int(i), float(s)) for i, s in fused[:max(0, k)]]


def finalize_segment_candidates(
    segments: Sequence,
    plans: Sequence[M.ModulationPlan],
    ks: Sequence[int],
    selected: Sequence[Candidates],
    *,
    mmr_done: bool = False,
    counters: Optional[FusedCounters] = None,
) -> List[List[Tuple[int, float]]]:
    """HOST TAIL of the segmented pipeline — the separable counterpart of
    :func:`score_select_segments` (the device pass).

    Takes the per-plan ``(global_rows, scores)`` candidates the device
    pass produced and finishes them on the host: truncate plain top-k,
    or — for diverse plans — gather the (pool,)-sized candidate
    embeddings and run the :func:`mmr_host` oracle over the oversampled
    pool, then resolve global rows to chunk ids.  Returns per-plan
    ``[(chunk_id, score), ...]`` descending — the shape every serving
    surface hands back.

    ``mmr_done=True`` declares that the device pass already finished
    diversity on device (``backend.device_mmr`` paths): diverse plans
    then truncate exactly like plain ones, and NO pool embedding gather
    happens at all — the pool never crossed the device boundary, and
    ``counters.host_pool_transfers`` stays untouched.

    Reads ONLY the immutable segment arrays of the snapshot it is given
    (sealed ids/matrix never change; compaction swaps the store's list
    but old segments stay valid), so it is safe to run WITHOUT the store
    lock, concurrently with the next batch's device pass — that overlap
    is the async engine's pipeline win.  Every consumer (direct
    ``VectorCache.search_plan``, the batched engine) calls this one
    function, so batched and direct rankings stay bit-identical.
    """
    from repro.core.segments import gather_ids, gather_rows

    out: List[List[Tuple[int, float]]] = []
    for plan, k, (gidx, vals) in zip(plans, ks, selected):
        if gidx.size == 0:
            out.append([])
            continue
        if plan.diverse is not None and not mmr_done:
            # host-oracle finishing: gather the oversample pool and run
            # mmr_host — the transfer the fused device paths avoid
            pool_emb = gather_rows(segments, gidx)
            loc, final_vals = finalize_candidates(
                pool_emb, np.arange(gidx.size, dtype=np.int64), vals, k,
                plan)
            if counters is not None:
                counters.host_pool_transfers += 1
            chunk_ids = gather_ids(segments, gidx[loc])
        else:
            # plain top-k — or a diverse plan the device already
            # finished — truncates; no pool embedding gather at all
            kf = max(0, min(k, int(gidx.size)))
            chunk_ids = gather_ids(segments, gidx[:kf])
            final_vals = vals[:kf]
        out.append([(int(i), float(v))
                    for i, v in zip(chunk_ids, final_vals)])
    return out


def select_candidates(
    matrix: np.ndarray,
    scores: np.ndarray,
    k: int,
    plan: M.ModulationPlan,
) -> np.ndarray:
    """Top-k (or MMR-diverse) row selection over a FULL host score array.

    The host-path reference for :meth:`ExecutionBackend.score_select` +
    :func:`finalize_candidates`; kept as the oracle the fused paths are
    pinned against.
    """
    n = scores.shape[0]
    k = min(k, n)
    if k <= 0:
        return np.empty(0, dtype=np.int64)
    if plan.diverse is not None:
        over = selection_width(plan, k, n)
        pool_idx = top_idx(scores, over)
        sel = mmr_host(matrix[pool_idx], scores[pool_idx], k,
                       plan.diverse.lam)
        return pool_idx[sel]
    return top_idx(scores, k)
