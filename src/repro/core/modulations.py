"""Programmatic Embedding Modulation (PEM) — the paper's core operator set.

Each modulation is a pure function over (embedding matrix ``M``, score array
``s``, query vector ``q``).  The formulas are paper Table 1, verbatim:

    suppress:X    s -= w * (M @ embed(X))                       (w = 0.5)
    decay:N       s *= 1 / (1 + days / N)
    centroid:ids  q = a*q + (1-a)*mean(E[ids]); q /= ||q||       (a = 0.5)
    from:A to:B   s  = 0.5*s + 0.5*(M @ (embed(B) - embed(A)))
    diverse       MMR: score = lam*rel - (1-lam)*max_sim         (lam = 0.7)

Modulations execute in a FIXED order regardless of token order (paper §3.3):

    centroid -> base similarity -> trajectory -> decay -> suppress -> diverse

The functions below are written against the array-API subset shared by numpy
and jax.numpy, so the same code path is the oracle for (a) the paper-faithful
host/numpy engine, (b) the jit'd JAX engine, and (c) the Pallas kernel tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

Array = Any  # np.ndarray | jax.Array

# Paper defaults (§4.4): suppress w=0.5, centroid alpha=0.5, trajectory blend
# 0.5/0.5, decay half-life 30 days, diverse lambda=0.7 with 3x oversample,
# candidate pool K=500.
DEFAULT_SUPPRESS_WEIGHT = 0.5
DEFAULT_CENTROID_ALPHA = 0.5
DEFAULT_TRAJECTORY_BLEND = 0.5
DEFAULT_DECAY_HALF_LIFE = 30.0
DEFAULT_MMR_LAMBDA = 0.7
DEFAULT_MMR_OVERSAMPLE = 3
DEFAULT_POOL = 500
DEFAULT_FUSE_WEIGHT = 0.5
DEFAULT_RRF_K = 60


def l2_normalize(v: Array, eps: float = 1e-12) -> Array:
    """L2-normalize along the last axis. Works for numpy and jax arrays."""
    nrm = (v * v).sum(axis=-1, keepdims=True) ** 0.5
    return v / (nrm + eps)


# ---------------------------------------------------------------------------
# Specs — a declarative plan the grammar parser emits and every backend
# (numpy host engine, jit JAX engine, fused Pallas kernel) consumes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SuppressSpec:
    """`suppress:X` — subtract directional similarity toward a concept."""

    direction: Array  # (d,) L2-normalized embed(X)
    weight: float = DEFAULT_SUPPRESS_WEIGHT


@dataclasses.dataclass(frozen=True)
class DecaySpec:
    """`decay:N` — reciprocal temporal decay with an N-day half-life."""

    half_life_days: float = DEFAULT_DECAY_HALF_LIFE


@dataclasses.dataclass(frozen=True)
class CentroidSpec:
    """`centroid:ids` — shift the query toward the mean of example embeds."""

    examples: Array  # (m, d) embeddings of the example chunks
    alpha: float = DEFAULT_CENTROID_ALPHA


@dataclasses.dataclass(frozen=True)
class TrajectorySpec:
    """`from:A to:B` — blend directional similarity along embed(B)-embed(A)."""

    direction: Array  # (d,) = embed(B) - embed(A), NOT renormalized (paper)
    blend: float = DEFAULT_TRAJECTORY_BLEND


@dataclasses.dataclass(frozen=True)
class DiverseSpec:
    """`diverse` — MMR iterative selection from an oversampled pool."""

    lam: float = DEFAULT_MMR_LAMBDA
    oversample: int = DEFAULT_MMR_OVERSAMPLE


@dataclasses.dataclass(frozen=True)
class FusionSpec:
    """`fuse:MODE[,param]` — how lexical (BM25) and vector scores combine.

    ``weighted``: final = weight * modulated + (1-weight) * minmax(bm25),
    fused ON DEVICE as an additive score bias (the weight folds into the
    query panel by linearity, the lexical part rides as ``score_bias``).
    ``rrf``: reciprocal-rank fusion 1/(k+rank) over the two ranked lists,
    finished on host after selection (rank fusion is not linear in scores).
    ``filter``: the lexical hit set becomes a HARD Phase-1 candidate set
    (sharp-keyword hybrid: only FTS hits are eligible, so the
    selectivity-aware ``PrefilterRouter`` crossover applies to the
    lexical leg); ranking within the hits is pure-vector at the default
    ``weight=1.0``, or weighted fusion when ``fuse:filter,W`` gives
    ``W < 1``.
    """

    mode: str = "weighted"  # "weighted" | "rrf" | "filter"
    weight: float = DEFAULT_FUSE_WEIGHT  # vector-side weight, weighted mode
    rrf_k: int = DEFAULT_RRF_K


@dataclasses.dataclass(frozen=True)
class LexicalHits:
    """Resolved `keyword:` clause: sparse BM25 hits, min-max normalized.

    ``ids`` are chunk ids in descending lexical relevance; ``scores`` are
    the matching normalized scores in [0, 1].  Resolved at plan-build time
    (like centroid ids) so the plan stays executable without a connection.
    """

    ids: np.ndarray     # (m,) int64
    scores: np.ndarray  # (m,) float32, min-max normalized, descending


@dataclasses.dataclass(frozen=True)
class ModulationPlan:
    """Everything Phase 2 needs, in executable form.

    ``query`` is the raw `similar:` embedding; centroid shifting happens at
    execution time so the plan remains a faithful record of the request.
    ``cluster``/``central`` are the §3.2 STRUCTURAL operators: they compute
    over the selected candidates and surface as extra temp-table columns.
    """

    query: Array  # (d,) L2-normalized
    centroid: Optional[CentroidSpec] = None
    trajectory: Optional[TrajectorySpec] = None
    decay: Optional[DecaySpec] = None
    suppress: Tuple[SuppressSpec, ...] = ()
    diverse: Optional[DiverseSpec] = None
    pool: int = DEFAULT_POOL
    cluster: Optional[int] = None   # cluster:K -> k-means label column
    central: bool = False           # central -> similarity-centrality column
    keyword: Optional[str] = None   # keyword:TEXT -> lexical leg of fusion
    fusion: Optional[FusionSpec] = None
    lexical: Optional[LexicalHits] = None  # resolved keyword: hits

    @property
    def n_directions(self) -> int:
        """Query-side directions the fused kernel must score (incl. base)."""
        return 1 + (1 if self.trajectory is not None else 0) + len(self.suppress)


def fusion_scale(plan: ModulationPlan) -> float:
    """Vector-side multiplier for weighted fusion (1.0 = no scaling).

    Folding the weight into the query panel keeps the fused pipeline a
    single GEMM: w*(decay*(M@q_pre) + M@q_sup) == decay*(M@(w*q_pre)) +
    M@(w*q_sup) by linearity.  RRF never scales (rank-based).
    """
    if plan.fusion is not None and plan.fusion.mode in ("weighted", "filter"):
        return float(plan.fusion.weight)
    return 1.0


def filter_candidate_ids(
    plan: "ModulationPlan",
    candidate_ids=None,
):
    """Phase-1 candidate set for a ``fuse:filter`` plan.

    Returns the lexical hit ids (intersected with an existing Phase-1
    candidate set when both filters apply — the SQL pre-filter stays
    hard under the lexical one), or ``candidate_ids`` unchanged for
    every other plan.  An empty intersection returns an empty array, not
    None: a filter that matched nothing must yield no results, not the
    full corpus.
    """
    f = plan.fusion
    if f is None or f.mode != "filter" or plan.lexical is None:
        return candidate_ids
    lex = np.asarray(plan.lexical.ids, np.int64)
    if candidate_ids is None:
        return lex
    cand = (candidate_ids if isinstance(candidate_ids, np.ndarray)
            else np.asarray(list(candidate_ids), dtype=np.int64))
    return lex[np.isin(lex, cand)]


def combine_lexical_pools(
    pools: Sequence[Tuple[np.ndarray, np.ndarray]],
    pool: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-``keyword:``-token FTS pools into one lexical hit list.

    Overlapping hits across tokens dedup by chunk id and their per-token
    min-max scores combine by CombSUM (the sum of normalized scores — a
    chunk matching several keyword clauses outranks one matching a
    single clause at the same strength), then the combined scores
    re-normalize to [0, 1] and the list sorts descending, ties broken by
    first-seen order (token order, then each pool's own rank) so the
    result is deterministic.  Truncates to ``pool`` entries.
    """
    scores: dict = {}
    order: dict = {}
    for ids, vals in pools:
        for i, v in zip(np.asarray(ids, np.int64),
                        np.asarray(vals, np.float32)):
            i = int(i)
            scores[i] = scores.get(i, 0.0) + float(v)
            if i not in order:
                order[i] = len(order)
    if not scores:
        return np.empty(0, np.int64), np.empty(0, np.float32)
    ranked = sorted(scores.items(), key=lambda kv: (-kv[1], order[kv[0]]))
    ranked = ranked[:max(0, int(pool))]
    ids = np.asarray([i for i, _ in ranked], np.int64)
    vals = minmax_normalize(np.asarray([v for _, v in ranked], np.float32))
    return ids, np.asarray(vals, np.float32)


def minmax_normalize(values: Array) -> Array:
    """Min-max normalize to [0, 1]; degenerate (max==min) maps to ones."""
    np_mod = _module_of(values)
    values = np_mod.asarray(values)
    if values.shape[0] == 0:
        return values
    lo, hi = values.min(), values.max()
    if hi == lo:
        return np_mod.ones_like(values)
    return (values - lo) / (hi - lo)


def rrf_fuse(
    vector_ids: Sequence[int],
    lexical_ids: Sequence[int],
    rrf_k: int = DEFAULT_RRF_K,
) -> List[Tuple[int, float]]:
    """Reciprocal-rank fusion over two ranked id lists.

    score(id) = sum over lists containing id of 1/(rrf_k + rank), rank
    1-based.  Ties break deterministically by first-seen order (vector
    list first, then lexical).
    """
    scores: dict = {}
    order: dict = {}
    for lst in (vector_ids, lexical_ids):
        for rank, i in enumerate(lst, start=1):
            i = int(i)
            scores[i] = scores.get(i, 0.0) + 1.0 / (rrf_k + rank)
            if i not in order:
                order[i] = len(order)
    return sorted(scores.items(), key=lambda kv: (-kv[1], order[kv[0]]))


# ---------------------------------------------------------------------------
# The five modulations, as pure functions (paper Table 1).
# ---------------------------------------------------------------------------


def apply_centroid(query: Array, spec: CentroidSpec) -> Array:
    """q = alpha*q + (1-alpha)*mean(E[ids]);  q /= ||q||   (query-side)."""
    center = spec.examples.mean(axis=0)
    q = spec.alpha * query + (1.0 - spec.alpha) * center
    return l2_normalize(q)


def base_similarity(matrix: Array, query: Array) -> Array:
    """Brute-force cosine scores for L2-normalized rows: one matvec."""
    return matrix @ query


def apply_trajectory(scores: Array, matrix: Array, spec: TrajectorySpec) -> Array:
    """scores = (1-b)*sim + b*(M @ (embed(B) - embed(A))), b = 0.5 default."""
    directional = matrix @ spec.direction
    return (1.0 - spec.blend) * scores + spec.blend * directional


def apply_decay(scores: Array, days_ago: Array, spec: DecaySpec) -> Array:
    """scores *= 1 / (1 + days/N). Not a filter: old-but-relevant survives."""
    return scores * (1.0 / (1.0 + days_ago / spec.half_life_days))


def apply_suppress(scores: Array, matrix: Array, spec: SuppressSpec) -> Array:
    """scores -= w * (M @ embed(X)). Multiple suppressions stack additively."""
    return scores - spec.weight * (matrix @ spec.direction)


def mmr_select_np(
    pool_embeds: np.ndarray,
    pool_scores: np.ndarray,
    k: int,
    lam: float = DEFAULT_MMR_LAMBDA,
) -> np.ndarray:
    """Maximal Marginal Relevance over a candidate pool (numpy host path).

    Iteratively picks argmax of  lam*rel - (1-lam)*max_sim(selected)  from the
    remaining pool.  O(k * n * d); the pool is small (paper: 3x oversample of
    K=500) so this is the paper's ``k x n pairwise`` cost.
    """
    n = pool_scores.shape[0]
    k = min(k, n)
    selected = np.empty(k, dtype=np.int64)
    max_sim = np.full(n, -np.inf)
    taken = np.zeros(n, dtype=bool)
    for i in range(k):
        mmr = lam * pool_scores - (1.0 - lam) * np.where(
            np.isneginf(max_sim), 0.0, max_sim
        )
        mmr = np.where(taken, -np.inf, mmr)
        j = int(np.argmax(mmr))
        selected[i] = j
        taken[j] = True
        sim_to_j = pool_embeds @ pool_embeds[j]
        max_sim = np.maximum(max_sim, sim_to_j)
    return selected


def modulate_scores(
    matrix: Array,
    days_ago: Optional[Array],
    plan: ModulationPlan,
) -> Array:
    """Run the score-side fixed-order pipeline (no selection).

    Order (paper §3.3): centroid (query shift) -> base similarity ->
    trajectory -> decay -> suppress.  `diverse` changes selection, not
    scoring, and is applied by the caller over the top-pool candidates.
    """
    q = plan.query
    if plan.centroid is not None:
        q = apply_centroid(q, plan.centroid)
    scores = base_similarity(matrix, q)
    if plan.trajectory is not None:
        scores = apply_trajectory(scores, matrix, plan.trajectory)
    if plan.decay is not None:
        if days_ago is None:
            raise ValueError("decay: modulation requires per-chunk timestamps")
        scores = apply_decay(scores, days_ago, plan.decay)
    for spec in plan.suppress:
        scores = apply_suppress(scores, matrix, spec)
    scale = fusion_scale(plan)
    if scale != 1.0:  # guarded: fuse:weighted,1.0 stays bit-identical
        scores = scores * scale
    return scores


def effective_query(plan: ModulationPlan) -> Array:
    """The query vector after centroid shift (what base similarity uses)."""
    q = plan.query
    if plan.centroid is not None:
        q = apply_centroid(q, plan.centroid)
    return q


def stacked_directions(plan: ModulationPlan) -> Tuple[Array, Array]:
    """Fuse all query-side directions into one (d, m) panel + (m,) weights.

    This is the beyond-paper TPU formulation: because trajectory and suppress
    are LINEAR in the scores, the whole pre-decay/post-decay pipeline is

        scores = (M @ Q_all) @ w        with decay folded multiplicatively.

    Column 0 is the (centroid-shifted) query; its weight absorbs the
    trajectory blend ((1-b) scaling of the base sim). Trajectory contributes
    column with weight b. Suppressions contribute columns with weight -w_i.

    NOTE decay ordering: the paper applies decay BEFORE suppress, i.e.
        s = decay(
              (1-b)*sim + b*traj
            ) - sum_i w_i * (M @ x_i)
    so the fused form is  decay * (M @ Q_pre) @ w_pre  +  (M @ Q_sup) @ w_sup.
    `stacked_directions` returns the PRE-decay panel columns first and the
    suppress columns after; the consumer splits at `1 + has_trajectory`.
    """
    np_mod = _module_of(plan.query)
    q = effective_query(plan)
    cols = [q]
    weights = [1.0]
    if plan.trajectory is not None:
        weights[0] = 1.0 - plan.trajectory.blend
        cols.append(plan.trajectory.direction)
        weights.append(plan.trajectory.blend)
    for spec in plan.suppress:
        cols.append(spec.direction)
        weights.append(-spec.weight)
    panel = np_mod.stack(cols, axis=1)  # (d, m)
    w = np_mod.asarray(weights, dtype=panel.dtype)
    return panel, w


def fold_plan(plan: ModulationPlan) -> Tuple[np.ndarray, np.ndarray]:
    """Fold one plan's directions into (q_pre, q_sup), each (d,).

    Linearity (DESIGN.md §2.1): trajectory and suppress are linear in the
    score array, so
        q_pre = (1-blend)*q_centroid_shifted + blend*direction_traj
        q_sup = -sum_i w_i * x_i
    and  scores = decay * (M @ q_pre) + M @ q_sup  reproduces the fixed-order
    pipeline exactly.
    """
    q = np.asarray(effective_query(plan), dtype=np.float32)
    if plan.trajectory is not None:
        b = plan.trajectory.blend
        q_pre = (1.0 - b) * q + b * np.asarray(plan.trajectory.direction, np.float32)
    else:
        q_pre = q
    d = q.shape[-1]
    q_sup = np.zeros(d, dtype=np.float32)
    for spec in plan.suppress:
        q_sup -= spec.weight * np.asarray(spec.direction, np.float32)
    scale = fusion_scale(plan)
    if scale != 1.0:  # guarded: fuse:weighted,1.0 stays bit-identical
        q_pre = np.asarray(scale * q_pre, dtype=np.float32)
        q_sup = np.asarray(scale * q_sup, dtype=np.float32)
    return q_pre, q_sup


def fold_plans(plans: Sequence[ModulationPlan]) -> Tuple[np.ndarray, np.ndarray]:
    """Batch of plans -> (q_pre (d,B), q_sup (d,B)) panels."""
    pres, sups = zip(*(fold_plan(p) for p in plans))
    return np.stack(pres, axis=1), np.stack(sups, axis=1)


def fused_modulate_scores(
    matrix: Array,
    days_ago: Optional[Array],
    plan: ModulationPlan,
) -> Array:
    """Single-GEMM formulation of `modulate_scores` (algebraically equal).

    scores = decay * ((M @ Q_pre) @ w_pre) + (M @ Q_sup) @ w_sup
    """
    panel, w = stacked_directions(plan)
    n_pre = 1 + (1 if plan.trajectory is not None else 0)
    all_scores = matrix @ panel  # (N, m) — ONE pass over the corpus matrix
    pre = all_scores[:, :n_pre] @ w[:n_pre]
    if plan.decay is not None:
        if days_ago is None:
            raise ValueError("decay: modulation requires per-chunk timestamps")
        pre = apply_decay(pre, days_ago, plan.decay)
    if panel.shape[1] > n_pre:
        pre = pre + all_scores[:, n_pre:] @ w[n_pre:]
    scale = fusion_scale(plan)
    if scale != 1.0:  # guarded: fuse:weighted,1.0 stays bit-identical
        pre = pre * scale
    return pre


def _module_of(x: Array):
    """numpy-or-jax dispatch for the few non-operator calls we need."""
    if type(x).__module__.startswith("jax") or "Array" in type(x).__name__:
        import jax.numpy as jnp

        return jnp
    return np
