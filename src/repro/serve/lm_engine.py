"""LM decode service: slot-based continuous batching (vLLM-style loop,
TPU-shaped state).

A fixed pool of decode SLOTS shares one (L, B_slots, T, K, hd) KV cache;
requests claim a free slot (prefill), the decode step advances EVERY active
slot by one token per iteration (one jitted step for the whole pool), and
finished slots are recycled mid-flight — new requests join between steps
without recompiling (static shapes).

This is the serving analogue of the PEM micro-batcher: amortize the
weight/cache stream across concurrent requests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import ShardingRules
from repro.models import transformer as T
from repro.models.layers import LMConfig


@dataclasses.dataclass
class DecodeRequest:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class LMDecodeEngine:
    """Continuous-batching decode over a shared slot pool."""

    def __init__(self, cfg: LMConfig, params: Any, rules: ShardingRules,
                 n_slots: int = 4, max_ctx: int = 256):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.n_slots = n_slots
        self.max_ctx = max_ctx
        self.cache = T.make_cache(cfg, n_slots, max_ctx)
        self.slot_req: List[Optional[DecodeRequest]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)      # filled cache length
        self.slot_budget = np.zeros(n_slots, np.int32)   # remaining new tokens
        self.last_token = np.zeros(n_slots, np.int32)
        self.steps = 0

        # one jitted call advances EVERY slot at its own position (vmap
        # re-batches per-slot single-sequence decodes; positions and kv
        # masks are per-slot via the lens vector)
        self._step = jax.jit(self._batched_decode)

    # -- jitted core ---------------------------------------------------------

    def _batched_decode(self, params, token, cache, lens):
        """token (B,1); lens (B,) per-slot cache fill -> (logits, cache)."""
        cfg, rules = self.cfg, self.rules
        B = token.shape[0]

        def one(tok, ck, cv, ln):
            # per-slot single-sequence decode (vmap re-batches)
            logits, (nk, nv) = T.forward(
                params, tok[None, None], cfg, rules,
                positions=ln + jnp.arange(1),
                cache=(ck[:, None], cv[:, None]),
                cache_len=ln, return_cache=True,
            )
            return logits[0, -1], nk[:, 0], nv[:, 0]

        return jax.vmap(one, in_axes=(0, 1, 1, 0), out_axes=(0, 1, 1))(
            token[:, 0], cache[0], cache[1], lens)

    # -- slot management -------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def submit(self, req: DecodeRequest) -> bool:
        """Claim a slot + prefill. False if the pool is full (caller queues)."""
        slot = self._free_slot()
        if slot is None:
            return False
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, pcache = T.prefill_step(self.params, prompt, self.cfg, self.rules)
        # write prefilled KV into the slot at offset 0
        pk, pv = pcache
        ck, cv = self.cache
        ck = jax.lax.dynamic_update_slice(ck, pk.astype(ck.dtype), (0, slot, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, pv.astype(cv.dtype), (0, slot, 0, 0, 0))
        self.cache = (ck, cv)
        self.slot_req[slot] = req
        self.slot_len[slot] = req.prompt.shape[0]
        self.slot_budget[slot] = req.max_new_tokens
        self.last_token[slot] = int(jnp.argmax(logits[0]))
        req.tokens.append(int(self.last_token[slot]))
        return True

    def step(self) -> int:
        """One decode iteration over all ACTIVE slots. Returns #active."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        token = jnp.asarray(self.last_token[:, None], jnp.int32)
        lens = jnp.asarray(self.slot_len, jnp.int32)
        logits, nk, nv = self._step(self.params, token, self.cache, lens)
        self.cache = (nk, nv)
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i in active:
            req = self.slot_req[i]
            self.slot_len[i] += 1
            self.slot_budget[i] -= 1
            tok = int(nxt[i])
            req.tokens.append(tok)
            self.last_token[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            out_of_ctx = self.slot_len[i] + 1 >= self.max_ctx
            if self.slot_budget[i] <= 0 or hit_eos or out_of_ctx:
                req.done = True
                self.slot_req[i] = None          # recycle mid-flight
        return len(active)

    def run(self, requests: List[DecodeRequest]) -> Dict[str, float]:
        """Serve a workload to completion with continuous batching."""
        queue = list(requests)
        served = 0
        occupancy = []
        while queue or any(r is not None for r in self.slot_req):
            while queue and self.submit(queue[0]):
                queue.pop(0)
                served += 1
            n = self.step()
            if n:
                occupancy.append(n)
        return {
            "requests": served,
            "decode_steps": self.steps,
            "mean_occupancy": float(np.mean(occupancy)) if occupancy else 0.0,
        }
