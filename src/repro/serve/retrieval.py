"""RetrievalService — the agent-facing surface (paper: FLEX via MCP).

One endpoint, two parameters (paper Appendix B): ``flex_search(query)``
where query is SQL (routed through the materializer) or an ``@preset``.
Errors come back as explicit structured failures so the agent can rewrite
and retry — never silent misexecution (paper §7).
"""

from __future__ import annotations

import dataclasses
import sqlite3
import time
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.backends import ExecutionBackend, get_backend
from repro.core.materializer import MaterializeError, Materializer
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder
from repro.sqlio.presets import run_preset
from repro.sqlio.schema import load_embedding_matrix


@dataclasses.dataclass
class SearchResult:
    ok: bool
    columns: List[str] = dataclasses.field(default_factory=list)
    rows: List[tuple] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    latency_ms: float = 0.0


class RetrievalService:
    """SQLite + VectorCache + Materializer behind one search call."""

    def __init__(
        self,
        conn: sqlite3.Connection,
        dim: int = 128,
        embedder: Optional[HashEmbedder] = None,
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "reference",
    ):
        self.conn = conn
        self.embedder = embedder or HashEmbedder(dim)
        ids, matrix, ts = load_embedding_matrix(conn, dim)
        self.cache = VectorCache(ids, matrix, ts, self.embedder)
        self.now = now
        # one registry resolve for the service lifetime; every Materializer
        # this service builds shares the same backend instance — including
        # its device-resident corpus cache and compiled PlanCache, so
        # repeated queries with the same plan structure never retrace
        self.engine = get_backend(engine)
        self.query_count = 0
        self.error_count = 0

    def flex_search(self, query: str) -> SearchResult:
        """SQL or @preset -> rows. The agent's single endpoint."""
        t0 = time.time()
        self.query_count += 1
        try:
            if query.strip().startswith("@"):
                name = query.strip().split()[0]
                out = run_preset(self.conn, name)
                rows: List[tuple] = []
                cols = ["section", "data"]
                for key, (c, r) in out.items():
                    rows.append((key, {"columns": c, "rows": r}))
                return SearchResult(True, cols, rows,
                                    latency_ms=(time.time() - t0) * 1e3)
            mz = Materializer(self.conn, self.cache, now=self.now,
                              engine=self.engine)
            cols, rows = mz.execute(query)
            return SearchResult(True, cols, rows,
                                latency_ms=(time.time() - t0) * 1e3)
        except (MaterializeError, sqlite3.Error, KeyError) as e:
            # explicit failure -> the agent rewrites and retries (paper §7)
            self.error_count += 1
            return SearchResult(False, error=f"{type(e).__name__}: {e}",
                                latency_ms=(time.time() - t0) * 1e3)
