"""RetrievalService — the agent-facing surface (paper: FLEX via MCP).

One endpoint, two parameters (paper Appendix B): ``flex_search(query)``
where query is SQL (routed through the materializer) or an ``@preset``.
Errors come back as explicit structured failures so the agent can rewrite
and retry — never silent misexecution (paper §7).

Live corpora: ``INSERT INTO chunks`` / ``DELETE FROM chunks`` through
``flex_search`` (or the direct :meth:`RetrievalService.ingest` /
:meth:`RetrievalService.delete` methods) keep SQLite, FTS5 and the
segmented VectorCache in sync — only the touched segment changes.
:meth:`stats` surfaces query/error counts plus the engine's PlanCache
(hit/trace/eviction) and device-upload counters, the store shape, and the
Phase-1 ``prefilter`` router counters (``routed_masked`` /
``routed_gather`` / ``mask_build_ms``).

Async serving: :meth:`serving` attaches the continuous-batching
:class:`~repro.serve.engine.BatchedRetrievalEngine` (admission queue with
backpressure, per-request priorities/deadlines, pipelined device/host
overlap) over the SAME VectorCache, and the ``*_async`` variants
(:meth:`search_async`, :meth:`flex_search_async`, :meth:`ingest_async`,
:meth:`delete_async`) make every entry point awaitable without blocking
the caller's event loop.  Once attached, :meth:`stats` grows a
``serving`` section — queue depth, rejections, deadline misses, the
pipeline-overlap counter, idle-gap compactions.
"""

from __future__ import annotations

import asyncio
import dataclasses
import sqlite3
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import ExecutionBackend, get_backend
from repro.core.materializer import MaterializeError, Materializer
from repro.core.vectorcache import VectorCache
from repro.embed import HashEmbedder
from repro.sqlio.presets import run_preset
from repro.sqlio.schema import (delete_chunks, insert_chunks,
                                load_embedding_matrix)


@dataclasses.dataclass
class SearchResult:
    ok: bool
    columns: List[str] = dataclasses.field(default_factory=list)
    rows: List[tuple] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    latency_ms: float = 0.0


class RetrievalService:
    """SQLite + VectorCache + Materializer behind one search call."""

    def __init__(
        self,
        conn: sqlite3.Connection,
        dim: int = 128,
        embedder: Optional[HashEmbedder] = None,
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "reference",
        *,
        store_path: Optional[Any] = None,
        fault_plan: Optional[Any] = None,
    ):
        self.conn = conn
        self.embedder = embedder or HashEmbedder(dim)
        ids, matrix, ts = load_embedding_matrix(conn, dim)
        self._fault_plan = fault_plan
        # the FTS5/BM25 resolver behind every keyword:/fuse: plan built
        # through this service — shares the materializer's quoting fallback
        if store_path is not None:
            # durable mode: the segment store journals every mutation to
            # ``store_path`` and recovers from its snapshot + delta on
            # open; the SQLite matrix seeds it only when the journal is
            # brand-new (afterwards the journal IS the vector-store truth)
            from repro.core.segments import SegmentedCorpusStore

            store = SegmentedCorpusStore.open(
                store_path, dim=dim, fault_plan=fault_plan)
            if store.n_rows == 0 and len(ids):
                store.append(ids, matrix, ts)
            self.cache = VectorCache(embed_fn=self.embedder, store=store,
                                     lexical_fn=self._lexical_scores)
        else:
            self.cache = VectorCache(ids, matrix, ts, self.embedder,
                                     lexical_fn=self._lexical_scores)
        self.now = now
        # one registry resolve for the service lifetime; every Materializer
        # this service builds shares the same backend instance — including
        # its device-resident corpus cache and compiled PlanCache, so
        # repeated queries with the same plan structure never retrace
        self.engine = get_backend(engine)
        self.query_count = 0
        self.error_count = 0
        self._serving = None  # lazy BatchedRetrievalEngine (see serving())
        self._serving_lock = threading.Lock()
        self._shard_group = None  # lazy ProcessGroup (see shard_group())

    def flex_search(self, query: str, params: Sequence = ()) -> SearchResult:
        """SQL or @preset -> rows. The agent's single endpoint.

        ``params`` are standard SQLite positional bind parameters for the
        (rewritten) statement — same contract as ``Materializer.execute``,
        so parameterized SQL no longer needs a hand-built Materializer.
        """
        t0 = time.time()
        self.query_count += 1
        try:
            if query.strip().startswith("@"):
                name = query.strip().split()[0]
                out = run_preset(self.conn, name)
                rows: List[tuple] = []
                cols = ["section", "data"]
                for key, (c, r) in out.items():
                    rows.append((key, {"columns": c, "rows": r}))
                return SearchResult(True, cols, rows,
                                    latency_ms=(time.time() - t0) * 1e3)
            mz = Materializer(self.conn, self.cache, now=self.now,
                              engine=self.engine, serving=self._serving)
            cols, rows = mz.execute(query, params)
            return SearchResult(True, cols, rows,
                                latency_ms=(time.time() - t0) * 1e3)
        except (MaterializeError, sqlite3.Error, KeyError) as e:
            # explicit failure -> the agent rewrites and retries (paper §7)
            self.error_count += 1
            return SearchResult(False, error=f"{type(e).__name__}: {e}",
                                latency_ms=(time.time() - t0) * 1e3)

    def search(
        self,
        tokens: str,
        k: Optional[int] = 10,
        *,
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        candidate_ids: Optional[Sequence[int]] = None,
    ) -> List[Tuple[int, float]]:
        """Synchronous token search — the blocking mirror of
        :meth:`search_async` (same signature minus ``await``).  Routes
        through the attached batched engine when :meth:`serving` has been
        called (priorities/deadlines/batching apply); otherwise runs the
        direct VectorCache path, where ``priority``/``deadline_ms`` have
        no queue to act on and are accepted for signature parity.
        """
        if self._serving is not None:
            return self._serving.search(
                tokens, k, priority=priority, deadline_ms=deadline_ms,
                candidate_ids=candidate_ids)
        if self._shard_group is not None:
            from repro.core import grammar

            plan = grammar.parse(tokens, self.cache.embed_fn,
                                 self.cache.embeddings_for_ids,
                                 self.cache.lexical_fn)
            results = self._shard_group.search_plan(
                plan, candidate_ids, now=self.now)
            return results if k is None else results[:k]
        results = self.cache.search(
            tokens, candidate_ids=candidate_ids, now=self.now,
            engine=self.engine)
        return results if k is None else results[:k]

    def _lexical_scores(self, term: str, limit: int) -> Tuple[np.ndarray, np.ndarray]:
        """``grammar.LexicalFn`` over this service's FTS5 table: keyword
        text + pool width -> (ids desc-by-bm25, min-max scores)."""
        from repro.core.materializer import fts_query

        rows = fts_query(self.conn, term, limit=limit)
        if not rows:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.float32))
        ids = np.asarray([r[0] for r in rows], dtype=np.int64)
        from repro.core import modulations as M
        return ids, M.minmax_normalize(
            np.asarray([r[1] for r in rows], np.float32))

    # -- async serving surface ----------------------------------------------

    def serving(
        self,
        *,
        vectorize: bool = True,
        ingest_queue: int = 1024,
        ingest_batch: int = 64,
        ingest_max_attempts: int = 5,
        ingest_base_backoff_s: float = 0.05,
        **engine_kwargs,
    ) -> "Any":
        """The service's continuous-batching engine, created on first use
        over the same VectorCache (same store, same compiled plans, same
        backend — batched and direct rankings stay bit-identical).

        Unless ``vectorize=False``, the engine carries a background
        ingest vectorizer: ``INSERT INTO chunks`` rows arriving without
        embeddings enqueue (bounded at ``ingest_queue`` rows —
        backpressure, not unbounded memory) and embed in batches of
        ``ingest_batch`` in the scheduler's idle gaps, retrying embedder
        failures with exponential backoff up to ``ingest_max_attempts``
        before dead-lettering.  Rows recovered from a journal as
        enqueued-but-never-embedded are re-adopted here.

        ``engine_kwargs`` (``max_batch``, ``max_wait_ms``, ``max_queue``,
        ``pipeline``, ``compaction``, ...) apply only on first creation.
        """
        with self._serving_lock:  # two racing first calls = one engine
            if self._serving is None:
                from repro.serve.engine import BatchedRetrievalEngine

                vec = None
                if vectorize:
                    from repro.serve.vectorizer import (IngestQueue,
                                                        VectorizerWorker)

                    store = self.cache.store
                    vec = VectorizerWorker(
                        IngestQueue(ingest_queue),
                        self.embedder,
                        self._vectorizer_sink,
                        batch_size=ingest_batch,
                        max_attempts=ingest_max_attempts,
                        base_backoff_s=ingest_base_backoff_s,
                        journal=store.journal,
                        fault_plan=self._fault_plan,
                    )
                    vec.adopt(store.recovered_pending,
                              store.recovered_dead_letters)
                    store.recovered_pending = []
                    store.recovered_dead_letters = []
                self._serving = BatchedRetrievalEngine(
                    self.cache, now=self.now, engine=self.engine,
                    shard_group=self._shard_group, vectorizer=vec,
                    **engine_kwargs)
            return self._serving

    def _vectorizer_sink(self, ids: List[int], vecs: np.ndarray,
                         ts: List[Optional[float]]) -> None:
        """Vectorizer batch -> sealed cache segment (+ shard mirror),
        with the same timestamp-presence policy as the inline path."""
        store = self.cache.store
        use_ts = store.has_timestamps or not store.n_segments
        stamps = [t or 0.0 for t in ts] if use_ts else None
        self.cache.ingest(ids, vecs, stamps)
        if self._shard_group is not None:
            self._shard_group.append(ids, vecs, stamps)

    def shard_group(
        self,
        n_shards: int = 4,
        *,
        transport: str = "thread",
        dtype: str = "f32",
        replicas: int = 1,
        block: Optional[int] = None,
    ) -> "Any":
        """Attach a cross-process shard group mirroring this service's
        corpus (:class:`repro.dist.procgroup.ProcessGroup`): the corpus is
        dealt round-robin across ``n_shards`` per-shard segmented stores
        and every subsequent :meth:`search` — direct, or batched once
        :meth:`serving` is attached afterwards — fans out to one replica
        per shard and merges with the exact-union contract.  Ingest and
        delete keep the group in sync with the cache.  ``dtype`` picks
        the per-shard scoring mode: ``"f32"`` (exact, bit-identical to
        the monolith), ``"f32b"`` (blocked single-stream panel pass —
        the million-chunk latency mode) or ``"bf16"`` (packed codes,
        half the resident scoring bytes).  Arguments apply on first
        creation only.
        """
        with self._serving_lock:
            if self._shard_group is None:
                from repro.dist.procgroup import ProcessGroup

                with self.cache.store.lock:
                    self._shard_group = ProcessGroup.build(
                        self.cache.ids, self.cache.matrix,
                        self.cache.timestamps, normalized=True,
                        n_shards=n_shards, transport=transport,
                        dtype=dtype, replicas=replicas, block=block)
                if self._serving is not None:
                    self._serving.shard_group = self._shard_group
            return self._shard_group

    async def search_async(
        self,
        tokens: str,
        k: Optional[int] = 10,
        *,
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        candidate_ids: Optional[Sequence[int]] = None,
    ) -> List[Tuple[int, float]]:
        """Awaitable token search through the batched engine: admission
        (with backpressure), micro-batching, pipelined scoring — without
        ever blocking the caller's event loop.  ``candidate_ids`` is the
        Phase-1 pre-filter output; filtered requests batch and route
        (masked-device vs gather-host) like every other request."""
        return await self.serving().asearch(
            tokens, k, priority=priority, deadline_ms=deadline_ms,
            candidate_ids=candidate_ids)

    async def flex_search_async(self, query: str) -> SearchResult:
        """Awaitable ``flex_search`` (SQL / @preset): the materializer is
        synchronous SQLite, so it runs on a worker thread."""
        return await asyncio.to_thread(self.flex_search, query)

    async def ingest_async(
        self,
        rows: Sequence[tuple],
        embeddings: Optional[np.ndarray] = None,
    ) -> int:
        """Awaitable :meth:`ingest` — the store lock may briefly wait for
        an in-flight scoring pass, so keep it off the event loop."""
        return await asyncio.to_thread(self.ingest, rows, embeddings)

    async def delete_async(self, ids: Sequence[int]) -> int:
        """Awaitable :meth:`delete` (same reasoning as ingest_async)."""
        return await asyncio.to_thread(self.delete, ids)

    def close(self) -> None:
        """Shut down the attached serving engine and the shard group's
        worker replicas — WITHOUT dropping accepted ingest: the engine's
        close flushes the vectorizer queue (every queued INSERT either
        embeds or dead-letters within its retry budget), and a journaled
        store writes a final checkpoint so the next open recovers the
        exact serving state with zero replay."""
        serving, self._serving = self._serving, None
        if serving is not None:
            serving.close()
        store = self.cache.store
        if store.journal is not None:
            vec = serving.vectorizer if serving is not None else None
            if vec is not None:
                pending = vec.queue.snapshot_rows()  # empty unless a
                #             sink failure interrupted the close flush
                dead = vec.dead_letters
            else:
                pending = store.recovered_pending
                dead = store.recovered_dead_letters
            store.checkpoint(pending=pending, dead_letters=dead)
            store.journal.close()
        if self._shard_group is not None:
            self._shard_group.close()
            self._shard_group = None

    # -- live-corpus entry points -------------------------------------------

    def ingest(
        self,
        rows: Sequence[tuple],
        embeddings: Optional[np.ndarray] = None,
    ) -> int:
        """Append chunk rows (the ``insert_chunks`` tuple shape) to SQLite
        + FTS and seal them as ONE new VectorCache segment.  Missing
        embeddings are computed from content.  Returns rows ingested."""
        rows = list(rows)
        if not rows:
            return 0
        # validate BEFORE touching SQLite: a duplicate live id would
        # otherwise REPLACE the row, desyncing FTS and the vector store
        dupes = [int(r[0]) for r in rows if int(r[0]) in self.cache.store]
        if dupes:
            raise ValueError(
                f"ingest: ids already live in the corpus: {dupes[:10]}"
                + ("..." if len(dupes) > 10 else "")
            )
        if embeddings is None:
            embeddings = np.stack(
                [self.embedder(r[3] or "") for r in rows]
            ).astype(np.float32)
        insert_chunks(self.conn, rows, embeddings)
        self.cache.ingest(
            [r[0] for r in rows], embeddings,
            [r[4] or 0.0 for r in rows],
        )
        if self._shard_group is not None:
            self._shard_group.append(
                [r[0] for r in rows], embeddings,
                [r[4] or 0.0 for r in rows])
        return len(rows)

    def delete(self, ids: Sequence[int]) -> int:
        """Remove chunks from SQLite + FTS, tombstone them in the cache."""
        removed = delete_chunks(self.conn, ids)
        if removed:
            self.cache.delete(removed)
            if self._shard_group is not None:
                self._shard_group.delete(removed)
        return len(removed)

    def stats(self) -> Dict[str, Any]:
        """Serving + storage + compile-cache counters, one dict.

        ``plan_cache`` (hits/builds/evictions/jax_traces) and
        ``device_cache`` (uploads/hits/evictions) appear when the resolved
        backend compiles executables / keeps device-resident segments —
        the observability half of the PlanCache productionization.
        ``serving`` (queue_depth / rejected / deadline_misses /
        overlapped_batches / compactions_run) appears once the async
        batched engine is attached via :meth:`serving`.  ``prefilter``
        (threshold / routed_masked / routed_panel / routed_gather /
        mask_build_ms) is the Phase-1 selectivity router's ledger.
        ``fused`` (device_mmr / host_pool_transfers / panel_batches)
        tracks how often Phase-2 finished entirely on device and how
        often a host pool round-trip was still needed.
        """
        out: Dict[str, Any] = {
            "engine": self.engine.name,
            "queries": self.query_count,
            "errors": self.error_count,
            "store": self.cache.store.stats(),
            "prefilter": self.cache.prefilter.stats(),
            "fused": self.cache.fused.stats(),
        }
        if self._serving is not None:
            out["serving"] = self._serving.stats()
        vec = (self._serving.vectorizer
               if self._serving is not None else None)
        store = self.cache.store
        if vec is not None or store.journal is not None:
            # the durable-ingest ledger: queue/worker counters plus the
            # journal's recovery cost (records replayed at the last open,
            # bytes a crash right now would have to replay)
            ingest: Dict[str, Any] = {
                "queued": 0, "in_queue": 0, "rejected": 0, "embedded": 0,
                "batches": 0, "retries": 0, "dead_letter": 0,
            }
            if vec is not None:
                ingest.update(vec.stats())
            ingest["recovered_records"] = store.recovered_records
            ingest["journal_bytes"] = (
                store.journal.journal_bytes
                if store.journal is not None else 0)
            ingest["checkpoints"] = store.checkpoints
            out["ingest"] = ingest
        if self._shard_group is not None:
            # topology + per-shard memory/latency rows (the million-chunk
            # capacity ledger: each shard reports its scoring-resident
            # bytes and last fan-out pass latency)
            out["shard_group"] = self._shard_group.stats()
        plan_cache = getattr(self.engine, "plan_cache", None)
        if plan_cache is not None:
            out["plan_cache"] = plan_cache.stats()
        dev_stats = getattr(self.engine, "device_cache_stats", None)
        if dev_stats is not None:
            out["device_cache"] = dev_stats()
        return out
