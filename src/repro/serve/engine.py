"""Batched serving engine: request micro-batching over the PEM kernel.

The paper serves one agent query at a time (desktop MCP). At fleet scale,
queries are MICRO-BATCHED so the corpus matrix is streamed once per batch
(pem_score's (d, B) query panel): the scoring cost is amortized B ways —
the arithmetic-intensity argument in DESIGN.md §2.1.

The engine is synchronous-core with a thread-safe front door: requests
accumulate until `max_batch` or `max_wait_ms`, then one backend scoring
pass answers all of them.  Scoring and selection route through the shared
:mod:`repro.core.backends` dispatch — segment-aware via
``score_select_segments``, the same code path as the direct
``VectorCache`` engine, so batched and direct rankings are identical.

Live corpora: :meth:`ingest` and :meth:`delete` append/tombstone chunks
between batches (the store lock spans one scoring pass, so a mutation
never lands inside a batch).  Appends seal a new segment; warm segments
keep their device residency and compiled plans.

Failure isolation: a bad request (grammar error, decay without
timestamps) fails ONLY that request — its error re-raises from ``search``
— while the rest of the batch is served normally.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import (ExecutionBackend, finalize_candidates,
                                 get_backend, score_select_segments)
from repro.core.grammar import parse
from repro.core.segments import gather_ids, gather_rows
from repro.core.vectorcache import VectorCache


@dataclasses.dataclass
class Request:
    tokens: str
    k: int = 10
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    _result: Optional[List[Tuple[int, float]]] = None
    _error: Optional[Exception] = None
    enqueued_at: float = dataclasses.field(default_factory=time.time)
    latency_ms: float = 0.0


class BatchedRetrievalEngine:
    def __init__(
        self,
        cache: VectorCache,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "fused",
    ):
        self.cache = cache
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.now = now
        self.backend = get_backend(engine)
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self.batches_served = 0
        self.requests_served = 0
        self._worker.start()

    # -- public API --------------------------------------------------------

    def search(self, tokens: str, k: int = 10, timeout: float = 30.0):
        req = Request(tokens=tokens, k=k)
        self._q.put(req)
        if not req._event.wait(timeout):
            raise TimeoutError("retrieval request timed out")
        if req._error is not None:
            raise req._error
        return req._result

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=2.0)

    def ingest(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
    ):
        """Append chunks as one sealed segment; lands between batches
        (the store lock spans a scoring pass). Returns the new segment."""
        return self.cache.ingest(ids, matrix, timestamps,
                                 normalized=normalized)

    def delete(self, ids: Sequence[int], *, strict: bool = False) -> int:
        """Tombstone chunks between batches; returns rows tombstoned."""
        return self.cache.delete(ids, strict=strict)

    # -- batching core -------------------------------------------------------

    def _collect(self) -> List[Request]:
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.time() + self.max_wait_ms / 1e3
        while len(batch) < self.max_batch:
            remaining = deadline - time.time()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            self._serve(batch)

    def _fail(self, req: Request, err: Exception) -> None:
        req._error = err
        req.latency_ms = (time.time() - req.enqueued_at) * 1e3
        req._event.set()

    def _finish(self, req: Request, result: List[Tuple[int, float]]) -> None:
        req._result = result
        req.latency_ms = (time.time() - req.enqueued_at) * 1e3
        req._event.set()
        self.requests_served += 1

    def _serve(self, batch: List[Request]) -> None:
        """One fused backend pass: fold every live request's plan into the
        (d, B) panels and run the segment-aware ``score_select_segments``
        — every segment is scored ONCE for the whole batch (tombstones
        masked on device) and only per-request candidate lists come back
        (the (N, B) panel never reaches this thread)."""
        store = self.cache.store
        live: List[Request] = []
        plans = []
        for req in batch:
            try:
                plan = parse(req.tokens, self.cache.embed_fn,
                             self.cache.embeddings_for_ids)
                if plan.decay is not None and not store.has_timestamps:
                    raise ValueError("decay: requires timestamps in the cache")
            except Exception as e:  # bad request: fail it, keep the batch
                self._fail(req, e)
                continue
            live.append(req)
            plans.append(plan)

        self.batches_served += 1
        if not live:
            return

        ref = self.now if self.now is not None else time.time()
        try:
            # the lock spans snapshot + scoring: ingest/delete land
            # BETWEEN batches, never inside one
            with store.lock:
                segs = store.segments
                n_live = store.n_live
                ks = [min(req.k, n_live) for req in live]
                # per-plan (global_rows, scores) candidates — (pool,)-sized
                selected = score_select_segments(
                    self.backend, segs, plans, ks, now=ref)
        except Exception as e:  # backend failure: fail the whole batch loudly
            for req in live:
                self._fail(req, e)
            return

        for req, plan, k, (gidx, vals) in zip(live, plans, ks, selected):
            try:
                pool_emb = gather_rows(segs, gidx)
                loc, vals = finalize_candidates(
                    pool_emb, np.arange(gidx.size, dtype=np.int64),
                    vals, k, plan)
                chunk_ids = gather_ids(segs, gidx[loc])
                self._finish(
                    req,
                    [(int(i), float(v)) for i, v in zip(chunk_ids, vals)],
                )
            except Exception as e:
                self._fail(req, e)
