"""Async continuous-batching serving engine (request micro-batching + pipelining).

The paper serves one agent query at a time (desktop MCP).  At fleet scale,
queries are MICRO-BATCHED so the corpus matrix is streamed once per batch
(pem_score's (d, B) query panel) — the arithmetic-intensity argument in
DESIGN.md §2.1 — and successive batches are PIPELINED: the Phase-2 path
splits into a device pass (``score_select_segments``: per-segment fused
score->select under the store lock) and a host tail
(``finalize_segment_candidates``: gather + MMR + id resolution over the
immutable segment snapshot, no lock needed), and the scheduler overlaps
the host tail of batch *i* with the device pass of batch *i+1* instead of
serializing behind it (Vextra's middleware argument: admission decoupled
from backend execution; Bruch frames re-ranking as a separable stage).

The core is an **asyncio event loop** on a private thread:

* **admission** — ``search`` (sync facade, thread-safe from any thread)
  and ``asearch`` (awaitable from any event loop) enqueue a
  :class:`Request`.  The queue is BOUNDED: past ``max_queue`` in-flight
  requests, admission rejects immediately with :class:`QueueFullError`
  (backpressure beats unbounded latency).  Parsing/validation happens AT
  admission, on the caller's thread: a bad request (grammar error, decay
  without timestamps) fails fast without ever consuming a queue slot,
  parse work spreads across client threads instead of serializing on the
  device stage, and the device pass stays dominated by the GIL-releasing
  matmul — which is what makes the stage overlap real parallelism.
* **collect** — the scheduler lingers after the first arrival (up to
  ``max_batch``), then drops requests whose deadline already passed
  (:class:`DeadlineExceededError`, counted in ``deadline_misses``) and
  serves the rest highest-``priority``-first (FIFO within a priority).
  With ``adaptive_window`` (default) the linger is a QUIESCENCE GAP
  learned online — an EWMA of inter-arrival deltas, clamped to
  [0.05 ms, 4·``max_wait_ms``] with a hard cap at 8·``max_wait_ms`` —
  so bursty closed-loop load keeps folding into one cohort while a lone
  request closes its window as soon as arrivals quiesce, instead of the
  fixed ``max_wait_ms`` fragmenting cohorts (``adaptive_window=False``
  restores the fixed window exactly).
* **pipeline** — one device pass and one host tail may be in flight at
  once (two single-thread executors); ``overlapped_batches`` counts
  batches whose device pass ran while the previous tail was still
  finishing.  With ``async_dispatch`` (default) the dispatch is REAL
  async: the scheduler submits the device pass as a future and returns
  to admission immediately — the loop thread is free DURING the pass,
  so the next cohort keeps forming while the device crunches (the
  admission window stays open until the device frees;
  ``overlapped_collects`` counts windows held open that way) and a
  completion task chains device future → host tail in batch order.
  ``async_dispatch=False`` keeps the await-in-dispatch pipeline step.
  ``pipeline=False`` reproduces the PRE-ASYNC synchronous core
  faithfully — parsing serialized inside the serve loop (not at
  admission) and the host tail serialized behind the device pass, the
  old one-thread strict collect→score→finalize phasing — kept as the
  benchmark comparator (`serve_throughput`) and conservative fallback.
* **idle gaps** — between batches the scheduler runs store maintenance:
  a :class:`~repro.core.segments.CompactionPolicy`, when configured,
  folds sparse/fragmented segments.  Compaction shares the device
  executor AND the store lock with the scoring pass, so it can never
  land inside one.

Latency accounting uses ``time.monotonic()`` end to end, so an NTP step
can't produce negative or inflated latencies.  ``close()`` drains the
queue: every request not yet served fails with :class:`EngineClosedError`
instead of hanging into its timeout.

Phase-1 filtered queries are first-class batch citizens: ``search`` /
``asearch`` take ``candidate_ids`` and the device stage groups requests
by canonical candidate set — unfiltered requests share one segment pass,
each distinct filter shares one :func:`score_select_prefiltered` call
(the cache's selectivity router picks masked-device vs gather-host), and
every group produces the same ``(global_rows, scores)`` contract, so the
host tail and the pipeline overlap are untouched.

Live corpora: :meth:`ingest` and :meth:`delete` append/tombstone chunks
between batches (the store lock spans one device pass, so a mutation
never lands inside a batch).  Failure isolation is per request: a bad
request (grammar error, decay without timestamps) fails ONLY that
request; a backend failure fails its batch loudly.  Scoring routes
through the shared :mod:`repro.core.backends` dispatch — the same device
pass + host tail as the direct ``VectorCache`` engine, so batched and
direct rankings are bit-identical.
"""

from __future__ import annotations

import asyncio
import concurrent.futures as cf
import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import (ExecutionBackend,
                                 finalize_fusion,
                                 finalize_segment_candidates,
                                 fusion_bias_arrays, get_backend,
                                 score_select_filter_panel,
                                 score_select_prefiltered,
                                 score_select_segments)
from repro.core import modulations as M
from repro.core.grammar import parse
from repro.core.segments import CompactionPolicy
from repro.core.vectorcache import VectorCache

__all__ = [
    "BatchedRetrievalEngine",
    "Request",
    "EngineClosedError",
    "QueueFullError",
    "DeadlineExceededError",
]

_IDLE_TICK_S = 0.05  # scheduler wake period when the queue is empty


class EngineClosedError(RuntimeError):
    """The engine was closed; the request was drained, not served."""


class QueueFullError(RuntimeError):
    """Admission rejected: the bounded queue is at capacity (backpressure)."""


class DeadlineExceededError(TimeoutError):
    """The request's deadline passed before a batch could serve it."""


_seq = itertools.count()


@dataclasses.dataclass
class Request:
    tokens: str
    k: Optional[int] = 10              # None = the parsed plan's pool size
    priority: int = 0                  # higher serves sooner at collect time
    deadline_ms: Optional[float] = None  # relative to enqueue; None = never
    # Phase-1 pre-filter output; canonicalized (unique, sorted) at
    # construction on the CALLER's thread so identical filters from
    # different clients group into one scoring call at the device stage
    candidate_ids: Optional[np.ndarray] = None
    # monotonic clock: NTP steps can't produce negative/inflated latencies
    enqueued_at: float = dataclasses.field(default_factory=time.monotonic)
    latency_ms: float = 0.0
    plan: Optional[Any] = None         # parsed at admission (see _submit)
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    future: "cf.Future[List[Tuple[int, float]]]" = dataclasses.field(
        default_factory=cf.Future)

    def __post_init__(self) -> None:
        if self.candidate_ids is None:
            self._filter_key = None
        else:
            arr = (self.candidate_ids
                   if isinstance(self.candidate_ids, np.ndarray)
                   else np.asarray(list(self.candidate_ids), dtype=np.int64))
            self.candidate_ids = np.unique(arr.astype(np.int64, copy=False))
            self._filter_key = self.candidate_ids.tobytes()

    @property
    def filter_key(self) -> Optional[bytes]:
        """Batch-grouping key: requests with the same canonical candidate
        set share one filtered scoring call (None = unfiltered); computed
        once at admission, not per batch."""
        return self._filter_key

    def apply_plan_filter(self) -> None:
        """``fuse:filter`` plans promote their lexical FTS hit set to the
        Phase-1 candidate set (intersecting any SQL pre-filter) — called
        once the plan is known, so the device stage groups sharp-keyword
        hybrids by hit set and routes them through the selectivity-aware
        prefilter exactly like SQL-filtered requests."""
        if self.plan is None:
            return
        cand = M.filter_candidate_ids(self.plan, self.candidate_ids)
        if cand is not self.candidate_ids:
            self.candidate_ids = np.unique(
                np.asarray(cand, dtype=np.int64))
            self._filter_key = self.candidate_ids.tobytes()

    def expired(self, now_monotonic: float) -> bool:
        if self.deadline_ms is None:
            return False
        return (now_monotonic - self.enqueued_at) * 1e3 > self.deadline_ms


@dataclasses.dataclass
class _TailWork:
    """One batch's hand-off from the device pass to the host tail."""

    requests: List[Request]
    plans: List[Any]
    segments: Tuple  # immutable snapshot; safe to read without the lock
    ks: List[int]
    selected: List[Tuple[np.ndarray, np.ndarray]]
    mmr_done: bool = False  # device pass already finished diversity on device
    # shard-group fan-out: ``selected`` holds FINAL per-request result
    # lists (ids resolved, diversity and rrf done at the coordinator);
    # the tail only truncates to each request's k and delivers
    final: bool = False


class BatchedRetrievalEngine:
    """Continuous-batching retrieval engine with a sync facade.

    ``search()`` keeps the original thread-safe blocking contract (the
    materializer path and every existing caller work unchanged);
    ``asearch()`` is the awaitable entry point for async servers.
    """

    def __init__(
        self,
        cache: VectorCache,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "fused",
        *,
        max_queue: int = 256,
        pipeline: bool = True,
        async_dispatch: bool = True,
        adaptive_window: bool = True,
        compaction: Optional[CompactionPolicy] = None,
        shard_group: Optional[Any] = None,
        vectorizer: Optional[Any] = None,
    ):
        self.cache = cache
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.now = now
        self.backend = get_backend(engine)
        self.max_queue = max_queue
        self.pipeline = pipeline
        # real async dispatch rides the pipeline split; the sync-core
        # comparator keeps its strict one-thread phasing
        self.async_dispatch = bool(async_dispatch and pipeline)
        self.adaptive_window = adaptive_window
        self.compaction = compaction
        # cross-process shard router (repro.dist.procgroup.ProcessGroup):
        # when attached, the device stage fans each collected batch out to
        # one replica per shard and merges with the exact-union contract
        # instead of scoring the local cache; admission, batching,
        # priorities and the pipeline overlap are unchanged
        self.shard_group = shard_group
        # background ingest vectorizer (repro.serve.vectorizer.
        # VectorizerWorker): when attached, the materializer enqueues
        # missing-embedding INSERT rows here and the idle-gap hook (next
        # to compaction) drains them in batches through the embedder
        self.vectorizer = vectorizer

        # counters (single-writer or benign int bumps, same as the store's)
        self.batches_served = 0
        self.requests_served = 0
        self.rejected = 0            # admissions refused at capacity
        self.shed_low_priority = 0   # queued requests evicted for a
        #                              higher-priority newcomer at capacity
        self.deadline_misses = 0     # requests expired at collect time
        self.overlapped_batches = 0  # device pass ran while prev tail ran
        self.overlapped_collects = 0  # admission windows held open on a
        #                               busy device (async dispatch)
        self.windows_extended = 0    # adaptive windows that outlingered base
        self.compactions_run = 0     # idle-gap compactions that folded
        self.vectorizer_drains = 0   # idle-gap vectorizer batches ingested

        self._depth = 0              # queued, not yet collected into a batch
        self._queued: Dict[int, Request] = {}  # seq -> queued request, the
        #                              shedding candidate set (admission lock)
        self._admission_lock = threading.Lock()
        self._closed = False         # no new admissions (set by close())
        self._closing = False        # loop-confined shutdown flag
        self._done = threading.Event()

        self._pending: List[Request] = []       # loop-confined
        self._arrival = asyncio.Event()         # loop-confined
        self._tail_fut: Optional[asyncio.Future] = None
        # async-dispatch state (loop-confined except _tail_running, which
        # the tail thread clears when its host tail actually finishes)
        self._dev_fut: Optional[asyncio.Future] = None
        self._finish_task: Optional[asyncio.Task] = None
        self._tail_running = False
        # adaptive window state: EWMA of inter-arrival gaps (ms); None
        # until the first delta lands, so the static base stays in force
        self._gap_ms: Optional[float] = None
        self._last_arrival_t: Optional[float] = None

        # one thread per pipeline stage: the device pass and the host tail
        # each get a dedicated executor, so exactly one of each runs at a
        # time and the two stages genuinely overlap
        self._dev_pool = cf.ThreadPoolExecutor(
            1, thread_name_prefix="flexvec-device")
        self._tail_pool = cf.ThreadPoolExecutor(
            1, thread_name_prefix="flexvec-tail")

        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="flexvec-scheduler",
            daemon=True)
        self._thread.start()
        self._scheduler_fut = asyncio.run_coroutine_threadsafe(
            self._scheduler(), self._loop)

    # -- public API ----------------------------------------------------------

    def search(
        self,
        tokens: str,
        k: Optional[int] = 10,
        timeout: float = 30.0,
        *,
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        candidate_ids: Optional[Sequence[int]] = None,
        plan: Optional[Any] = None,
    ) -> List[Tuple[int, float]]:
        """Blocking search (thread-safe).  Raises :class:`QueueFullError`
        at capacity, :class:`DeadlineExceededError` past ``deadline_ms``,
        :class:`EngineClosedError` after :meth:`close`.

        ``candidate_ids`` is the Phase-1 pre-filter output (None = full
        corpus); filtered requests batch and pipeline like everything
        else, routed masked-device vs gather-host by the cache's
        selectivity router.  ``k=None`` serves the plan's full pool.
        ``plan`` hands over an already-parsed ModulationPlan for
        ``tokens`` — admission skips re-parsing (the materializer uses
        this so SQL-surface queries don't pay the parse+embed twice)."""
        req = Request(tokens=tokens, k=k, priority=priority,
                      deadline_ms=deadline_ms, candidate_ids=candidate_ids,
                      plan=plan)
        self._submit(req)
        try:
            return req.future.result(timeout)
        except DeadlineExceededError:
            raise
        except cf.TimeoutError:
            raise TimeoutError("retrieval request timed out") from None

    async def asearch(
        self,
        tokens: str,
        k: Optional[int] = 10,
        *,
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        candidate_ids: Optional[Sequence[int]] = None,
        plan: Optional[Any] = None,
    ) -> List[Tuple[int, float]]:
        """Awaitable search: usable from ANY event loop (the engine runs
        its own private loop; results cross via the request future)."""
        req = Request(tokens=tokens, k=k, priority=priority,
                      deadline_ms=deadline_ms, candidate_ids=candidate_ids,
                      plan=plan)
        self._submit(req)
        return await asyncio.wrap_future(req.future)

    def close(self) -> None:
        """Stop the scheduler and DRAIN the queue: every request not yet
        served fails with :class:`EngineClosedError` immediately — nothing
        hangs into its timeout.  Pending ingest is NOT dropped: the
        vectorizer queue is flushed (every accepted row either embeds or
        dead-letters within its retry budget) before the executors stop."""
        with self._admission_lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._loop.call_soon_threadsafe(self._signal_close)
        except RuntimeError:  # loop already stopped
            pass
        self._done.wait(timeout=30.0)
        self._thread.join(timeout=2.0)
        if self.vectorizer is not None:
            # the scheduler has stopped (no concurrent idle-gap drain);
            # flush on the closing thread so accepted INSERTs land
            self.vectorizer.flush()
        if not self._thread.is_alive():
            # closing the loop makes a racing _submit's
            # call_soon_threadsafe raise (-> EngineClosedError) instead
            # of silently enqueueing onto a dead loop, and releases the
            # loop's fds
            self._loop.close()
        self._dev_pool.shutdown(wait=False)
        self._tail_pool.shutdown(wait=False)

    def ingest(
        self,
        ids: Sequence[int],
        matrix: np.ndarray,
        timestamps: Optional[Sequence[float]] = None,
        *,
        normalized: bool = False,
    ):
        """Append chunks as one sealed segment; lands between batches
        (the store lock spans one device pass). Returns the new segment.
        An attached shard group mirrors the append (each shard normalizes
        its slice row-wise, so replicas match the cache bit for bit)."""
        seg = self.cache.ingest(ids, matrix, timestamps,
                                normalized=normalized)
        if self.shard_group is not None:
            self.shard_group.append(ids, matrix, timestamps,
                                    normalized=normalized)
        return seg

    def delete(self, ids: Sequence[int], *, strict: bool = False) -> int:
        """Tombstone chunks between batches; returns rows tombstoned.
        Rows still waiting in the ingest queue are discarded too — a
        DELETE racing a not-yet-embedded INSERT must not resurrect it."""
        removed = self.cache.delete(ids, strict=strict)
        if self.vectorizer is not None:
            self.vectorizer.queue.discard(ids)
        if self.shard_group is not None:
            self.shard_group.delete(ids)
        return removed

    def enqueue_ingest(self, rows: Sequence[Tuple[int, str,
                                                  Optional[float]]]) -> int:
        """Admit ``(chunk_id, content, timestamp)`` rows to the background
        vectorizer (the materializer's INSERT path when embeddings are
        missing).  Raises :class:`~repro.serve.vectorizer.
        IngestQueueFullError` at capacity — ingest backpressure surfaces
        to the SQL caller like admission backpressure does to search."""
        if self.vectorizer is None:
            raise RuntimeError(
                "enqueue_ingest: engine has no vectorizer attached")
        return self.vectorizer.enqueue(rows)

    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet collected into a batch."""
        with self._admission_lock:
            return self._depth

    def stats(self) -> Dict[str, int]:
        """Serving counters (surfaced via ``RetrievalService.stats()``)."""
        return {
            "queue_depth": self.queue_depth,
            "max_queue": self.max_queue,
            "batches_served": self.batches_served,
            "requests_served": self.requests_served,
            "rejected": self.rejected,
            "shed_low_priority": self.shed_low_priority,
            "deadline_misses": self.deadline_misses,
            "overlapped_batches": self.overlapped_batches,
            "overlapped_collects": self.overlapped_collects,
            "windows_extended": self.windows_extended,
            "window_ms": round(self._window_s() * 1e3, 3),
            "async_dispatch": self.async_dispatch,
            "adaptive_window": self.adaptive_window,
            "compactions_run": self.compactions_run,
            "vectorizer_drains": self.vectorizer_drains,
        }

    # -- admission -----------------------------------------------------------

    def _submit(self, req: Request) -> None:
        with self._admission_lock:
            if self._closed:
                raise EngineClosedError("engine is closed")
            if self._depth >= self.max_queue:
                # priority-aware shedding: at capacity, evict the lowest-
                # priority queued request (newest arrival among ties) and
                # hand its slot to the newcomer; the newcomer is rejected
                # only if it is itself lowest.  Selection, eviction and
                # the victim's failure all happen under the admission
                # lock, so collect (which pops under the same lock) can
                # never serve an evicted request.
                victim: Optional[Request] = None
                if self._queued:
                    low = min(self._queued.values(),
                              key=lambda r: (r.priority, -r.seq))
                    if low.priority < req.priority:
                        victim = low
                if victim is None:
                    self.rejected += 1
                    raise QueueFullError(
                        f"admission queue at capacity ({self.max_queue}); "
                        f"retry with backoff")
                del self._queued[victim.seq]
                self.shed_low_priority += 1
                self._fail(victim, QueueFullError(
                    f"shed at capacity for a priority-{req.priority} "
                    f"request (this request was priority {victim.priority})"),
                    count_depth=False)  # its slot transfers to the newcomer
            else:
                self._depth += 1  # slot reserved before the (costly) parse
            self._queued[req.seq] = req
        try:
            if req.plan is not None:
                # pre-parsed plan handed over (materializer path): skip
                # the duplicate parse+embed, but still validate at
                # admission so a bad request fails fast in BOTH modes
                self._validate(req.plan)
            elif self.pipeline:
                # parse + validate on the CALLER's thread: bad requests
                # fail fast (no queue slot held), parse work spreads
                # across client threads instead of serializing on the
                # device stage, which stays matmul-dominated.  The sync-
                # core comparator keeps the legacy behavior (parse inside
                # the serve loop, errors delivered via the future).
                req.plan = self._parse(req)
            req.apply_plan_filter()
        except Exception:
            self._release_slot(req)
            raise
        try:
            self._loop.call_soon_threadsafe(self._admit, req)
        except RuntimeError:  # loop closed between the check and the call
            self._release_slot(req)
            raise EngineClosedError("engine is closed") from None

    def _release_slot(self, req: Request) -> None:
        """Free one admission slot and drop the request from the shedding
        candidate set (no-op on the latter if collect already took it)."""
        with self._admission_lock:
            self._depth -= 1
            self._queued.pop(req.seq, None)

    def _parse(self, req: Request):
        plan = parse(req.tokens, self.cache.embed_fn,
                     self.cache.embeddings_for_ids,
                     self.cache.lexical_fn)
        self._validate(plan)
        return plan

    def _validate(self, plan) -> None:
        if plan.decay is not None and not self.cache.store.has_timestamps:
            raise ValueError("decay: requires timestamps in the cache")

    def _window_s(self) -> float:
        """Current admission-window linger in seconds: the static base, or
        the learned quiescence gap clamped to [0.05 ms, 4·base]."""
        if not self.adaptive_window or self._gap_ms is None:
            return self.max_wait_ms / 1e3
        return min(max(self._gap_ms, 0.05), self.max_wait_ms * 4) / 1e3

    def _admit(self, req: Request) -> None:  # loop thread
        if self._closing:
            self._fail(req, EngineClosedError(
                "engine closed before the request was served"))
            return
        t = self._loop.time()
        last = self._last_arrival_t
        self._last_arrival_t = t
        if self.adaptive_window and last is not None:
            delta_ms = (t - last) * 1e3
            # a gap past the hard cap is a NEW burst, not a cadence
            # sample — folding it in would freeze the window wide open
            if delta_ms <= self.max_wait_ms * 8:
                g = self._gap_ms
                self._gap_ms = (delta_ms if g is None
                                else g + 0.2 * (delta_ms - g))
        self._pending.append(req)
        self._arrival.set()

    def _signal_close(self) -> None:  # loop thread
        self._closing = True
        self._arrival.set()

    # -- scheduler (loop thread) ---------------------------------------------

    async def _scheduler(self) -> None:
        try:
            while not self._closing:
                batch = await self._collect()
                if self._closing:
                    # already depth-decremented at collect; fail in place
                    for req in batch:
                        self._fail(req, EngineClosedError(
                            "engine closed before the request was served"),
                            count_depth=False)
                    break
                if not batch:
                    await self._idle_maintenance()
                    continue
                await self._dispatch(batch)
        finally:
            pending, self._pending = self._pending, []
            for req in pending:
                if req.future.done():
                    continue  # shed at admission; slot already transferred
                self._fail(req, EngineClosedError(
                    "engine closed before the request was served"))
            if self._finish_task is not None:
                # async dispatch: the completion chain delivers the last
                # in-flight batch (device future -> host tail) — drain it
                try:
                    await self._finish_task
                except Exception:
                    pass
            if self._tail_fut is not None:
                try:
                    await self._tail_fut
                except Exception:
                    pass
            self._done.set()
            self._loop.call_soon(self._loop.stop)

    async def _collect(self) -> List[Request]:
        """One admission window: first arrival, then linger (fixed
        ``max_wait_ms``, or the learned quiescence gap per arrival when
        ``adaptive_window`` — close as soon as arrivals quiesce, hard cap
        8·base); under async dispatch a busy device HOLDS the window open
        (arrivals keep folding into this cohort — queuing a micro-batch
        behind the pass would only fragment it); expire deadlines; pick
        the highest-priority ``max_batch`` (FIFO within a priority)."""
        if not self._pending:
            self._arrival.clear()
            try:
                await asyncio.wait_for(self._arrival.wait(), _IDLE_TICK_S)
            except asyncio.TimeoutError:
                return []
        if self._closing:
            return []
        start = self._loop.time()
        base_s = self.max_wait_ms / 1e3
        deadline = start + base_s
        hard_deadline = start + base_s * 8
        while len(self._pending) < self.max_batch:
            now_t = self._loop.time()
            if self.adaptive_window:
                # each arrival re-arms a quiescence gap: the window stays
                # open while the burst keeps delivering, closes one gap
                # after it stops
                deadline = min(now_t + self._window_s(), hard_deadline)
            remaining = deadline - now_t
            if remaining <= 0:
                break
            self._arrival.clear()
            try:
                await asyncio.wait_for(self._arrival.wait(), remaining)
            except asyncio.TimeoutError:
                break
            if self._closing:
                return []
        if self.adaptive_window and self._loop.time() - start > base_s:
            self.windows_extended += 1

        if self.async_dispatch:
            dev = self._dev_fut
            if dev is not None and not dev.done():
                if self._pending:
                    self.overlapped_collects += 1
                try:
                    await dev  # arrivals keep appending while we wait
                except Exception:
                    pass  # the completion chain fails that batch

        now_mono = time.monotonic()
        live: List[Request] = []
        expired: List[Request] = []
        with self._admission_lock:
            # partition under the admission lock: a request shed by a
            # concurrent _submit has a done future (set under this same
            # lock) and is dropped here without touching its slot — that
            # slot now belongs to the newcomer that evicted it
            for req in self._pending:
                if req.future.done():
                    continue
                (expired if req.expired(now_mono) else live).append(req)
            live.sort(key=lambda r: (-r.priority, r.seq))
            batch, rest = live[:self.max_batch], live[self.max_batch:]
            self._depth -= len(batch) + len(expired)
            for req in batch:
                self._queued.pop(req.seq, None)
            for req in expired:
                self._queued.pop(req.seq, None)
        self._pending = rest
        for req in expired:
            self.deadline_misses += 1
            self._fail(req, DeadlineExceededError(
                f"deadline of {req.deadline_ms:.1f} ms passed before the "
                f"request reached a batch"), count_depth=False)
        return batch

    async def _idle_maintenance(self) -> None:
        """Store maintenance in the scheduler's idle gaps.  Both the
        ingest vectorizer drain and compaction run on the DEVICE executor
        and take the store lock, so neither can land inside a scoring
        pass — and never even queues behind one mid-batch, because the
        executor is busy exactly then."""
        if self._dev_fut is not None and not self._dev_fut.done():
            # async dispatch: a pass is in flight on the device executor —
            # don't queue maintenance behind it, the next idle gap will do
            return
        vec = self.vectorizer
        if vec is not None and vec.has_due():
            ingested = await self._loop.run_in_executor(
                self._dev_pool, vec.drain_once)
            if ingested:
                self.vectorizer_drains += 1
        policy = self.compaction
        if policy is None:
            return
        store = self.cache.store
        if not policy.should_compact(store):
            return
        folded = await self._loop.run_in_executor(
            self._dev_pool, store.maybe_compact, policy)
        if folded:
            self.compactions_run += 1

    async def _dispatch(self, batch: List[Request]) -> None:
        """Two-stage pipeline step: run this batch's device pass while the
        PREVIOUS batch's host tail is (possibly) still finishing.

        Async mode submits the device pass as a FUTURE and returns to the
        scheduler immediately — the loop thread is free during the pass
        (admission keeps forming the next cohort) and a completion task
        chains device future → host tail, tails strictly in batch order,
        at most one tail outstanding."""
        if self.async_dispatch:
            prev_finish = self._finish_task
            dev_fut = self._loop.run_in_executor(
                self._dev_pool, self._device_stage_async, batch)
            self._dev_fut = dev_fut
            self._finish_task = self._loop.create_task(
                self._finish_batch(batch, dev_fut, prev_finish))
            return
        prev_tail = self._tail_fut
        overlapped = prev_tail is not None and not prev_tail.done()
        try:
            work = await self._loop.run_in_executor(
                self._dev_pool, self._device_stage, batch)
        except Exception as e:  # defensive: _device_stage fails per request
            for req in batch:
                if not req.future.done():
                    self._fail(req, e, count_depth=False)
            return
        if overlapped:
            self.overlapped_batches += 1
        if prev_tail is not None:
            # bound the pipeline at ONE outstanding tail (keeps memory and
            # result latency bounded if tails ever run slower than passes)
            try:
                await prev_tail
            except Exception:
                pass
            self._tail_fut = None
        if work is None:
            return
        self._tail_fut = self._loop.run_in_executor(
            self._tail_pool, self._host_tail, work)
        if not self.pipeline:
            # synchronous-core comparator: serialize tail behind the pass
            try:
                await self._tail_fut
            except Exception:
                pass
            self._tail_fut = None

    async def _finish_batch(self, batch: List[Request],
                            dev_fut: asyncio.Future,
                            prev_finish: Optional[asyncio.Task]) -> None:
        """Async-dispatch completion chain: await this batch's device
        future, then the previous batch's chain (tails launch strictly in
        batch order), then the previous tail itself (at most ONE tail
        outstanding, same bound as the legacy step), then hand off to the
        host tail executor."""
        try:
            work = await dev_fut
        except Exception as e:  # defensive: _device_stage fails per request
            if prev_finish is not None:
                try:
                    await prev_finish
                except Exception:
                    pass
            for req in batch:
                if not req.future.done():
                    self._fail(req, e, count_depth=False)
            return
        if prev_finish is not None:
            try:
                await prev_finish
            except Exception:
                pass
        prev_tail = self._tail_fut
        if prev_tail is not None:
            try:
                await prev_tail
            except Exception:
                pass
            self._tail_fut = None
        if work is None:
            return
        # flag raised on the LOOP thread before the submit, cleared by the
        # tail thread when the tail truly finishes: the next device stage
        # reads it at ITS start, so the overlap counter measures real
        # device-pass/host-tail concurrency, not dispatch bookkeeping
        self._tail_running = True
        self._tail_fut = self._loop.run_in_executor(
            self._tail_pool, self._run_tail, work)

    # -- pipeline stages (executor threads) ----------------------------------

    def _device_stage_async(self, batch: List[Request]) -> Optional[_TailWork]:
        if self._tail_running:
            self.overlapped_batches += 1
        return self._device_stage(batch)

    def _run_tail(self, work: _TailWork) -> None:
        try:
            self._host_tail(work)
        finally:
            self._tail_running = False

    def _device_stage(self, batch: List[Request]) -> Optional[_TailWork]:
        """One fused backend pass: fold every request's (admission-parsed)
        plan into the (d, B) panels and run the segment-aware
        ``score_select_segments`` — every segment is scored ONCE for the
        whole batch (tombstones masked on device) and only per-request
        candidate lists come back (the (N, B) panel never leaves the
        backend).  This stage is matmul-dominated (parse happened at
        admission), so it releases the GIL while the previous batch's
        host tail finishes — that is the pipeline's overlap.  In
        sync-core mode requests arrive unparsed and parse HERE,
        serially, exactly like the legacy one-thread engine."""
        store = self.cache.store
        live: List[Request] = []
        plans: List[Any] = []
        for req in batch:
            if req.plan is None:  # sync-core comparator: parse in-loop
                try:
                    req.plan = self._parse(req)
                    req.apply_plan_filter()
                except Exception as e:  # bad request: fail it, keep the batch
                    self._fail(req, e, count_depth=False)
                    continue
            live.append(req)
            plans.append(req.plan)

        self.batches_served += 1
        if not live:
            return None

        ref = self.now if self.now is not None else time.time()
        if self.shard_group is not None:
            # shard-router fan-out: the whole collected batch goes to one
            # replica per shard as ONE plan cohort (heterogeneous filters
            # ride each shard's mask panel) and comes back merged + final
            # — the host tail only truncates to each request's k
            try:
                n_live = self.shard_group.n_live
                ks = []
                for req in live:
                    k_req = req.k if req.k is not None else req.plan.pool
                    f = req.plan.fusion
                    if f is not None and f.mode == "rrf":
                        k_req = max(k_req, req.plan.pool)
                    ks.append(min(k_req, n_live))
                results = self.shard_group.search_plan_batch(
                    plans, [req.candidate_ids for req in live],
                    now=ref, ks=ks)
            except Exception as e:  # group failure: fail the batch loudly
                for req in live:
                    self._fail(req, e, count_depth=False)
                return None
            return _TailWork(live, plans, (), ks, results,
                             mmr_done=True, final=True)
        try:
            # the lock spans snapshot + scoring: ingest/delete/compaction
            # land BETWEEN batches, never inside one
            with store.lock:
                segs = store.segments
                n_live = store.n_live
                ks = []
                for req in live:
                    k_req = req.k if req.k is not None else req.plan.pool
                    f = req.plan.fusion
                    if f is not None and f.mode == "rrf":
                        # rrf fuses on host over the POOL-width vector
                        # ranking (parity with the direct path); the tail
                        # truncates back to the request's k afterwards
                        k_req = max(k_req, req.plan.pool)
                    ks.append(min(k_req, n_live))
                # group by Phase-1 filter: unfiltered requests share one
                # segment pass; each distinct candidate set shares one
                # routed (masked-device / gather-host) pass — identical
                # filters from different clients fold into one call
                groups: "OrderedDict[Optional[bytes], List[int]]"
                groups = OrderedDict()
                for j, req in enumerate(live):
                    groups.setdefault(req.filter_key, []).append(j)
                router = self.cache.prefilter
                counters = self.cache.fused
                selected: List = [None] * len(live)
                counts = [None if key is None
                          else int(live[idxs[0]].candidate_ids.size)
                          for key, idxs in groups.items()]
                if router.use_panel(counts, n_live):
                    # heterogeneous-filter cohort: ONE batched (N, B)
                    # mask-panel pass for the whole batch instead of one
                    # pass per distinct filter — unfiltered requests ride
                    # along as all-live columns, so the cohort never
                    # splits (see score_select_filter_panel)
                    selected = score_select_filter_panel(
                        self.backend, store, segs, plans, ks,
                        [req.candidate_ids for req in live], now=ref,
                        router=router, counters=counters,
                        score_bias=fusion_bias_arrays(store, segs, plans))
                else:
                    for key, idxs in groups.items():
                        g_plans = [plans[j] for j in idxs]
                        g_ks = [ks[j] for j in idxs]
                        # hybrid requests ride the batch as a sparse
                        # additive score panel (None when the group has
                        # no weighted-fusion plans — the common case)
                        g_bias = fusion_bias_arrays(store, segs, g_plans)
                        if key is None:
                            # the batch IS a cohort: one fused (d, 2·Q)
                            # panel per segment pass, pow2 Q-bucketed on
                            # device backends so varying cohort sizes
                            # share executables
                            sel = score_select_segments(
                                self.backend, segs, g_plans, g_ks, now=ref,
                                counters=counters, score_bias=g_bias,
                                cohort=True)
                        else:
                            sel = score_select_prefiltered(
                                self.backend, store, segs, g_plans, g_ks,
                                live[idxs[0]].candidate_ids, now=ref,
                                router=router, weight=len(idxs),
                                counters=counters, score_bias=g_bias)
                        for j, s in zip(idxs, sel):
                            selected[j] = s
        except Exception as e:  # backend failure: fail the whole batch loudly
            for req in live:
                self._fail(req, e, count_depth=False)
            return None
        return _TailWork(live, plans, segs, ks, selected,
                         mmr_done=self.backend.device_mmr)

    def _host_tail(self, work: _TailWork) -> None:
        """Finish each request over the immutable segment snapshot (no
        lock): gather the candidate pool, truncate/MMR, resolve ids —
        exactly :func:`finalize_segment_candidates`, the same host tail
        the direct path runs, called per request so one bad finish fails
        only its request.

        Results are computed for the WHOLE batch first and delivered in
        one burst at the end: each delivery wakes a (possibly closed-loop)
        client whose next admission parse grabs the GIL, so delivering
        mid-loop would let those parses convoy against the remaining MMR
        work.  Delivered at the end, the wake-up storm lands during the
        next batch's GIL-releasing device pass instead."""
        if work.final:
            # shard-group results arrive final (diversity + fusion done at
            # the coordinator, pool-width like the direct path): hand back k
            for req, res in zip(work.requests, work.selected):
                self._finish(req, res if req.k is None else res[:req.k])
            return
        done: List[Tuple[Request, Optional[List[Tuple[int, float]]],
                         Optional[Exception]]] = []
        for req, plan, k, sel in zip(work.requests, work.plans, work.ks,
                                     work.selected):
            try:
                (results,) = finalize_segment_candidates(
                    work.segments, [plan], [k], [sel],
                    mmr_done=work.mmr_done, counters=self.cache.fused)
                # fuse:rrf finishes on host (rank fusion is not a linear
                # bias); weighted fusion already happened on device
                results = finalize_fusion(
                    plan, results, k, store=self.cache.store,
                    candidate_ids=req.candidate_ids)
                if req.k is not None:
                    # rrf requests score at pool width; hand back k
                    results = results[:req.k]
                done.append((req, results, None))
            except Exception as e:
                done.append((req, None, e))
        for req, results, err in done:
            if err is not None:
                self._fail(req, err, count_depth=False)
            else:
                self._finish(req, results)

    # -- completion ----------------------------------------------------------

    def _fail(self, req: Request, err: Exception, *,
              count_depth: bool = True) -> None:
        req.latency_ms = (time.monotonic() - req.enqueued_at) * 1e3
        if count_depth:
            self._release_slot(req)
        try:
            req.future.set_exception(err)
        except cf.InvalidStateError:  # pragma: no cover - already completed
            pass

    def _finish(self, req: Request, result: List[Tuple[int, float]]) -> None:
        req.latency_ms = (time.monotonic() - req.enqueued_at) * 1e3
        self.requests_served += 1
        try:
            req.future.set_result(result)
        except cf.InvalidStateError:  # pragma: no cover - already completed
            pass
