"""Batched serving engine: request micro-batching over the PEM kernel.

The paper serves one agent query at a time (desktop MCP). At fleet scale,
queries are MICRO-BATCHED so the corpus matrix is streamed once per batch
(pem_score's (d, B) query panel): the scoring cost is amortized B ways —
the arithmetic-intensity argument in DESIGN.md §2.1.

The engine is synchronous-core with a thread-safe front door: requests
accumulate until `max_batch` or `max_wait_ms`, then one fused scoring pass
answers all of them.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import modulations as M
from repro.core.grammar import parse
from repro.core.vectorcache import VectorCache
from repro.kernels.pem_score.ops import fold_plans


@dataclasses.dataclass
class Request:
    tokens: str
    k: int = 10
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    _result: Optional[List[Tuple[int, float]]] = None
    enqueued_at: float = dataclasses.field(default_factory=time.time)
    latency_ms: float = 0.0


class BatchedRetrievalEngine:
    def __init__(
        self,
        cache: VectorCache,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        now: Optional[float] = None,
    ):
        self.cache = cache
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.now = now
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self.batches_served = 0
        self.requests_served = 0
        self._worker.start()

    # -- public API --------------------------------------------------------

    def search(self, tokens: str, k: int = 10, timeout: float = 30.0):
        req = Request(tokens=tokens, k=k)
        self._q.put(req)
        if not req._event.wait(timeout):
            raise TimeoutError("retrieval request timed out")
        return req._result

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=2.0)

    # -- batching core -------------------------------------------------------

    def _collect(self) -> List[Request]:
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.time() + self.max_wait_ms / 1e3
        while len(batch) < self.max_batch:
            remaining = deadline - time.time()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            self._serve(batch)

    def _serve(self, batch: List[Request]) -> None:
        """One fused pass: fold every request's plan into the (d, B) panels,
        score the corpus ONCE, then per-request selection."""
        plans = [
            parse(r.tokens, self.cache.embed_fn, self.cache.embeddings_for_ids)
            for r in batch
        ]
        q_pre, q_sup = fold_plans(plans)                      # (d, B) x 2
        matrix = self.cache.matrix
        # shared decay column per request (half-life may differ per plan)
        ref = self.now if self.now is not None else time.time()
        days = None
        if self.cache.timestamps is not None:
            days = np.maximum((ref - self.cache.timestamps) / 86400.0, 0.0)
        base = matrix @ q_pre                                 # ONE pass (N, B)
        sup = matrix @ q_sup
        for j, (req, plan) in enumerate(zip(batch, plans)):
            col = base[:, j]
            if plan.decay is not None:
                col = col * (1.0 / (1.0 + days / plan.decay.half_life_days))
            col = col + sup[:, j]
            k = min(req.k, col.shape[0])
            if plan.diverse is not None:
                over = min(plan.diverse.oversample * max(k, plan.pool), col.shape[0])
                pool_idx = np.argpartition(-col, over - 1)[:over]
                pool_idx = pool_idx[np.argsort(-col[pool_idx])]
                sel = M.mmr_select_np(matrix[pool_idx], col[pool_idx], k,
                                      plan.diverse.lam)
                top = pool_idx[sel]
            else:
                top = np.argpartition(-col, k - 1)[:k]
                top = top[np.argsort(-col[top])]
            req._result = [(int(self.cache.ids[i]), float(col[i])) for i in top]
            req.latency_ms = (time.time() - req.enqueued_at) * 1e3
            req._event.set()
        self.batches_served += 1
        self.requests_served += len(batch)
