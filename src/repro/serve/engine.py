"""Batched serving engine: request micro-batching over the PEM kernel.

The paper serves one agent query at a time (desktop MCP). At fleet scale,
queries are MICRO-BATCHED so the corpus matrix is streamed once per batch
(pem_score's (d, B) query panel): the scoring cost is amortized B ways —
the arithmetic-intensity argument in DESIGN.md §2.1.

The engine is synchronous-core with a thread-safe front door: requests
accumulate until `max_batch` or `max_wait_ms`, then one backend scoring
pass answers all of them.  Scoring and selection route through the shared
:mod:`repro.core.backends` dispatch — the same code path as the direct
``VectorCache`` engine, so batched and direct rankings are identical.

Failure isolation: a bad request (grammar error, decay without
timestamps) fails ONLY that request — its error re-raises from ``search``
— while the rest of the batch is served normally.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.core.backends import (ExecutionBackend, finalize_candidates,
                                 get_backend)
from repro.core.grammar import parse
from repro.core.vectorcache import VectorCache


@dataclasses.dataclass
class Request:
    tokens: str
    k: int = 10
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    _result: Optional[List[Tuple[int, float]]] = None
    _error: Optional[Exception] = None
    enqueued_at: float = dataclasses.field(default_factory=time.time)
    latency_ms: float = 0.0


class BatchedRetrievalEngine:
    def __init__(
        self,
        cache: VectorCache,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        now: Optional[float] = None,
        engine: Union[str, ExecutionBackend] = "fused",
    ):
        self.cache = cache
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.now = now
        self.backend = get_backend(engine)
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self.batches_served = 0
        self.requests_served = 0
        self._worker.start()

    # -- public API --------------------------------------------------------

    def search(self, tokens: str, k: int = 10, timeout: float = 30.0):
        req = Request(tokens=tokens, k=k)
        self._q.put(req)
        if not req._event.wait(timeout):
            raise TimeoutError("retrieval request timed out")
        if req._error is not None:
            raise req._error
        return req._result

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=2.0)

    # -- batching core -------------------------------------------------------

    def _collect(self) -> List[Request]:
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.time() + self.max_wait_ms / 1e3
        while len(batch) < self.max_batch:
            remaining = deadline - time.time()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            self._serve(batch)

    def _fail(self, req: Request, err: Exception) -> None:
        req._error = err
        req.latency_ms = (time.time() - req.enqueued_at) * 1e3
        req._event.set()

    def _finish(self, req: Request, result: List[Tuple[int, float]]) -> None:
        req._result = result
        req.latency_ms = (time.time() - req.enqueued_at) * 1e3
        req._event.set()
        self.requests_served += 1

    def _serve(self, batch: List[Request]) -> None:
        """One fused backend pass: fold every live request's plan into the
        (d, B) panels and run ``score_select`` — the corpus is scored ONCE
        and only per-request candidate lists come back (device backends
        top-k on device; the (N, B) panel never reaches this thread)."""
        live: List[Request] = []
        plans = []
        for req in batch:
            try:
                plan = parse(req.tokens, self.cache.embed_fn,
                             self.cache.embeddings_for_ids)
                if plan.decay is not None and self.cache.timestamps is None:
                    raise ValueError("decay: requires timestamps in the cache")
            except Exception as e:  # bad request: fail it, keep the batch
                self._fail(req, e)
                continue
            live.append(req)
            plans.append(plan)

        self.batches_served += 1
        if not live:
            return

        matrix = self.cache.matrix
        ref = self.now if self.now is not None else time.time()
        days = None
        if self.cache.timestamps is not None:
            days = np.maximum((ref - self.cache.timestamps) / 86400.0, 0.0)

        n = matrix.shape[0]
        ks = [min(req.k, n) for req in live]
        try:
            # per-plan (indices, scores) candidate lists — (pool,)-sized
            selected = self.backend.score_select(matrix, days, plans, ks)
        except Exception as e:  # backend failure: fail the whole batch loudly
            for req in live:
                self._fail(req, e)
            return

        for req, plan, k, (idx, vals) in zip(live, plans, ks, selected):
            try:
                idx, vals = finalize_candidates(matrix, idx, vals, k, plan)
                self._finish(
                    req,
                    [(int(self.cache.ids[i]), float(v))
                     for i, v in zip(idx, vals)],
                )
            except Exception as e:
                self._fail(req, e)
