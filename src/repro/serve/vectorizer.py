"""Background ingest vectorizer: bounded queue + batching embed worker.

The materializer used to embed missing vectors synchronously inside
``INSERT INTO chunks`` — every insert paid an embedder round-trip, and an
embedder outage failed the write path.  Production vector stores decouple
the two (timescale pgai's vectorizer and p8k8's ``embedding_queue`` both
run trigger -> queue -> batching worker): the INSERT *enqueues* and
returns, and a background worker drains the queue in batches through the
embedder, with retry/backoff on failure.

* :class:`IngestQueue` — a bounded FIFO of :class:`PendingChunk` rows.
  ``put`` raises :class:`IngestQueueFullError` at capacity (backpressure
  surfaces to the SQL caller instead of unbounded memory growth).
* :class:`VectorizerWorker` — drains due rows in batches through an
  ``embed_fn`` and hands ``(ids, vectors, timestamps)`` to a sink
  (``VectorCache.ingest``).  A failed batch retries with exponential
  backoff + deterministic jitter; rows exhausting ``max_attempts`` spill
  to a **dead-letter list** (journaled, visible in ``stats()``, never
  retried again) so one poison row can't wedge the queue.

The worker owns NO thread: the serving scheduler's idle-gap hook (where
compaction already runs) calls :meth:`VectorizerWorker.drain_once`, so
embedding happens between request batches on the same executor that owns
the store lock's device pass.  ``clock`` is injectable — the backoff
schedule is tested against a fake clock, not wall time.

Durability: when the owning store has a journal, accepted rows are
journaled as ``enqueue`` records (and dead letters as ``dead_letter``),
so a crash cannot silently drop an acknowledged INSERT —
``SegmentedCorpusStore.open`` resurfaces them in ``recovered_pending``.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.journal import FaultPlan, StoreJournal

__all__ = [
    "EmbedderError",
    "IngestQueueFullError",
    "PendingChunk",
    "IngestQueue",
    "VectorizerWorker",
]

Row = Tuple[int, str, Optional[float]]


class IngestQueueFullError(RuntimeError):
    """The bounded ingest queue is at capacity (backpressure)."""


class EmbedderError(RuntimeError):
    """Injected/propagated embedder failure (retryable)."""


@dataclasses.dataclass
class PendingChunk:
    """One enqueued row awaiting embedding."""

    chunk_id: int
    content: str
    timestamp: Optional[float]
    attempts: int = 0
    due_at: float = 0.0  # worker-clock time when (re)eligible

    @property
    def row(self) -> Row:
        return (self.chunk_id, self.content, self.timestamp)


class IngestQueue:
    """Bounded, thread-safe FIFO of pending rows.

    Retried rows rejoin at the BACK with a future ``due_at`` (their
    backoff), so fresh rows are not starved behind a failing batch.
    """

    def __init__(self, maxsize: int = 1024) -> None:
        self.maxsize = int(maxsize)
        self._items: List[PendingChunk] = []
        self._lock = threading.Lock()
        self.accepted = 0
        self.rejected = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def put(self, rows: Sequence[Row]) -> int:
        """Enqueue ``rows``; all-or-nothing at capacity."""
        rows = list(rows)
        with self._lock:
            if len(self._items) + len(rows) > self.maxsize:
                self.rejected += len(rows)
                raise IngestQueueFullError(
                    f"ingest queue full ({len(self._items)}/{self.maxsize}; "
                    f"{len(rows)} offered)")
            for cid, content, ts in rows:
                self._items.append(PendingChunk(int(cid), content, ts))
            self.accepted += len(rows)
            return len(rows)

    def requeue(self, items: Sequence[PendingChunk]) -> None:
        """Put retried items back (never counts against capacity — they
        already held a slot)."""
        with self._lock:
            self._items.extend(items)

    def take_due(self, now: float, limit: int) -> List[PendingChunk]:
        """Pop up to ``limit`` items with ``due_at <= now``, FIFO order."""
        out: List[PendingChunk] = []
        with self._lock:
            rest: List[PendingChunk] = []
            for item in self._items:
                if len(out) < limit and item.due_at <= now:
                    out.append(item)
                else:
                    rest.append(item)
            self._items = rest
        return out

    def has_due(self, now: float) -> bool:
        with self._lock:
            return any(i.due_at <= now for i in self._items)

    def discard(self, ids: Sequence[int]) -> int:
        """Drop pending rows whose chunk id is in ``ids`` (a DELETE racing
        the not-yet-embedded row must not resurrect it)."""
        drop = {int(i) for i in ids}
        with self._lock:
            before = len(self._items)
            self._items = [i for i in self._items if i.chunk_id not in drop]
            return before - len(self._items)

    def snapshot_rows(self) -> List[Row]:
        """Current pending rows (for checkpointing into a snapshot)."""
        with self._lock:
            return [i.row for i in self._items]


class VectorizerWorker:
    """Batch-embedding worker with retry/backoff and a dead-letter list.

    ``sink(ids, vectors, timestamps)`` receives each successfully embedded
    batch (wired to ``VectorCache.ingest`` by the service).  All methods
    are safe to call from the scheduler's executor thread AND from a
    closing thread (the queue is internally locked; ``drain_once`` itself
    is serialized by ``_drain_lock``).
    """

    def __init__(
        self,
        queue: IngestQueue,
        embed_fn: Callable[[str], np.ndarray],
        sink: Callable[[List[int], np.ndarray, List[Optional[float]]], Any],
        *,
        batch_size: int = 64,
        max_attempts: int = 5,
        base_backoff_s: float = 0.05,
        max_backoff_s: float = 5.0,
        jitter: float = 0.25,
        clock: Callable[[], float] = time.monotonic,
        seed: int = 0,
        journal: Optional[StoreJournal] = None,
        fault_plan: Optional[FaultPlan] = None,
    ) -> None:
        self.queue = queue
        self.embed_fn = embed_fn
        self.sink = sink
        self.batch_size = int(batch_size)
        self.max_attempts = int(max_attempts)
        self.base_backoff_s = float(base_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.jitter = float(jitter)
        self.clock = clock
        self.journal = journal
        self.fault_plan = fault_plan
        self._rng = random.Random(seed)
        self._drain_lock = threading.Lock()
        self.embedded = 0
        self.batches = 0
        self.retries = 0
        self.dead_letters: List[Dict[str, Any]] = []

    # -- intake --------------------------------------------------------------

    def enqueue(self, rows: Sequence[Row]) -> int:
        """Admit ``rows`` (raises :class:`IngestQueueFullError` at
        capacity) and journal them so an accepted INSERT survives a
        crash before its background embed lands."""
        n = self.queue.put(rows)
        if self.journal is not None and n:
            self.journal.append_record(
                "enqueue", {"rows": [tuple(r) for r in rows]})
        return n

    def adopt(self, rows: Sequence[Row],
              dead_letters: Sequence[Dict[str, Any]] = ()) -> int:
        """Re-admit rows recovered from a journal (already journaled —
        not re-journaled) plus any recovered dead letters."""
        self.dead_letters.extend(dict(d) for d in dead_letters)
        if not rows:
            return 0
        return self.queue.put(rows)

    # -- the drain path ------------------------------------------------------

    def backoff_s(self, attempts: int) -> float:
        """Exponential backoff with multiplicative jitter for the
        ``attempts``-th failure: ``base * 2^(attempts-1)`` capped at
        ``max_backoff_s``, times ``1 + U(0, jitter)``."""
        delay = min(self.max_backoff_s,
                    self.base_backoff_s * (2.0 ** max(0, attempts - 1)))
        if self.jitter > 0.0:
            delay *= 1.0 + self.jitter * self._rng.random()
        return delay

    def has_due(self, now: Optional[float] = None) -> bool:
        return self.queue.has_due(self.clock() if now is None else now)

    def pending(self) -> int:
        return len(self.queue)

    def drain_once(self, now: Optional[float] = None) -> int:
        """Embed + ingest ONE due batch; returns rows ingested (0 when
        nothing was due or the batch failed and went back for retry)."""
        with self._drain_lock:
            now = self.clock() if now is None else now
            batch = self.queue.take_due(now, self.batch_size)
            if not batch:
                return 0
            try:
                if (self.fault_plan is not None
                        and self.fault_plan.take_embed_failure()):
                    raise EmbedderError("injected embedder failure")
                vecs = np.stack([
                    np.asarray(self.embed_fn(c.content), dtype=np.float32)
                    for c in batch
                ])
            except Exception as err:  # noqa: BLE001 - any embed error retries
                self._handle_failure(batch, now, err)
                return 0
            if self.fault_plan is not None:
                self.fault_plan.reach("vectorizer:post-embed")
            self.sink([c.chunk_id for c in batch], vecs,
                      [c.timestamp for c in batch])
            self.embedded += len(batch)
            self.batches += 1
            return len(batch)

    def _handle_failure(self, batch: List[PendingChunk], now: float,
                        err: Exception) -> None:
        retry: List[PendingChunk] = []
        dead: List[PendingChunk] = []
        for item in batch:
            item.attempts += 1
            if item.attempts >= self.max_attempts:
                dead.append(item)
            else:
                item.due_at = now + self.backoff_s(item.attempts)
                retry.append(item)
        if retry:
            self.retries += len(retry)
            self.queue.requeue(retry)
        if dead:
            rows = [{
                "chunk_id": item.chunk_id,
                "content": item.content,
                "timestamp": item.timestamp,
                "attempts": item.attempts,
                "error": repr(err),
            } for item in dead]
            self.dead_letters.extend(rows)
            if self.journal is not None:
                self.journal.append_record("dead_letter", {"rows": rows})

    def flush(self) -> int:
        """Drive the queue to empty, ignoring backoff due-times (used by
        ``close()``): every pending row either ingests or exhausts its
        retry budget into the dead-letter list.  Returns rows ingested."""
        total = 0
        # each non-ingesting round burns one attempt per due row, so the
        # loop is bounded by max_attempts rounds even for poison rows
        while len(self.queue):
            total += self.drain_once(now=float("inf"))
        return total

    def stats(self) -> Dict[str, int]:
        return {
            "queued": self.queue.accepted,
            "in_queue": len(self.queue),
            "rejected": self.queue.rejected,
            "embedded": self.embedded,
            "batches": self.batches,
            "retries": self.retries,
            "dead_letter": len(self.dead_letters),
        }
