"""Straggler mitigation + elastic-scaling hooks.

On a real multi-pod deployment:
* the StepWatchdog's flags feed a controller that can (a) exclude a slow
  host from the next data-parallel rendezvous, (b) trigger an elastic
  re-mesh (checkpoints are sharding-agnostic: train/checkpoint.py), or
  (c) pre-emptively checkpoint when failure probability rises;
* ``replan_mesh`` computes the largest valid (data, model) mesh for a
  degraded device count — the restart path after losing nodes.

The watchdog and replanner are fully exercised in tests on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


class StepWatchdog:
    """EWMA step-timer; flags steps slower than mean + k*std (stragglers)."""

    def __init__(self, alpha: float = 0.1, k: float = 3.0, warmup: int = 5):
        self.alpha = alpha
        self.k = k
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.events: List[Tuple[int, float]] = []

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        std = self.var ** 0.5
        if self.n > self.warmup and dt > self.mean + self.k * max(std, 0.05 * self.mean):
            self.events.append((self.n, dt))
            is_straggler = True
            # do NOT absorb outliers into the EWMA
            return True
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


def replan_mesh(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for a degraded device count.

    Keeps the model axis fixed (TP degree is architecture-determined) and
    shrinks data parallelism: 512 -> 496 devices with model=16 yields
    (31, 16). Raises if even one model group doesn't fit."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot fit model-parallel degree {model_parallel} on {n_devices} devices")
    data = n_devices // model_parallel
    return data, model_parallel


@dataclasses.dataclass
class ElasticPlan:
    old_devices: int
    new_devices: int
    mesh_shape: Tuple[int, int]
    action: str

    @classmethod
    def on_failure(cls, old_devices: int, failed: int, model_parallel: int) -> "ElasticPlan":
        new = old_devices - failed
        shape = replan_mesh(new, model_parallel)
        return cls(old_devices, shape[0] * shape[1], shape,
                   action="restore-from-checkpoint-with-smaller-mesh")
