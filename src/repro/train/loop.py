"""Fault-tolerant training loop.

Design for 1000+ nodes (DESIGN.md §5), exercised here on CPU:

* restart-from-latest-checkpoint on startup (node-failure recovery path:
  the launcher simply re-executes the job);
* checkpoint includes data-iterator state -> bitwise-identical resume;
* async checkpointing off the critical path;
* per-step watchdog: step-time EWMA + z-score flags stragglers (on real
  pods this feeds the elastic controller in train/elastic.py);
* pull-based prefetching data pipeline (a slow host can't stall the step).
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.data.loader import LMDataConfig, PrefetchLoader, SyntheticLMStream
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.train.elastic import StepWatchdog


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3


class Trainer:
    """Generic pytree trainer: step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def __init__(
        self,
        step_fn: Callable,
        params: Any,
        opt_state: Any,
        stream: SyntheticLMStream,
        cfg: TrainLoopConfig,
        to_batch: Callable[[Dict[str, np.ndarray]], Any] = None,
    ):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.stream = stream
        self.cfg = cfg
        self.to_batch = to_batch or (lambda b: b)
        self.step = 0
        self.watchdog = StepWatchdog()
        self.ckpt = (
            AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_ckpts) if cfg.ckpt_dir else None
        )
        self.history: list = []

    # -- fault tolerance -------------------------------------------------

    def try_resume(self) -> bool:
        """Node-failure recovery: restore (params, opt, data state) from the
        newest complete checkpoint, if any."""
        if not self.cfg.ckpt_dir or latest_step(self.cfg.ckpt_dir) is None:
            return False
        tree = {"params": self.params, "opt_state": self.opt_state}
        tree, step, extra = restore(self.cfg.ckpt_dir, tree)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = step
        if "data_state" in extra:
            self.stream.load_state_dict(extra["data_state"])
        return True

    def _checkpoint(self) -> None:
        if self.ckpt is None:
            return
        # data_state records the CONSUMED batch count (== train step; one
        # batch per step), NOT stream.state_dict(): the prefetch thread's
        # producer cursor runs ahead of consumption, and checkpointing it
        # would skip batches on resume.
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt_state": self.opt_state},
            extra={"data_state": {"step": self.step}},
        )

    # -- the loop ----------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        target = self.step + (steps if steps is not None else
                              self.cfg.total_steps - self.step)
        loader = PrefetchLoader(self.stream)
        try:
            while self.step < target:
                batch = self.to_batch(loader.next())
                t0 = time.time()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                self.step += 1
                straggler = self.watchdog.observe(dt)
                if self.step % self.cfg.log_every == 0 or self.step == target:
                    self.history.append(
                        {"step": self.step, "loss": float(metrics["loss"]),
                         "sec_per_step": dt, "straggler": straggler})
                if self.cfg.ckpt_dir and self.step % self.cfg.ckpt_every == 0:
                    self._checkpoint()
        finally:
            loader.close()
            if self.ckpt is not None and self.cfg.ckpt_dir:
                self._checkpoint()
                self.ckpt.wait()
        return {
            "final_step": self.step,
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "history": self.history,
            "straggler_events": self.watchdog.events,
        }
