"""Checkpointing: atomic, resharding-agnostic, async-capable.

* Pytrees are flattened to path-keyed arrays in an .npz + JSON metadata
  (step, data-iterator state, config fingerprint).
* Writes go to a temp file then os.replace() — a crash mid-save never
  corrupts the latest checkpoint (fault tolerance).
* Arrays are saved UNSHARDED (host-gathered): a restart may use a different
  device count/mesh — restore() re-places onto whatever shardings the new
  mesh dictates (elastic scaling).
* ``AsyncCheckpointer`` offloads serialization to a background thread so the
  train loop never blocks on I/O.
"""

from __future__ import annotations

import json
import os
import re
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(
    ckpt_dir: str | Path,
    step: int,
    tree: Any,
    extra: Optional[Dict[str, Any]] = None,
) -> Path:
    """Atomic checkpoint write -> <dir>/ckpt_<step>.npz (+ .json)."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    tmp = ckpt_dir / f".tmp_ckpt_{step}.npz"
    final = ckpt_dir / f"ckpt_{step}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    meta = {"step": step, "extra": extra or {}, "keys": sorted(flat)}
    tmp_meta = ckpt_dir / f".tmp_ckpt_{step}.json"
    tmp_meta.write_text(json.dumps(meta))
    os.replace(tmp, final)                       # atomic on POSIX
    os.replace(tmp_meta, ckpt_dir / f"ckpt_{step}.json")
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.glob("ckpt_*.npz"):
        m = re.match(r"ckpt_(\d+)\.npz", p.name)
        if m and (ckpt_dir / f"ckpt_{m.group(1)}.json").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``like``; optionally re-place onto
    ``shardings`` (a matching pytree of NamedSharding) — this is the elastic
    path: the mesh at restore time may differ from the one at save time."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(ckpt_dir / f"ckpt_{step}.npz")
    meta = json.loads((ckpt_dir / f"ckpt_{step}.json").read_text())

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        expect = np.asarray(leaf)
        if tuple(arr.shape) != tuple(expect.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {expect.shape}")
        leaves.append(arr.astype(expect.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step, meta.get("extra", {})


def prune(ckpt_dir: str | Path, keep: int = 3) -> None:
    """Keep the newest `keep` checkpoints (bounded disk on long runs)."""
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(
        int(re.match(r"ckpt_(\d+)\.npz", p.name).group(1))
        for p in ckpt_dir.glob("ckpt_*.npz")
        if re.match(r"ckpt_(\d+)\.npz", p.name)
    )
    for s in steps[:-keep]:
        for suffix in (".npz", ".json"):
            try:
                (ckpt_dir / f"ckpt_{s}{suffix}").unlink()
            except FileNotFoundError:
                pass


class AsyncCheckpointer:
    """Background-thread checkpoint writer: snapshot on the caller thread
    (device -> host copy), serialize/write off-thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                prune(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
