"""Sharded AdamW with distributed-training conveniences.

* Optimizer state inherits parameter sharding (2-D FSDP x TP), so m/v never
  exceed per-device HBM on the production mesh.
* Gradient compression: grads are cast to bf16 BEFORE the (XLA-inserted)
  data-parallel all-reduce — halving the dominant collective — and
  accumulated into f32 moments (``compress_grads``).
* Global-norm clipping, decoupled weight decay, linear warmup + cosine decay.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = True   # bf16 gradient all-reduce (compression)


class OptState(NamedTuple):
    step: jnp.ndarray   # ()
    m: Params           # f32, param-shaped
    v: Params           # f32, param-shaped


def init_opt_state(params: Params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_update(
    cfg: AdamWConfig,
    params: Params,
    grads: Params,
    state: OptState,
) -> Tuple[Params, OptState, Dict[str, jnp.ndarray]]:
    if cfg.compress_grads:
        # bf16 on the wire (the DP all-reduce XLA inserts happens on these
        # values); moments below re-accumulate in f32.
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads32))
    )
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads32 = jax.tree.map(lambda g: g * scale, grads32)

    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads32)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v), metrics


def make_grad_accum_step(loss_fn, cfg: AdamWConfig, n_micro: int):
    """Gradient accumulation: scan `n_micro` microbatches per optimizer
    update (batch leaves carry leading dim n_micro*mb). Exact: equal-size
    microbatches of a mean loss give the identical global gradient, so
    global batch can exceed per-step activation memory by n_micro x."""

    def step(params, opt_state, batch):
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
            batch)

        def body(gsum, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g), loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, losses = jax.lax.scan(body, zeros, micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, metrics = adamw_update(cfg, params, grads, opt_state)
        return params, opt_state, {"loss": losses.mean(), **metrics}

    return step
