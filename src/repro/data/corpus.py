"""Synthetic production corpus — AI coding session history (paper §1).

Mirrors the paper's production corpus structurally: chunks (user_prompt /
assistant / tool_call / file) grouped into sessions with project, timestamps,
tool names and file paths. Content is generated from topic vocabularies with
a deliberately *dominant descriptive cluster* and a *buried implementation
cluster* sharing vocabulary — the structure §5.1's suppression case study
depends on.
"""

from __future__ import annotations

import dataclasses
import sqlite3
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.embed import HashEmbedder
from repro.sqlio import schema as schema_mod
from repro.sqlio.presets import register_presets

# Topic vocabularies. 'overlap' words appear in both clusters (and in the
# §5.1 query), which is exactly why baseline cosine cannot separate them.
_OVERLAP = ["system", "works", "architecture", "how", "the", "overview"]

# Words shared ACROSS descriptive topics (marketing copy and docs genuinely
# share vocabulary); this intra-cluster correlation is what lets the two
# suppress: directions of §5.1 cover the whole descriptive cluster, the same
# way a real embedding space correlates same-genre content.
_DESCRIPTIVE_SHARED = [
    "website", "landing", "page", "design", "tagline",
    "documentation", "readme", "community", "post", "draft", "copy",
]
_IMPLEMENTATION_SHARED = ["implementation", "internal", "logic", "code"]

DESCRIPTIVE_TOPICS = [
    ("ui_style", ["website", "landing", "page", "design", "style", "layout", "css", "iteration"]),
    ("tagline", ["marketing", "tagline", "draft", "copy", "headline", "brand", "positioning"]),
    ("docs_site", ["documentation", "readme", "site", "structure", "guide", "tutorial"]),
    ("positioning", ["product", "positioning", "discussion", "market", "pitch", "story"]),
    ("community", ["community", "post", "announcement", "launch", "blog", "share"]),
]

IMPLEMENTATION_TOPICS = [
    ("identity", ["identity", "layer", "data", "model", "uuid", "provenance", "tracking"]),
    ("server", ["server", "lifecycle", "debugging", "restart", "socket", "operations"]),
    ("worker", ["background", "worker", "failure", "analysis", "queue", "retry"]),
    ("rendering", ["rendering", "pipeline", "implementation", "frame", "buffer", "draw"]),
    ("platform", ["platform", "detection", "branching", "logic", "linux", "darwin"]),
]

NEUTRAL_TOPICS = [
    ("auth", ["auth", "token", "jwt", "login", "session", "oauth", "refresh"]),
    ("database", ["database", "sqlite", "storage", "schema", "migration", "index"]),
    ("search", ["search", "retrieval", "embedding", "vector", "score", "ranking"]),
    ("testing", ["test", "pytest", "assert", "fixture", "coverage", "mock"]),
    ("deploy", ["deploy", "release", "docker", "build", "publish", "version"]),
    ("files", ["file", "path", "snapshot", "diff", "edit", "patch"]),
]

PROJECTS = ["core", "website", "cli", "infra"]
TOOLS = ["read", "edit", "bash", "grep", "write"]
CHUNK_TYPES = ["user_prompt", "assistant", "tool_call", "file"]
# Descriptive cluster is LARGER (paper: 'the descriptive cluster is typically
# larger') — weights over (descriptive, implementation, neutral).
CLUSTER_WEIGHTS = (0.42, 0.13, 0.45)


@dataclasses.dataclass
class Chunk:
    id: int
    session_id: str
    type: str
    content: str
    created_at: float
    position: int
    project: str
    tool_name: Optional[str]
    file: Optional[str]
    ext: Optional[str]
    topic: str
    cluster: str  # descriptive|implementation|neutral

    def row(self) -> tuple:
        return (
            self.id, self.session_id, self.type, self.content, self.created_at,
            self.position, self.project, self.tool_name, self.file, self.ext,
        )


def generate_corpus(
    n_chunks: int = 240_000,
    n_sessions: int = 4_000,
    days: float = 180.0,
    seed: int = 0,
    now: float = 1_770_000_000.0,
) -> List[Chunk]:
    rng = np.random.Generator(np.random.PCG64(seed))
    clusters = [
        ("descriptive", DESCRIPTIVE_TOPICS),
        ("implementation", IMPLEMENTATION_TOPICS),
        ("neutral", NEUTRAL_TOPICS),
    ]
    chunks: List[Chunk] = []
    per_session = max(1, n_chunks // n_sessions)
    cid = 0
    for s in range(n_sessions):
        session_id = f"s{s:06d}"
        project = PROJECTS[int(rng.integers(len(PROJECTS)))]
        t0 = now - float(rng.uniform(0, days * 86400.0))
        n_in_session = per_session + (1 if s < n_chunks - per_session * n_sessions else 0)
        for pos in range(n_in_session):
            if cid >= n_chunks:
                break
            ci = int(rng.choice(3, p=CLUSTER_WEIGHTS))
            cluster_name, topics = clusters[ci]
            tname, vocab = topics[int(rng.integers(len(topics)))]
            ctype = CHUNK_TYPES[int(rng.choice(4, p=[0.2, 0.45, 0.25, 0.1]))]
            content = _make_content(rng, vocab, cluster_name, ctype)
            tool = TOOLS[int(rng.integers(len(TOOLS)))] if ctype == "tool_call" else None
            fpath = f"src/{tname}/{tname}_{int(rng.integers(20))}.py" if ctype == "file" else None
            chunks.append(
                Chunk(
                    id=cid, session_id=session_id, type=ctype, content=content,
                    created_at=t0 + pos * 30.0, position=pos, project=project,
                    tool_name=tool, file=fpath, ext="py" if fpath else None,
                    topic=tname, cluster=cluster_name,
                )
            )
            cid += 1
    return chunks


def _make_content(rng: np.random.Generator, vocab: Sequence[str], cluster: str, ctype: str) -> str:
    n_topic = int(rng.integers(6, 14))
    words = [vocab[int(rng.integers(len(vocab)))] for _ in range(n_topic)]
    # Both descriptive and implementation clusters use the query's vocabulary
    # (paper §5.1: 'use the same vocabulary'); descriptive uses MORE of it,
    # which is what makes it dominate baseline cosine ranking.
    # Paper §5.1: the clusters 'use the same vocabulary' — per-doc query
    # overlap is drawn from the SAME distribution; the descriptive cluster
    # dominates baseline top-K through its larger SIZE (order statistics),
    # which is exactly the failure mode suppression exists to fix.
    n_overlap = int(rng.integers(2, 5)) if cluster in ("descriptive", "implementation") \
        else int(rng.integers(0, 2))
    words += [_OVERLAP[int(rng.integers(len(_OVERLAP)))] for _ in range(n_overlap)]
    if cluster == "descriptive":
        shared = _DESCRIPTIVE_SHARED
        n_shared = int(rng.integers(4, 9))
    elif cluster == "implementation":
        shared = _IMPLEMENTATION_SHARED
        n_shared = int(rng.integers(1, 3))
    else:
        shared, n_shared = [], 0
    words += [shared[int(rng.integers(len(shared)))] for _ in range(n_shared)]
    rng.shuffle(words)  # type: ignore[arg-type]
    body = " ".join(words)
    if ctype == "assistant":
        # long-form so `length(content) > 300` pre-filters keep them
        body = (body + " ") * 4
    return body.strip()


def build_database(
    conn: sqlite3.Connection,
    chunks: Sequence[Chunk],
    embedder: Optional[HashEmbedder] = None,
    description: str = "Agentic coding conversation history. Sessions, messages, tool calls, and output.",
) -> np.ndarray:
    """Create schema, insert chunks + sources + embeddings. Returns matrix."""
    embedder = embedder or HashEmbedder(128)
    schema_mod.build_schema(conn, description)
    register_presets(conn)

    sessions: dict = {}
    for c in chunks:
        st = sessions.setdefault(
            c.session_id, [c.project, f"session {c.session_id}", c.created_at, c.created_at, 0]
        )
        st[2] = min(st[2], c.created_at)
        st[3] = max(st[3], c.created_at)
        st[4] += 1
    schema_mod.insert_sources(
        conn, [(sid, *vals) for sid, vals in sessions.items()]
    )

    matrix = embedder.embed_batch([c.content for c in chunks])
    B = 20_000
    for i in range(0, len(chunks), B):
        schema_mod.insert_chunks(
            conn, [c.row() for c in chunks[i : i + B]], matrix[i : i + B]
        )
    return matrix
