"""Synthetic BEIR-like labeled corpora for behavioral validation (paper §4.4).

Real BEIR downloads are unavailable offline; these generators preserve the
properties the paper's behavioral suite measures:

* topical corpora with graded query relevance (nDCG@10 computable),
* controllable cluster tightness (near-duplicate rate) — the knob behind
  the paper's SciFact(broad, 93% diverse retention) vs NFCorpus(tight, 59%)
  spread,
* synthetic 90-day-uniform timestamps (the paper's own caveat for decay),
* document counts matching the four BEIR datasets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

_WORDPOOL_SIZE = 4000


@dataclasses.dataclass
class BeirLikeDataset:
    name: str
    doc_texts: List[str]
    doc_topics: np.ndarray          # (N,)
    timestamps: np.ndarray          # (N,) unix seconds, 90-day uniform
    queries: List[str]              # >= 30
    query_topics: np.ndarray
    qrels: List[Dict[int, int]]     # per query: {doc_row: relevance}
    now: float


# (n_docs, n_topics, dup_rate, noise_words, topic_words) — dup_rate high =>
# tight clusters (NFCorpus-like); more noise + fewer topic words => harder
# baseline (paper baseline nDCG@10 band: 0.13 NFCorpus .. 0.60 SciFact).
DATASET_SPECS = {
    "scifact-like": (5_183, 120, 0.20, 10, 6),
    "nfcorpus-like": (3_633, 30, 0.70, 22, 4),
    "scidocs-like": (25_657, 150, 0.45, 18, 5),
    "fiqa-like": (57_638, 100, 0.40, 16, 5),
}


def _word(i: int) -> str:
    return f"w{i:05d}"


def make_dataset(name: str, seed: int = 0) -> BeirLikeDataset:
    n_docs, n_topics, dup_rate, n_noise, n_topic_words = DATASET_SPECS[name]
    rng = np.random.Generator(np.random.PCG64(seed ^ hash(name) & 0x7FFF))
    # topic vocabularies: 12 words each, drawn from a shared pool (overlap
    # between topics => realistic non-zero off-topic similarity)
    topic_vocab = rng.integers(0, _WORDPOOL_SIZE, size=(n_topics, 12))
    # per-topic "template" docs that near-duplicates perturb
    templates = [
        [_word(w) for w in rng.choice(topic_vocab[t], n_topic_words)]
        for t in range(n_topics)
    ]

    doc_texts: List[str] = []
    doc_topics = rng.integers(0, n_topics, n_docs)
    is_template_dup = np.zeros(n_docs, bool)
    for i in range(n_docs):
        t = doc_topics[i]
        if rng.random() < dup_rate:
            words = list(templates[t])
            # small perturbation
            words[int(rng.integers(len(words)))] = _word(int(rng.choice(topic_vocab[t])))
            is_template_dup[i] = True
        else:
            words = [_word(int(w)) for w in rng.choice(topic_vocab[t], n_topic_words)]
        words += [_word(int(w)) for w in rng.integers(0, _WORDPOOL_SIZE, n_noise)]
        doc_texts.append(" ".join(words))

    now = 1_770_000_000.0
    timestamps = now - rng.uniform(0, 90 * 86400.0, n_docs)  # 90-day spread

    n_queries = 40
    queries: List[str] = []
    query_topics = rng.integers(0, n_topics, n_queries)
    qrels: List[Dict[int, int]] = []
    topic_rows: Dict[int, np.ndarray] = {
        t: np.where(doc_topics == t)[0] for t in range(n_topics)
    }
    for qi in range(n_queries):
        t = int(query_topics[qi])
        rows = topic_rows[t]
        # Queries are written ABOUT specific (judged) documents, as in real
        # BEIR: pick an anchor doc, sample query words from its text.
        anchor = int(rows[int(rng.integers(len(rows)))])
        anchor_words = doc_texts[anchor].split()
        qwords = [anchor_words[int(rng.integers(len(anchor_words)))]
                  for _ in range(3)]
        queries.append(" ".join(qwords))
        # SPARSE graded qrels (real BEIR judges a handful per query): anchor
        # + template-duplicates of the topic (rel 2) + a judged sample
        # (rel 1). Unjudged same-topic docs still rank high and drag nDCG
        # down — producing the paper's 0.13-0.60 baseline band.
        dups = [int(r) for r in rows if is_template_dup[r]][:8]
        n_judged = min(10, len(rows))
        judged = rng.choice(rows, n_judged, replace=False)
        rel: Dict[int, int] = {int(r): 1 for r in judged}
        for r in dups:
            rel[r] = 2
        rel[anchor] = 2
        qrels.append(rel)

    return BeirLikeDataset(
        name=name, doc_texts=doc_texts, doc_topics=doc_topics,
        timestamps=timestamps, queries=queries, query_topics=query_topics,
        qrels=qrels, now=now,
    )
