"""Synthetic recsys data (Criteo-like click logs, behavior sequences)."""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

# MLPerf DLRM / Criteo 1TB per-table cardinalities (day-23 counts) —
# the published benchmark config [arXiv:1906.00091; MLPerf v0.7 rules].
CRITEO_1TB_VOCAB_SIZES: Tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def dlrm_batch(
    batch: int, n_dense: int, vocab_sizes: Sequence[int], seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.PCG64(seed))
    sparse = np.stack(
        [rng.integers(0, v, batch).astype(np.int32) for v in vocab_sizes], axis=1
    )
    # clicks correlate with a hidden linear signal so training can learn
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    p = 1 / (1 + np.exp(-(dense[:, :3].sum(1))))
    return {
        "dense": dense,
        "sparse": sparse,
        "labels": (rng.random(batch) < p).astype(np.float32),
    }


def bst_batch(batch: int, seq_len: int, vocab_items: int, n_other: int = 8,
              seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.PCG64(seed))
    hist = rng.integers(0, vocab_items, (batch, seq_len)).astype(np.int32)
    target = rng.integers(0, vocab_items, batch).astype(np.int32)
    other = rng.standard_normal((batch, n_other)).astype(np.float32)
    # click iff target shares a coarse "category" (id modulo) with history
    cat = target % 97
    match = (hist % 97 == cat[:, None]).any(axis=1)
    noise = rng.random(batch) < 0.1
    return {
        "hist": hist, "target": target, "other": other,
        "labels": (match ^ noise).astype(np.float32),
    }


def autoint_batch(batch: int, n_fields: int, vocab_per_field: int,
                  seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.PCG64(seed))
    sparse = rng.integers(0, vocab_per_field, (batch, n_fields)).astype(np.int32)
    p = 1 / (1 + np.exp(-((sparse[:, :2].sum(1) % 7) - 3.0)))
    return {"sparse": sparse, "labels": (rng.random(batch) < p).astype(np.float32)}


def twotower_batch(batch: int, vocab_user: int, vocab_item: int, hist_len: int,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.PCG64(seed))
    hist = rng.integers(0, vocab_item, (batch, hist_len)).astype(np.int32)
    # ragged bags: pad a random suffix with -1
    lens = rng.integers(1, hist_len + 1, batch)
    hist[np.arange(hist_len)[None, :] >= lens[:, None]] = -1
    pos = rng.integers(0, vocab_item, batch).astype(np.int32)
    # logQ correction: popularity-biased sampling probability (synthetic Zipf)
    q = 1.0 / (1.0 + (pos % 1000).astype(np.float64))
    return {
        "user_id": rng.integers(0, vocab_user, batch).astype(np.int32),
        "hist": hist,
        "pos_item": pos,
        "logq": np.log(q / q.sum()).astype(np.float32),
    }
