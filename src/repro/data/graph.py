"""Graph data substrate: synthetic graphs + a real neighbor sampler.

JAX has no sparse message-passing; graphs are (edge_index (2,E), feats,
labels) with segment-ops in the model (kernel taxonomy §GNN). The sampler
produces PADDED subgraphs (static shapes) so the jitted train step compiles
once; padding is masked via a sink node.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class GraphBatch:
    """Padded, jit-ready graph. Sink node at index n_nodes-1 absorbs padding."""

    feats: np.ndarray        # (N, F) float32
    edge_src: np.ndarray     # (E,) int32 — padded edges point at the sink
    edge_dst: np.ndarray     # (E,) int32
    labels: np.ndarray       # (N,) int32 node labels, or (G,) graph labels
    node_mask: np.ndarray    # (N,) bool — real (non-padding) nodes
    edge_mask: np.ndarray    # (E,) bool
    graph_ids: Optional[np.ndarray] = None  # (N,) int32 for batched graphs
    n_graphs: int = 1


def make_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int = 16,
    seed: int = 0,
    power_law: bool = True,
) -> GraphBatch:
    """Synthetic featured graph with power-law-ish degree and label-correlated
    features (so training actually reduces loss in smoke tests)."""
    rng = np.random.Generator(np.random.PCG64(seed))
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + 0.5 * rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    if power_law:
        w = 1.0 / (1.0 + np.arange(n_nodes)) ** 0.5
        p = w / w.sum()
        dst = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    else:
        dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return GraphBatch(
        feats=feats,
        edge_src=src,
        edge_dst=dst,
        labels=labels,
        node_mask=np.ones(n_nodes, bool),
        edge_mask=np.ones(n_edges, bool),
    )


def make_molecule_batch(
    batch: int, nodes_per_graph: int, edges_per_graph: int, d_feat: int,
    n_classes: int = 2, seed: int = 0,
) -> GraphBatch:
    """Batched small graphs (molecule regime): block-diagonal edge index."""
    rng = np.random.Generator(np.random.PCG64(seed))
    N = batch * nodes_per_graph
    E = batch * edges_per_graph
    feats = rng.standard_normal((N, d_feat)).astype(np.float32)
    offs = np.repeat(np.arange(batch) * nodes_per_graph, edges_per_graph)
    src = (rng.integers(0, nodes_per_graph, E) + offs).astype(np.int32)
    dst = (rng.integers(0, nodes_per_graph, E) + offs).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), nodes_per_graph).astype(np.int32)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    return GraphBatch(
        feats=feats, edge_src=src, edge_dst=dst, labels=labels,
        node_mask=np.ones(N, bool), edge_mask=np.ones(E, bool),
        graph_ids=graph_ids, n_graphs=batch,
    )


class CSRGraph:
    """CSR adjacency for neighbor sampling (built once, host-side)."""

    def __init__(self, n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray):
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order].astype(np.int32)  # in-neighbors of dst
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=self.indptr[1:])
        self.n_nodes = n_nodes

    def sample_neighbors(self, nodes: np.ndarray, fanout: int, rng) -> np.ndarray:
        """Uniform with replacement; isolated nodes self-loop. (len, fanout)."""
        out = np.empty((len(nodes), fanout), np.int32)
        for i, v in enumerate(nodes):
            lo, hi = self.indptr[v], self.indptr[v + 1]
            if hi > lo:
                out[i] = self.nbr[rng.integers(lo, hi, fanout)]
            else:
                out[i] = v
        return out


def sample_subgraph(
    graph: GraphBatch,
    csr: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> GraphBatch:
    """GraphSAGE-style layered sampling -> padded subgraph with STATIC shapes
    (max_nodes = seeds*(1+f1+f1*f2+...), max_edges = seeds*(f1+f1*f2+...)).
    The returned subgraph is relabeled 0..N-1 with a sink node at N-1."""
    layers: List[np.ndarray] = [seeds.astype(np.int32)]
    edges_src: List[np.ndarray] = []
    edges_dst: List[np.ndarray] = []
    frontier = seeds.astype(np.int32)
    for f in fanouts:
        nbrs = csr.sample_neighbors(frontier, f, rng)        # (len, f)
        src = nbrs.reshape(-1)
        dst = np.repeat(frontier, f)
        edges_src.append(src)
        edges_dst.append(dst)
        frontier = src
        layers.append(src)

    all_nodes = np.concatenate(layers)
    uniq, inv = np.unique(all_nodes, return_inverse=True)

    # static budgets
    max_nodes = _max_nodes(len(seeds), fanouts) + 1          # +1 sink
    max_edges = _max_edges(len(seeds), fanouts)
    n_real = len(uniq)
    assert n_real < max_nodes, (n_real, max_nodes)

    remap = {int(g): i for i, g in enumerate(uniq)}
    src = np.concatenate(edges_src)
    dst = np.concatenate(edges_dst)
    src_l = np.fromiter((remap[int(s)] for s in src), np.int32, len(src))
    dst_l = np.fromiter((remap[int(d)] for d in dst), np.int32, len(dst))

    sink = max_nodes - 1
    feats = np.zeros((max_nodes, graph.feats.shape[1]), np.float32)
    feats[:n_real] = graph.feats[uniq]
    labels = np.zeros(max_nodes, np.int32)
    labels[:n_real] = graph.labels[uniq]
    node_mask = np.zeros(max_nodes, bool)
    # supervise ONLY seed nodes (standard sampled-training objective)
    seed_local = np.fromiter((remap[int(s)] for s in seeds), np.int32, len(seeds))
    node_mask[seed_local] = True

    e_src = np.full(max_edges, sink, np.int32)
    e_dst = np.full(max_edges, sink, np.int32)
    e_mask = np.zeros(max_edges, bool)
    e_src[: len(src_l)] = src_l
    e_dst[: len(dst_l)] = dst_l
    e_mask[: len(src_l)] = True
    return GraphBatch(
        feats=feats, edge_src=e_src, edge_dst=e_dst, labels=labels,
        node_mask=node_mask, edge_mask=e_mask,
    )


def _max_nodes(n_seeds: int, fanouts: Sequence[int]) -> int:
    total, layer = n_seeds, n_seeds
    for f in fanouts:
        layer *= f
        total += layer
    return total


def _max_edges(n_seeds: int, fanouts: Sequence[int]) -> int:
    total, layer = 0, n_seeds
    for f in fanouts:
        layer *= f
        total += layer
    return total
