"""LM data pipeline: deterministic synthetic token stream with
checkpointable iterator state (resume-exact after restart) and host-side
prefetch so a straggling host never stalls the device step."""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class LMDataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    # Markov-ish structure so the LM has something learnable
    n_states: int = 64


class SyntheticLMStream:
    """Deterministic, seekable token stream. state = (step,) — a restart
    resumes from any step with identical batches (fault-tolerance tested in
    tests/test_checkpoint.py)."""

    def __init__(self, cfg: LMDataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        rng = np.random.Generator(np.random.PCG64(cfg.seed))
        # fixed random transition table: state -> token distribution peak
        self._peaks = rng.integers(0, cfg.vocab, cfg.n_states)

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.PCG64(hash((cfg.seed, self.step)) & 0x7FFFFFFF)
        )
        states = rng.integers(0, cfg.n_states, (cfg.batch, cfg.seq_len + 1))
        noise = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1))
        use_peak = rng.random((cfg.batch, cfg.seq_len + 1)) < 0.8
        toks = np.where(use_peak, self._peaks[states], noise).astype(np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


class PrefetchLoader:
    """Background-thread prefetch (pull-based): the training loop never
    blocks on data generation unless the queue is fully drained."""

    def __init__(self, stream: SyntheticLMStream, depth: int = 2):
        self.stream = stream
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            batch = self.stream.next_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
