"""SQL presets (paper §3.1, Appendices C/D).

``@orient`` is a multi-query SQL script; each ``-- @query:`` section produces
one key of the output. ``pragma_table_info()`` discovers view columns at
runtime so schema changes propagate without updating agent instructions.
"""

from __future__ import annotations

import sqlite3
from typing import Dict, List, Optional, Tuple

ORIENT_SQL = """
-- @query: now
SELECT datetime('now', 'localtime') as now,
       'UTC' || printf('%+d',
         cast((julianday('now', 'localtime')
               - julianday('now')) * 24 as integer)) as timezone;

-- @query: about
SELECT value as description FROM _meta WHERE key = 'description';

-- @query: shape
SELECT 'chunks' as what, COUNT(*) as n FROM _raw_chunks
UNION ALL
SELECT 'sources', COUNT(*) FROM _raw_sources;

-- @query: query_surface
SELECT 'view' as kind, m.name as name,
       GROUP_CONCAT(p.name, ', ') as columns,
       CASE m.name
         WHEN 'chunks' THEN 'UNIFIED surface -- all chunks. type: user_prompt|assistant|tool_call|file.'
         WHEN 'messages' THEN 'Message chunks only.'
         WHEN 'sessions' THEN 'Sources with graph intelligence.'
         ELSE ''
       END as note
FROM sqlite_master m, pragma_table_info(m.name) p
WHERE m.type = 'view'
GROUP BY m.name
UNION ALL
SELECT 'table_function', 'vec_ops', 'id, score, snippet',
       'Semantic retrieval with token grammar -- use after FROM/JOIN.'
UNION ALL
SELECT 'table_function', 'keyword', 'id, score, snippet',
       'FTS5 keyword search (scores min-max normalized).'
UNION ALL
SELECT 'table_function', 'hybrid_search', 'id, score, snippet',
       'HYBRID_SEARCH(''query''[, weight]) -- weight*vector + (1-weight)*bm25, fused on device.'
UNION ALL
SELECT 'table_function', 'vector_search', 'id, score, snippet',
       'VECTOR_SEARCH(''query'') -- pure-vector baseline (plain text, no grammar).'
ORDER BY kind, name;

-- @query: presets
SELECT name, description, params FROM _presets ORDER BY name;
"""

DIGEST_SQL = """
-- @query: digest
SELECT date(created_at, 'unixepoch') AS day, project,
       COUNT(*) AS chunks,
       SUM(type = 'assistant') AS assistant_msgs,
       SUM(type = 'tool_call') AS tool_calls
FROM _raw_chunks
WHERE created_at > strftime('%s', 'now') - :days * 86400
GROUP BY day, project ORDER BY day DESC, chunks DESC;
"""

FILE_SQL = """
-- @query: file_sessions
SELECT DISTINCT c.session_id, s.project, s.title,
       datetime(s.start_time, 'unixepoch') AS started
FROM _raw_chunks c JOIN _raw_sources s USING (session_id)
WHERE c.file LIKE :path OR c.content LIKE :path
ORDER BY s.start_time DESC LIMIT 50;
"""

SPRINTS_SQL = """
-- @query: sprints
WITH ordered AS (
    SELECT session_id, start_time,
           start_time - LAG(start_time) OVER (ORDER BY start_time) AS gap
    FROM _raw_sources
)
SELECT session_id, datetime(start_time, 'unixepoch') AS started,
       CASE WHEN gap IS NULL OR gap > 6 * 3600 THEN 1 ELSE 0 END AS sprint_start
FROM ordered ORDER BY start_time;
"""

PRESETS: Dict[str, Tuple[str, str, str]] = {
    # name -> (description, params, sql script)
    "@orient": ("Full cell orientation", "", ORIENT_SQL),
    "@digest": ("Multi-day activity summary", "days=7", DIGEST_SQL),
    "@file": ("Sessions that touched a file", "path required", FILE_SQL),
    "@sprints": ("Work sprints detected by 6h gaps", "", SPRINTS_SQL),
}


def register_presets(conn: sqlite3.Connection) -> None:
    conn.executemany(
        "INSERT OR REPLACE INTO _presets (name, description, params, sql)"
        " VALUES (?,?,?,?)",
        [(n, d, p, s) for n, (d, p, s) in PRESETS.items()],
    )
    conn.commit()


def run_preset(
    conn: sqlite3.Connection,
    name: str,
    params: Optional[Dict[str, object]] = None,
) -> Dict[str, Tuple[List[str], List[tuple]]]:
    """Execute a multi-query preset script -> {query_key: (cols, rows)}."""
    if name not in PRESETS:
        row = conn.execute("SELECT sql FROM _presets WHERE name = ?", (name,)).fetchone()
        if row is None:
            raise KeyError(f"unknown preset {name}")
        script = row[0]
    else:
        script = PRESETS[name][2]

    out: Dict[str, Tuple[List[str], List[tuple]]] = {}
    key = None
    buf: List[str] = []

    def flush() -> None:
        nonlocal buf
        sql = "\n".join(buf).strip()
        buf = []
        if not key or not sql:
            return
        for stmt in _split_statements(sql):
            cur = conn.execute(stmt, params or {})
            cols = [d[0] for d in cur.description] if cur.description else []
            prev = out.get(key, (cols, []))
            out[key] = (cols, prev[1] + cur.fetchall())

    for line in script.splitlines():
        if line.strip().startswith("-- @query:"):
            flush()
            key = line.split(":", 1)[1].strip()
        else:
            buf.append(line)
    flush()
    return out


def _split_statements(sql: str) -> List[str]:
    """Split on top-level semicolons (quote-aware, minimal)."""
    parts, depth, start, i, n = [], 0, 0, 0, len(sql)
    while i < n:
        c = sql[i]
        if c == "'":
            i += 1
            while i < n and not (sql[i] == "'" and (i + 1 >= n or sql[i + 1] != "'")):
                i += 2 if sql[i] == "'" else 1
        elif c == ";" and depth == 0:
            parts.append(sql[start:i])
            start = i + 1
        elif c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    tail = sql[start:].strip()
    if tail:
        parts.append(tail)
    return [p.strip() for p in parts if p.strip()]
