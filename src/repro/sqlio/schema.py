"""Production schema (paper Appendix D): raw tables, views, FTS5, embeddings.

Single SQLite database; each chunk is one indexed unit (message, tool call,
or file snapshot); each source is a session. Embeddings live in a BLOB column
and are loaded into the in-memory matrix at startup (paper §3.2).
"""

from __future__ import annotations

import sqlite3
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

SCHEMA_SQL = """
CREATE TABLE IF NOT EXISTS _meta (key TEXT PRIMARY KEY, value TEXT);

CREATE TABLE IF NOT EXISTS _raw_sources (
    session_id   TEXT PRIMARY KEY,
    project      TEXT,
    title        TEXT,
    start_time   REAL,
    end_time     REAL,
    message_count INTEGER DEFAULT 0
);

CREATE TABLE IF NOT EXISTS _raw_chunks (
    id          INTEGER PRIMARY KEY,
    session_id  TEXT REFERENCES _raw_sources(session_id),
    type        TEXT,            -- user_prompt|assistant|tool_call|file
    content     TEXT,
    created_at  REAL,            -- unix seconds
    position    INTEGER,
    project     TEXT,
    tool_name   TEXT,
    file        TEXT,
    ext         TEXT,
    embedding   BLOB             -- float32 little-endian, d dims
);

CREATE TABLE IF NOT EXISTS _presets (
    name        TEXT PRIMARY KEY,
    description TEXT,
    params      TEXT,
    sql         TEXT
);

CREATE INDEX IF NOT EXISTS idx_chunks_type    ON _raw_chunks(type);
CREATE INDEX IF NOT EXISTS idx_chunks_project ON _raw_chunks(project);
CREATE INDEX IF NOT EXISTS idx_chunks_created ON _raw_chunks(created_at);
CREATE INDEX IF NOT EXISTS idx_chunks_session ON _raw_chunks(session_id);

CREATE VIEW IF NOT EXISTS chunks AS
    SELECT id, content, created_at AS timestamp, created_at, type, session_id,
           position, project, tool_name, file, ext
    FROM _raw_chunks;

CREATE VIEW IF NOT EXISTS messages AS
    SELECT c.id, c.content, c.created_at AS timestamp, c.created_at,
           c.session_id, c.position, c.project, s.title, s.message_count,
           c.tool_name, c.type
    FROM _raw_chunks c JOIN _raw_sources s USING (session_id)
    WHERE c.type IN ('user_prompt', 'assistant', 'tool_call');

CREATE VIEW IF NOT EXISTS files AS
    SELECT id, content, created_at AS timestamp, created_at, session_id,
           file, ext, position AS chunk_position
    FROM _raw_chunks WHERE type = 'file';

CREATE VIEW IF NOT EXISTS sessions AS
    SELECT s.session_id, s.project, s.title, s.message_count,
           s.start_time, s.end_time,
           (s.end_time - s.start_time) AS duration,
           COUNT(c.id) AS chunk_count
    FROM _raw_sources s LEFT JOIN _raw_chunks c USING (session_id)
    GROUP BY s.session_id;
"""

FTS_SQL = """
CREATE VIRTUAL TABLE IF NOT EXISTS chunks_fts USING fts5(
    content, content='_raw_chunks', content_rowid='id'
);
"""


def build_schema(conn: sqlite3.Connection, description: str = "") -> None:
    conn.executescript(SCHEMA_SQL)
    conn.executescript(FTS_SQL)
    conn.execute(
        "INSERT OR REPLACE INTO _meta (key, value) VALUES ('description', ?)",
        (description or "Agentic coding conversation history.",),
    )
    conn.commit()


def insert_chunks(
    conn: sqlite3.Connection,
    rows: Iterable[tuple],
    embeddings: Optional[np.ndarray] = None,
) -> None:
    """rows: (id, session_id, type, content, created_at, position, project,
    tool_name, file, ext); embeddings: (n, d) float32 aligned with rows."""
    rows = list(rows)
    blobs: Sequence[Optional[bytes]]
    if embeddings is not None:
        emb = np.ascontiguousarray(embeddings, dtype=np.float32)
        assert emb.shape[0] == len(rows), "rows/embeddings misaligned"
        blobs = [emb[i].tobytes() for i in range(len(rows))]
    else:
        blobs = [None] * len(rows)
    conn.executemany(
        "INSERT OR REPLACE INTO _raw_chunks "
        "(id, session_id, type, content, created_at, position, project,"
        " tool_name, file, ext, embedding) VALUES (?,?,?,?,?,?,?,?,?,?,?)",
        [r + (b,) for r, b in zip(rows, blobs)],
    )
    # external-content FTS5 needs explicit sync
    conn.executemany(
        "INSERT INTO chunks_fts (rowid, content) VALUES (?, ?)",
        [(r[0], r[3]) for r in rows],
    )
    conn.commit()


def delete_chunks(
    conn: sqlite3.Connection,
    ids: Sequence[int],
    *,
    fts_table: str = "chunks_fts",
) -> List[int]:
    """Remove chunks (rows + FTS sync). Returns the ids actually removed.

    The FTS5 index is external-content, so the 'delete' command needs the
    old content; rows are fetched first.  Callers keep the VectorCache in
    sync by tombstoning the same ids (``cache.delete(ids)``) — only the
    touched segments' masks change.
    """
    ids = [int(i) for i in ids]
    if not ids:
        return []
    ph = ",".join("?" * len(ids))
    rows = conn.execute(
        f"SELECT id, content FROM _raw_chunks WHERE id IN ({ph})", ids
    ).fetchall()
    conn.executemany(
        f"INSERT INTO {fts_table} ({fts_table}, rowid, content) "
        f"VALUES ('delete', ?, ?)",
        [(r[0], r[1] or "") for r in rows],
    )
    conn.executemany(
        "DELETE FROM _raw_chunks WHERE id = ?", [(r[0],) for r in rows]
    )
    conn.commit()
    return [r[0] for r in rows]


def insert_sources(conn: sqlite3.Connection, rows: Iterable[tuple]) -> None:
    """rows: (session_id, project, title, start_time, end_time, message_count)"""
    conn.executemany(
        "INSERT OR REPLACE INTO _raw_sources "
        "(session_id, project, title, start_time, end_time, message_count)"
        " VALUES (?,?,?,?,?,?)",
        rows,
    )
    conn.commit()


def load_embedding_matrix(
    conn: sqlite3.Connection, dim: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Startup load (paper §3.2): -> (ids, matrix, created_at)."""
    cur = conn.execute(
        "SELECT id, embedding, created_at FROM _raw_chunks "
        "WHERE embedding IS NOT NULL ORDER BY id"
    )
    ids, vecs, ts = [], [], []
    for cid, blob, created in cur:
        ids.append(cid)
        vecs.append(np.frombuffer(blob, dtype=np.float32, count=dim))
        ts.append(created or 0.0)
    if not ids:
        return (
            np.zeros(0, np.int64),
            np.zeros((0, dim), np.float32),
            np.zeros(0, np.float64),
        )
    return (
        np.asarray(ids, np.int64),
        np.stack(vecs).astype(np.float32),
        np.asarray(ts, np.float64),
    )
