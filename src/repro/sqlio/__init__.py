from repro.sqlio.schema import build_schema, load_embedding_matrix, insert_chunks
from repro.sqlio.presets import run_preset, PRESETS

__all__ = [
    "build_schema",
    "load_embedding_matrix",
    "insert_chunks",
    "run_preset",
    "PRESETS",
]
