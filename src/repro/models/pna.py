"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Multi-aggregator message passing: per node, the in-neighbor messages are
reduced with {mean, max, min, std} and each aggregate is scaled by
{identity, amplification, attenuation} degree scalers — 12 aggregate blocks
per layer, concatenated with the node state and mixed by a linear update.

JAX sparse is BCOO-only, so message passing is built directly on
``jax.ops.segment_sum/max/min`` over the edge index (kernel taxonomy §GNN)
— this IS the system's GNN substrate, not a stub. Padded edges point at a
sink node (data/graph.py), so static shapes jit cleanly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 75
    d_feat: int = 128
    n_classes: int = 16
    task: str = "node"            # node | graph
    n_graphs: int = 1             # graph task: graphs per batch (static)
    delta: float = 2.5            # mean log-degree normalizer (PNA eq. 5)
    dtype: Any = jnp.float32


AGGS = ("mean", "max", "min", "std")
N_SCALERS = 3  # identity, amplification, attenuation


def init_params(cfg: PNAConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, cfg.n_layers * 2 + 2)
    d = cfg.d_hidden

    def w(k, fan_in, fan_out):
        s = (2.0 / fan_in) ** 0.5
        return (jax.random.normal(k, (fan_in, fan_out), jnp.float32) * s).astype(cfg.dtype)

    params: Params = {
        "embed_w": w(keys[0], cfg.d_feat, d),
        "embed_b": jnp.zeros((d,), cfg.dtype),
        "layers": [],
        "readout_w": w(keys[1], d, cfg.n_classes),
        "readout_b": jnp.zeros((cfg.n_classes,), cfg.dtype),
    }
    for i in range(cfg.n_layers):
        params["layers"].append(
            {
                # message MLP over (h_src || h_dst)
                "msg_w": w(keys[2 + 2 * i], 2 * d, d),
                "msg_b": jnp.zeros((d,), cfg.dtype),
                # update over (h || 12 aggregate blocks)
                "upd_w": w(keys[3 + 2 * i], d + len(AGGS) * N_SCALERS * d, d),
                "upd_b": jnp.zeros((d,), cfg.dtype),
            }
        )
    return params


def _segment_reduce(msgs, dst, n_nodes, edge_w):
    """All four PNA aggregators in one pass over the edge list."""
    msgs = msgs * edge_w[:, None]
    s = jax.ops.segment_sum(msgs, dst, n_nodes)
    cnt = jax.ops.segment_sum(edge_w, dst, n_nodes)
    deg = jnp.maximum(cnt, 1.0)[:, None]
    mean = s / deg
    sq = jax.ops.segment_sum(msgs * msgs, dst, n_nodes) / deg
    std = jnp.sqrt(jax.nn.relu(sq - mean * mean) + 1e-5)
    # max/min: mask padded edges to +/- inf sentinels, then clean empties
    big = jnp.float32(1e30)
    mx = jax.ops.segment_max(jnp.where(edge_w[:, None] > 0, msgs, -big), dst, n_nodes)
    mn = jax.ops.segment_min(jnp.where(edge_w[:, None] > 0, msgs, big), dst, n_nodes)
    empty = (cnt < 0.5)[:, None]
    mx = jnp.where(empty | (mx <= -big), 0.0, mx)
    mn = jnp.where(empty | (mn >= big), 0.0, mn)
    return mean, mx, mn, std, cnt


def pna_layer(h, lp, edge_src, edge_dst, edge_w, cfg: PNAConfig):
    n = h.shape[0]
    m_in = jnp.concatenate([h[edge_src], h[edge_dst]], axis=-1)   # (E, 2d)
    msgs = jax.nn.relu(m_in @ lp["msg_w"] + lp["msg_b"])          # (E, d)
    mean, mx, mn, std, cnt = _segment_reduce(msgs, edge_dst, n, edge_w)
    agg = jnp.concatenate([mean, mx, mn, std], axis=-1)           # (N, 4d)
    logd = jnp.log1p(cnt)[:, None]
    amp = logd / cfg.delta
    att = cfg.delta / jnp.maximum(logd, 1e-5)
    scaled = jnp.concatenate([agg, agg * amp, agg * att], axis=-1)  # (N, 12d)
    upd_in = jnp.concatenate([h, scaled], axis=-1)
    return jax.nn.relu(upd_in @ lp["upd_w"] + lp["upd_b"]) + h    # residual


def forward(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: PNAConfig,
    rules: ShardingRules,
) -> jnp.ndarray:
    feats = batch["feats"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    edge_w = batch["edge_mask"].astype(cfg.dtype)
    h = jax.nn.relu(feats @ params["embed_w"] + params["embed_b"])
    h = constrain(h, rules, "nodes", None)
    for lp in params["layers"]:
        h = pna_layer(h, lp, src, dst, edge_w, cfg)
        h = constrain(h, rules, "nodes", None)
    if cfg.task == "graph":
        gid = batch["graph_ids"]
        w = batch["node_mask"].astype(cfg.dtype)[:, None]
        pooled = jax.ops.segment_sum(h * w, gid, cfg.n_graphs)
        cnt = jax.ops.segment_sum(w, gid, cfg.n_graphs)
        pooled = pooled / jnp.maximum(cnt, 1.0)                     # mean pool
        return pooled @ params["readout_w"] + params["readout_b"]   # (G, C)
    return h @ params["readout_w"] + params["readout_b"]           # (N, C)


def loss_fn(params, batch, cfg: PNAConfig, rules: ShardingRules) -> jnp.ndarray:
    logits = forward(params, batch, cfg, rules).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = logz - gold
    if cfg.task == "graph":
        return jnp.mean(ce)
    w = batch["node_mask"].astype(jnp.float32)
    return jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)
