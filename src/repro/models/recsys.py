"""RecSys model family: DLRM, BST, AutoInt, Two-Tower retrieval.

Substrate built here (JAX has neither nn.EmbeddingBag nor CSR sparse):
* ``embedding_bag`` — ragged multi-hot lookup via ``jnp.take`` +
  masked segment reduction (sum/mean), per kernel taxonomy §B.6/§B.11.
* Row-sharded embedding tables: big tables (Criteo 1TB / MLPerf: ~188M rows,
  ~24B embedding params) shard over every mesh axis via 'table_rows'.

The Two-Tower ``retrieval_cand`` path scores 1M candidates for one query —
exactly the paper's PEM setting — and routes through the fused
``pem_score`` + streaming ``topk`` kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------


def embedding_lookup(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Single-value lookup: (V, D) x (B,) -> (B, D)."""
    return jnp.take(table, idx, axis=0)


def embedding_bag(
    table: jnp.ndarray,       # (V, D)
    idx: jnp.ndarray,         # (B, L) int32, padded with -1
    mode: str = "sum",
) -> jnp.ndarray:
    """Manual EmbeddingBag: gather + masked reduce over the bag dim."""
    mask = (idx >= 0).astype(table.dtype)               # (B, L)
    safe = jnp.maximum(idx, 0)
    vecs = jnp.take(table, safe, axis=0)                # (B, L, D)
    s = jnp.sum(vecs * mask[..., None], axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    raise ValueError(mode)


def mlp(x: jnp.ndarray, ws: Sequence[jnp.ndarray], bs: Sequence[jnp.ndarray],
        final_act: bool = False) -> jnp.ndarray:
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i < len(ws) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _init_mlp(key, dims: Sequence[int], dtype) -> Tuple[List, List]:
    ws, bs = [], []
    keys = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        s = (2.0 / dims[i]) ** 0.5
        ws.append((jax.random.normal(keys[i], (dims[i], dims[i + 1]), jnp.float32) * s).astype(dtype))
        bs.append(jnp.zeros((dims[i + 1],), dtype))
    return ws, bs


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jax.nn.softplus(logits) - labels * logits
    )


# ---------------------------------------------------------------------------
# DLRM (MLPerf config) [arXiv:1906.00091]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    embed_dim: int = 128
    vocab_sizes: Tuple[int, ...] = ()
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def padded_vocab_sizes(self) -> Tuple[int, ...]:
        """Row counts padded to 512 so row-sharded tables split evenly on
        any production mesh (standard embedding-table padding); lookups
        only ever index < the published vocab size."""
        return tuple((v + 511) // 512 * 512 for v in self.vocab_sizes)


# tables smaller than this are replicated instead of row-sharded
_SHARD_MIN_ROWS = 4096


def dlrm_init(cfg: DLRMConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    tables = []
    tkeys = jax.random.split(k1, cfg.n_sparse)
    for i, v in enumerate(cfg.padded_vocab_sizes):
        s = 1.0 / (v ** 0.5)
        tables.append(
            (jax.random.uniform(tkeys[i], (v, cfg.embed_dim), jnp.float32, -s, s)).astype(cfg.dtype)
        )
    n_int = cfg.n_sparse + 1
    d_inter = (n_int * (n_int - 1)) // 2
    bw, bb = _init_mlp(k2, (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype)
    tw, tb = _init_mlp(k3, (cfg.bot_mlp[-1] + d_inter,) + cfg.top_mlp, cfg.dtype)
    return {"tables": tables, "bot_w": bw, "bot_b": bb, "top_w": tw, "top_b": tb}


def dlrm_shardings(cfg: DLRMConfig, rules: ShardingRules) -> Params:
    s = rules.spec
    return {
        "tables": [
            s("table_rows" if v >= _SHARD_MIN_ROWS else None, None)
            for v in cfg.padded_vocab_sizes
        ],
        "bot_w": [s(None, None)] * len(cfg.bot_mlp),
        "bot_b": [s(None)] * len(cfg.bot_mlp),
        "top_w": [s(None, None)] * len(cfg.top_mlp),
        "top_b": [s(None)] * len(cfg.top_mlp),
    }


def dlrm_forward(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: DLRMConfig, rules: ShardingRules) -> jnp.ndarray:
    dense = batch["dense"].astype(cfg.dtype)             # (B, 13)
    sparse = batch["sparse"]                             # (B, 26) int32
    x = mlp(dense, params["bot_w"], params["bot_b"], final_act=True)  # (B, D)
    embs = [embedding_lookup(t, sparse[:, i]) for i, t in enumerate(params["tables"])]
    z = jnp.stack([x] + embs, axis=1)                    # (B, 27, D)
    z = constrain(z, rules, "batch", None, None)
    inter = jnp.einsum("bnd,bmd->bnm", z, z)             # pairwise dots
    n_int = z.shape[1]
    iu, ju = jnp.triu_indices(n_int, k=1)
    flat = inter[:, iu, ju]                              # (B, n(n-1)/2)
    top_in = jnp.concatenate([x, flat], axis=1)
    return mlp(top_in, params["top_w"], params["top_b"])[:, 0]   # (B,)


def dlrm_loss(params, batch, cfg: DLRMConfig, rules) -> jnp.ndarray:
    return bce_with_logits(dlrm_forward(params, batch, cfg, rules), batch["labels"])


# ---------------------------------------------------------------------------
# BST — Behavior Sequence Transformer [arXiv:1905.06874]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str
    vocab_items: int = 2_000_000
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    d_ff: int = 128
    mlp_dims: Tuple[int, ...] = (1024, 512, 256, 1)
    n_other_feats: int = 8
    dtype: Any = jnp.float32


def bst_init(cfg: BSTConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 4 + 6 * cfg.n_blocks)
    D = cfg.embed_dim
    s = 1.0 / (cfg.vocab_items ** 0.5)
    p: Params = {
        "item_table": (jax.random.uniform(keys[0], (cfg.vocab_items, D), jnp.float32, -s, s)).astype(cfg.dtype),
        "pos_table": (jax.random.normal(keys[1], (cfg.seq_len + 1, D), jnp.float32) * 0.02).astype(cfg.dtype),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        kq, kk, kv, ko, k1, k2 = jax.random.split(keys[2 + i], 6)

        def w(k, a, b):
            return (jax.random.normal(k, (a, b), jnp.float32) * (2.0 / a) ** 0.5).astype(cfg.dtype)

        p["blocks"].append({
            "wq": w(kq, D, D), "wk": w(kk, D, D), "wv": w(kv, D, D), "wo": w(ko, D, D),
            "ff1": w(k1, D, cfg.d_ff), "ff2": w(k2, cfg.d_ff, D),
            "ln1": jnp.ones((D,), cfg.dtype), "ln2": jnp.ones((D,), cfg.dtype),
        })
    flat_in = (cfg.seq_len + 1) * D + cfg.n_other_feats
    mw, mb = _init_mlp(keys[-1], (flat_in,) + cfg.mlp_dims, cfg.dtype)
    p["mlp_w"], p["mlp_b"] = mw, mb
    return p


def _ln(x, scale):
    m = x.mean(-1, keepdims=True)
    v = ((x - m) ** 2).mean(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-6) * scale


def bst_forward(params: Params, batch: Dict[str, jnp.ndarray],
                cfg: BSTConfig, rules: ShardingRules) -> jnp.ndarray:
    hist = batch["hist"]                                  # (B, S) int32
    target = batch["target"]                              # (B,) int32
    other = batch["other"].astype(cfg.dtype)              # (B, n_other)
    B = hist.shape[0]
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # (B, S+1)
    x = embedding_lookup(params["item_table"], seq.reshape(-1)).reshape(B, cfg.seq_len + 1, -1)
    x = x + params["pos_table"][None]
    x = constrain(x, rules, "batch", None, None)
    H, D = cfg.n_heads, cfg.embed_dim
    hd = D // H
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(B, -1, H, hd)
        k = (h @ blk["wk"]).reshape(B, -1, H, hd)
        v = (h @ blk["wv"]).reshape(B, -1, H, hd)
        sc = jnp.einsum("bshd,bthd->bhst", q, k) * (hd ** -0.5)
        a = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(cfg.dtype)
        o = jnp.einsum("bhst,bthd->bshd", a, v).reshape(B, -1, D)
        x = x + o @ blk["wo"]
        h = _ln(x, blk["ln2"])
        x = x + jax.nn.relu(h @ blk["ff1"]) @ blk["ff2"]
    flat = jnp.concatenate([x.reshape(B, -1), other], axis=1)
    return mlp(flat, params["mlp_w"], params["mlp_b"])[:, 0]


def bst_loss(params, batch, cfg: BSTConfig, rules) -> jnp.ndarray:
    return bce_with_logits(bst_forward(params, batch, cfg, rules), batch["labels"])


# ---------------------------------------------------------------------------
# AutoInt [arXiv:1810.11921]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str
    n_fields: int = 39
    vocab_per_field: int = 100_000
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    dtype: Any = jnp.float32


def autoint_init(cfg: AutoIntConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 2 + cfg.n_attn_layers)
    s = 1.0 / (cfg.vocab_per_field ** 0.5)
    p: Params = {
        "table": (jax.random.uniform(
            keys[0], (cfg.n_fields * cfg.vocab_per_field, cfg.embed_dim),
            jnp.float32, -s, s)).astype(cfg.dtype),
        "layers": [],
    }
    d_in = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        kq, kk, kv, kr = jax.random.split(keys[1 + i], 4)

        def w(k, a, b):
            return (jax.random.normal(k, (a, b), jnp.float32) * (2.0 / a) ** 0.5).astype(cfg.dtype)

        p["layers"].append({
            "wq": w(kq, d_in, cfg.n_heads * cfg.d_attn),
            "wk": w(kk, d_in, cfg.n_heads * cfg.d_attn),
            "wv": w(kv, d_in, cfg.n_heads * cfg.d_attn),
            "wres": w(kr, d_in, cfg.n_heads * cfg.d_attn),
        })
        d_in = cfg.n_heads * cfg.d_attn
    kf = jax.random.split(keys[-1], 1)[0]
    p["out_w"] = (jax.random.normal(kf, (cfg.n_fields * d_in, 1), jnp.float32) * 0.02).astype(cfg.dtype)
    p["out_b"] = jnp.zeros((1,), cfg.dtype)
    return p


def autoint_forward(params: Params, batch: Dict[str, jnp.ndarray],
                    cfg: AutoIntConfig, rules: ShardingRules) -> jnp.ndarray:
    sparse = batch["sparse"]                               # (B, F) int32
    B, F = sparse.shape
    offset = jnp.arange(F, dtype=sparse.dtype) * cfg.vocab_per_field
    x = embedding_lookup(params["table"], (sparse + offset[None]).reshape(-1))
    x = x.reshape(B, F, cfg.embed_dim)
    x = constrain(x, rules, "batch", None, None)
    H, da = cfg.n_heads, cfg.d_attn
    for lp in params["layers"]:
        q = (x @ lp["wq"]).reshape(B, F, H, da)
        k = (x @ lp["wk"]).reshape(B, F, H, da)
        v = (x @ lp["wv"]).reshape(B, F, H, da)
        sc = jnp.einsum("bfhd,bghd->bhfg", q, k) * (da ** -0.5)
        a = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(cfg.dtype)
        o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, H * da)
        x = jax.nn.relu(o + x @ lp["wres"])
    return (x.reshape(B, -1) @ params["out_w"] + params["out_b"])[:, 0]


def autoint_loss(params, batch, cfg: AutoIntConfig, rules) -> jnp.ndarray:
    return bce_with_logits(autoint_forward(params, batch, cfg, rules), batch["labels"])


# ---------------------------------------------------------------------------
# Two-Tower retrieval [Yi et al., RecSys'19]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    vocab_user: int = 5_000_000
    vocab_item: int = 10_000_000
    hist_len: int = 20
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    dtype: Any = jnp.float32


def twotower_init(cfg: TwoTowerConfig, key: jax.Array) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    su = 1.0 / (cfg.vocab_user ** 0.5)
    si = 1.0 / (cfg.vocab_item ** 0.5)
    uw, ub = _init_mlp(k3, (2 * cfg.embed_dim,) + cfg.tower_mlp, cfg.dtype)
    iw, ib = _init_mlp(k4, (cfg.embed_dim,) + cfg.tower_mlp, cfg.dtype)
    return {
        "user_table": (jax.random.uniform(k1, (cfg.vocab_user, cfg.embed_dim), jnp.float32, -su, su)).astype(cfg.dtype),
        "item_table": (jax.random.uniform(k2, (cfg.vocab_item, cfg.embed_dim), jnp.float32, -si, si)).astype(cfg.dtype),
        "user_w": uw, "user_b": ub, "item_w": iw, "item_b": ib,
    }


def user_tower(params: Params, batch, cfg: TwoTowerConfig, rules) -> jnp.ndarray:
    uid = batch["user_id"]                                  # (B,)
    hist = batch["hist"]                                    # (B, L) item ids, -1 pad
    ue = embedding_lookup(params["user_table"], uid)
    he = embedding_bag(params["item_table"], hist, mode="mean")
    x = jnp.concatenate([ue, he], axis=1)
    u = mlp(x, params["user_w"], params["user_b"])
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_tower(params: Params, item_ids: jnp.ndarray, cfg: TwoTowerConfig, rules) -> jnp.ndarray:
    ie = embedding_lookup(params["item_table"], item_ids)
    v = mlp(ie, params["item_w"], params["item_b"])
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, batch, cfg: TwoTowerConfig, rules) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction."""
    u = user_tower(params, batch, cfg, rules)               # (B, D)
    v = item_tower(params, batch["pos_item"], cfg, rules)   # (B, D)
    logits = (u @ v.T) / 0.05                               # temperature
    logq = batch.get("logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def retrieval_scores(
    params: Params,
    batch,
    candidate_matrix: jnp.ndarray,   # (N_cand, D) PRECOMPUTED item-tower out
    cfg: TwoTowerConfig,
    rules: ShardingRules,
) -> jnp.ndarray:
    """Score one/few queries against the full candidate corpus.

    This is the paper's Phase-2 surface: the candidate matrix is the corpus,
    the user vector is the query; PEM modulations compose on the resulting
    scores (serve/retrieval.py wires suppress/decay/MMR through the fused
    kernels on this exact path)."""
    u = user_tower(params, batch, cfg, rules)               # (B, D)
    cand = constrain(candidate_matrix, rules, "candidates", None)
    return cand @ u.T                                       # (N_cand, B)
