"""Shared transformer layers: RMSNorm, RoPE, chunked GQA attention, MLP/MoE.

Pure-functional (params are pytrees of arrays); sharding is expressed through
logical-axis constraints (dist/sharding.py) so the same code runs on 1 CPU
device (smoke tests) and the 512-chip production mesh (dry-run).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # decode (S==1): merge single-token groups into groups of this many
    # tokens before routing — capacity slots shrink by the same factor
    # (E*C per 1-token group is ~E/k x waste; §Perf qwen3-2).
    decode_group: int = 0


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp_type: str = "swiglu"          # swiglu | gelu | relu2
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16         # activation/weight compute dtype
    q_chunk: int = 1024               # attention query-chunk (memory ceiling)
    remat: bool = True                # checkpoint each layer in train_step
    remat_policy: str = "full"        # full | dots  (§Perf granite-1)
    tie_embeddings: bool = False
    scan_unroll: int = 1              # lax.scan unroll (cost-analysis runs
                                      # set unroll=n_layers: see dryrun.py)

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        n_mats = 3 if self.mlp_type == "swiglu" else 2
        if self.moe is not None:
            mlp = self.moe.n_experts * n_mats * d * f + d * self.moe.n_experts
        else:
            mlp = n_mats * d * f
        embed = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + mlp + 2 * d) + embed + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_type == "swiglu" else 2
        dense_total = self.n_params - self.n_layers * self.moe.n_experts * n_mats * d * f
        return dense_total + self.n_layers * self.moe.top_k * n_mats * d * f


# ---------------------------------------------------------------------------
# Basic ops
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _mlp_act(cfg: LMConfig, wi_out: jnp.ndarray, wg_out: Optional[jnp.ndarray]) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        return jax.nn.silu(wg_out) * wi_out
    if cfg.mlp_type == "gelu":
        return jax.nn.gelu(wi_out)
    if cfg.mlp_type == "relu2":
        r = jax.nn.relu(wi_out)
        return r * r
    raise ValueError(cfg.mlp_type)


# ---------------------------------------------------------------------------
# Attention (GQA + RoPE), query-chunked for long-context memory control
# ---------------------------------------------------------------------------


def attention(
    q: jnp.ndarray,              # (B, S, H, hd) post-RoPE
    k: jnp.ndarray,              # (B, T, K, hd) post-RoPE
    v: jnp.ndarray,              # (B, T, K, hd)
    *,
    q_offset: jnp.ndarray,       # scalar: absolute position of q[:, 0]
    kv_len: Optional[jnp.ndarray] = None,  # valid cache length (decode)
    causal: bool = True,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Chunked softmax attention: scans query chunks so the live score
    block is (B, K, G, C, T) instead of (B, H, S, T) — the memory ceiling
    that makes prefill_32k lowerable. FLOPs identical to full attention."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    kv_pos = jnp.arange(T)
    kv_valid = kv_pos < (kv_len if kv_len is not None else T)

    def one_chunk(qc: jnp.ndarray, c0: jnp.ndarray) -> jnp.ndarray:
        # qc: (B, C, H, hd); c0: absolute position of qc[:, 0]
        C = qc.shape[1]
        qg = qc.reshape(B, C, K, G, hd)
        s = jnp.einsum("bckgh,btkh->bkgct", qg, k).astype(jnp.float32) * scale
        q_pos = c0 + jnp.arange(C)
        mask = kv_valid[None, :]
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgct,btkh->bckgh", p, v)
        return o.reshape(B, C, H, hd)

    if S <= q_chunk:
        return one_chunk(q, q_offset)
    assert S % q_chunk == 0, (S, q_chunk)
    n_chunks = S // q_chunk
    qs = q.reshape(B, n_chunks, q_chunk, H, hd)

    def body(i, _):
        return one_chunk(qs[:, i], q_offset + i * q_chunk)

    out = jax.lax.map(lambda i: body(i, None), jnp.arange(n_chunks))  # (n, B, C, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attention_block(
    x: jnp.ndarray,              # (B, S, D)
    p: Params,                   # wq, wk, wv, wo, attn_norm
    cfg: LMConfig,
    rules: ShardingRules,
    *,
    positions: jnp.ndarray,      # (S,) absolute positions
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (k,v) (B,T,K,hd)
    cache_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pre-norm attention with optional KV cache. Returns (out, new_kv)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    kx = (h @ p["wk"]).reshape(B, S, K, hd)
    vx = (h @ p["wv"]).reshape(B, S, K, hd)
    q = rope(q, positions[None, :], cfg.rope_theta)
    kx = rope(kx, positions[None, :], cfg.rope_theta)
    q = constrain(q, rules, "batch", None,
                  rules.if_divisible("heads", H), None)
    kx = constrain(kx, rules, "batch", rules.if_divisible("seq", S),
                   rules.if_divisible("kv_heads", K), None)

    if cache is not None:
        ck, cv = cache
        start = cache_len if cache_len is not None else 0
        ck = jax.lax.dynamic_update_slice(ck, kx, (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vx, (0, start, 0, 0))
        kv_len = (cache_len if cache_len is not None else 0) + S
        o = attention(
            q, ck, cv, q_offset=positions[0], kv_len=kv_len,
            causal=True, q_chunk=cfg.q_chunk,
        )
        new_kv = (ck, cv)
    else:
        o = attention(q, kx, vx, q_offset=positions[0], causal=True,
                      q_chunk=cfg.q_chunk)
        new_kv = (kx, vx)

    out = o.reshape(B, S, H * hd) @ p["wo"]
    return constrain(out, rules, "batch", "seq", "act_embed"), new_kv


# ---------------------------------------------------------------------------
# Dense MLP and MoE (scatter-dispatch, capacity-dropped, EP over 'expert')
# ---------------------------------------------------------------------------


def dense_mlp(x: jnp.ndarray, p: Params, cfg: LMConfig, rules: ShardingRules) -> jnp.ndarray:
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    wi_out = h @ p["wi"]
    wg_out = h @ p["wg"] if cfg.mlp_type == "swiglu" else None
    act = _mlp_act(cfg, wi_out, wg_out)
    act = constrain(act, rules, "batch", "seq", "ff")
    return act @ p["wo_mlp"]


def moe_mlp(x: jnp.ndarray, p: Params, cfg: LMConfig, rules: ShardingRules) -> jnp.ndarray:
    """Token-choice top-k MoE with per-GROUP capacity (GShard/T5X grouping).

    Tokens are grouped by batch row; routing positions are a cumsum over the
    group's S*k slots only — local to the 'batch' shard, so no cross-device
    prefix sum (a flat cumsum over all B*S*k slots was measured at ~200x
    useful FLOPs under SPMD; see EXPERIMENTS.md §Perf, iteration qwen3-0).
    Dispatch is a vmapped scatter-add into (E, C, D) slots; combine is a
    gather. Expert GEMMs run as einsums with E sharded over 'model' (EP) and
    groups over 'batch' — the dispatch boundary is where the all-to-all the
    roofline's collective term accounts for appears.
    """
    assert cfg.moe is not None
    B, S, D = x.shape
    orig_shape = (B, S, D)
    g = cfg.moe.decode_group
    if S == 1 and g > 1 and B % g == 0:
        x = x.reshape(B // g, g, D)   # (G groups, g tokens) — slots /g
        B, S = B // g, g
    E, topk = cfg.moe.n_experts, cfg.moe.top_k
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)

    router_logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # (B, S, E)
    gates, eidx = jax.lax.top_k(probs, topk)                # (B, S, k)
    gates = (gates / (gates.sum(-1, keepdims=True) + 1e-9)).astype(x.dtype)

    C = max(topk, int(cfg.moe.capacity_factor * S * topk / E))
    eflat = eidx.reshape(B, S * topk)                       # token-major slots
    onehot = jax.nn.one_hot(eflat, E, dtype=jnp.int32)      # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                    # rank within group
    pos = jnp.take_along_axis(pos, eflat[..., None], axis=2)[..., 0]
    keep = pos < C
    slot = jnp.where(keep, eflat * C + pos, E * C)          # E*C = drop slot

    trep = jnp.repeat(h, topk, axis=1)                      # (B, S*k, D)

    def scatter_group(slots, tok):
        return jnp.zeros((E * C + 1, D), x.dtype).at[slots].add(tok)

    buf = jax.vmap(scatter_group)(slot, trep)               # (B, E*C+1, D)
    xe = buf[:, : E * C].reshape(B, E, C, D)
    xe = constrain(xe, rules, "batch", "expert", None, None)

    wi_out = jnp.einsum("becd,edf->becf", xe, p["wi"])
    wg_out = jnp.einsum("becd,edf->becf", xe, p["wg"]) if cfg.mlp_type == "swiglu" else None
    act = _mlp_act(cfg, wi_out, wg_out)
    ye = jnp.einsum("becf,efd->becd", act, p["wo_mlp"])
    ye = constrain(ye, rules, "batch", "expert", None, None)

    out_slots = jnp.concatenate(
        [ye.reshape(B, E * C, D), jnp.zeros((B, 1, D), x.dtype)], axis=1
    )
    y = jnp.take_along_axis(out_slots, slot[..., None], axis=1)  # (B, S*k, D)
    y = (y.reshape(B, S, topk, D) * gates[..., None]).sum(axis=2)
    return y.reshape(orig_shape)


def mlp_block(x: jnp.ndarray, p: Params, cfg: LMConfig, rules: ShardingRules) -> jnp.ndarray:
    if cfg.moe is not None:
        return moe_mlp(x, p, cfg, rules)
    return dense_mlp(x, p, cfg, rules)
