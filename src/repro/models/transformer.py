"""Decoder-only LM family (dense + MoE, GQA), shared by all 5 LM archs.

Layers are scan-stacked (params carry a leading (L, ...) dim) so 88-94-layer
configs lower as one rolled loop — compile time stays flat across depths and
remat policy applies to the scan body.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, constrain
from repro.models.layers import (
    LMConfig,
    Params,
    attention_block,
    mlp_block,
    rms_norm,
)


# ---------------------------------------------------------------------------
# Parameter init (shape-only compatible: wrap with jax.eval_shape for dry-run)
# ---------------------------------------------------------------------------


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    H, K, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    keys = jax.random.split(key, 12)
    dt = cfg.dtype

    def norm_init(*shape):
        return jnp.ones(shape, dt)

    def w(key, *shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    layers: Dict[str, jnp.ndarray] = {
        "attn_norm": norm_init(L, d),
        "mlp_norm": norm_init(L, d),
        "wq": w(keys[0], L, d, H * hd),
        "wk": w(keys[1], L, d, K * hd),
        "wv": w(keys[2], L, d, K * hd),
        "wo": w(keys[3], L, H * hd, d),
    }
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        layers["router"] = w(keys[4], L, d, E)
        layers["wi"] = w(keys[5], L, E, d, cfg.d_ff)
        if cfg.mlp_type == "swiglu":
            layers["wg"] = w(keys[6], L, E, d, cfg.d_ff)
        layers["wo_mlp"] = w(keys[7], L, E, cfg.d_ff, d)
    else:
        layers["wi"] = w(keys[5], L, d, cfg.d_ff)
        if cfg.mlp_type == "swiglu":
            layers["wg"] = w(keys[6], L, d, cfg.d_ff)
        layers["wo_mlp"] = w(keys[7], L, cfg.d_ff, d)

    params: Params = {
        "embed": w(keys[8], cfg.vocab, d),
        "final_norm": norm_init(d),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = w(keys[9], d, cfg.vocab)
    return params


def param_shardings(cfg: LMConfig, rules: ShardingRules) -> Params:
    """PartitionSpec pytree matching init_params (2-D FSDP x TP layout).

    Every sharded dim is divisibility-guarded: input shardings require the
    dim to split evenly (e.g. granite-moe's vocab 49155 cannot shard over
    16 — it replicates instead; all headline weight dims do divide)."""
    s = rules.spec
    d = rules.if_divisible
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    qdim = cfg.n_heads * cfg.head_dim
    kdim = cfg.n_kv_heads * cfg.head_dim
    emb_d = d("embed", D)
    layers = {
        "attn_norm": s("stack", None),
        "mlp_norm": s("stack", None),
        "wq": s("stack", emb_d, d("heads", qdim)),
        "wk": s("stack", emb_d, d("kv_heads", kdim)),
        "wv": s("stack", emb_d, d("kv_heads", kdim)),
        "wo": s("stack", d("heads", qdim), emb_d),
    }
    if cfg.moe is not None:
        # 'moe_ff' maps expert-FFN columns; default None (pure EP + FSDP on
        # d_model). The 'serve_weights' variant maps it to 'data' so serving
        # weights are FULLY resident (EPxTP) — no per-step FSDP all-gather
        # (§Perf qwen3-decode-1).
        moe_f = d("moe_ff", F)
        layers["router"] = s("stack", emb_d, None)
        layers["wi"] = s("stack", d("expert", cfg.moe.n_experts), emb_d, moe_f)
        if cfg.mlp_type == "swiglu":
            layers["wg"] = s("stack", d("expert", cfg.moe.n_experts), emb_d, moe_f)
        layers["wo_mlp"] = s("stack", d("expert", cfg.moe.n_experts), moe_f, emb_d)
    else:
        layers["wi"] = s("stack", emb_d, d("ff", F))
        if cfg.mlp_type == "swiglu":
            layers["wg"] = s("stack", emb_d, d("ff", F))
        layers["wo_mlp"] = s("stack", d("ff", F), emb_d)
    out: Params = {
        "embed": s(d("vocab", V), emb_d),
        "final_norm": s(None),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        out["unembed"] = s(emb_d, d("vocab", V))
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _layer_fn(cfg: LMConfig, rules: ShardingRules, positions, cache_len, collect: bool):
    def fn(x, inputs):
        if len(inputs) == 3:  # with cache
            lp, ck, cv = inputs
            a, (nk, nv) = attention_block(
                x, lp, cfg, rules, positions=positions,
                cache=(ck, cv), cache_len=cache_len,
            )
        else:
            (lp,) = inputs
            a, (nk, nv) = attention_block(
                x, lp, cfg, rules, positions=positions,
            )
        x = x + a
        x = x + mlp_block(x, lp, cfg, rules)
        x = constrain(x, rules, "batch",
                      rules.if_divisible("seq", x.shape[1]), "act_embed")
        # Only materialize the stacked KV output when the caller needs a
        # cache — train_step must not pay (L,B,S,K,hd) HBM for nothing.
        return x, ((nk, nv) if collect else None)

    return fn


def forward(
    params: Params,
    tokens: jnp.ndarray,                 # (B, S) int32
    cfg: LMConfig,
    rules: ShardingRules,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (L,B,T,K,hd) x2
    cache_len: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    seq_ax = rules.if_divisible("seq", S)
    x = constrain(x, rules, "batch", seq_ax, "act_embed")

    fn = _layer_fn(cfg, rules, positions, cache_len, return_cache)
    if cfg.remat:
        # 'full' recomputes the whole layer in bwd (min memory, +1/3 flops);
        # 'dots' saves matmul outputs and recomputes only elementwise ops
        # (≈0 extra matmul flops, modest activation memory) — §Perf granite-1.
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        fn = jax.checkpoint(fn, prevent_cse=False, policy=policy)

    if cache is not None:
        xs = (params["layers"], cache[0], cache[1])
    else:
        xs = (params["layers"],)
    x, new_cache = jax.lax.scan(fn, x, xs, unroll=cfg.scan_unroll)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = x @ unembed.astype(cfg.dtype)                   # (B, S, V)
    logits = constrain(logits, rules, "batch", seq_ax,
                       rules.if_divisible("vocab", cfg.vocab))
    if return_cache:
        return logits, new_cache
    return logits


def lm_loss(
    params: Params,
    batch: Dict[str, jnp.ndarray],        # tokens (B,S), labels (B,S)
    cfg: LMConfig,
    rules: ShardingRules,
) -> jnp.ndarray:
    logits = forward(params, batch["tokens"], cfg, rules).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def prefill_step(
    params: Params,
    tokens: jnp.ndarray,                  # (B, S) the prompt
    cfg: LMConfig,
    rules: ShardingRules,
):
    """Prompt pass: returns (last-position logits, KV cache (L,B,S,K,hd))."""
    logits, cache = forward(params, tokens, cfg, rules, return_cache=True)
    return logits[:, -1], cache


def decode_step(
    params: Params,
    token: jnp.ndarray,                   # (B, 1) newest token
    cache: Tuple[jnp.ndarray, jnp.ndarray],  # (L,B,T,K,hd) x2, T = max ctx
    cache_len: jnp.ndarray,               # scalar int32: current cache fill
    cfg: LMConfig,
    rules: ShardingRules,
):
    """One autoregressive step against a pre-filled KV cache.

    Cost is O(T·d) per token — linear in context, which is why the
    long_500k *decode* cells remain runnable for full-attention archs
    (DESIGN.md §3.5) even though 500k *training* would be quadratic.
    """
    positions = cache_len + jnp.arange(1)
    logits, new_cache = forward(
        params, token, cfg, rules,
        positions=positions, cache=cache, cache_len=cache_len,
        return_cache=True,
    )
    return logits[:, -1], new_cache


def make_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Empty KV cache pytree (L, B, T, K, hd) x 2."""
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, dt), jnp.zeros(shape, dt)


def cache_shardings(cfg: LMConfig, rules: ShardingRules):
    spec = rules.spec("stack", "batch", "seq",
                      rules.if_divisible("kv_heads", cfg.n_kv_heads), None)
    return spec, spec
