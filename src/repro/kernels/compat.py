"""Pallas API compatibility across JAX versions.

The TPU compiler-params dataclass was renamed: older releases expose
``pltpu.TPUCompilerParams``, newer ones ``pltpu.CompilerParams``.  Kernels
import :func:`tpu_compiler_params` so they build under either name.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(**kwargs):
    """Build the TPU CompilerParams object under whichever name exists."""
    return _COMPILER_PARAMS_CLS(**kwargs)
