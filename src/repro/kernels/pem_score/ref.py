"""Pure-jnp oracle for the fused PEM scoring kernel.

Semantics (batched generalization of paper Table 1, fixed order):

    scores[n, b] = decay[n] * (M[n] . q_pre[:, b]) + (M[n] . q_sup[:, b])

where, per query b,
    q_pre = (1-blend)*query + blend*trajectory_direction   (post-centroid)
    q_sup = -sum_i w_i * suppress_direction_i
are the two *effective vectors* that the linearity of trajectory/suppress
allows us to fold all directions into (see DESIGN.md §2.1). ``decay`` is the
reciprocal temporal factor 1/(1 + days/half_life), or ones.
"""

from __future__ import annotations

import jax.numpy as jnp


def pem_score_ref(
    matrix: jnp.ndarray,   # (N, d)  corpus embeddings (any float dtype)
    q_pre: jnp.ndarray,    # (d, B)  pre-decay effective vectors
    q_sup: jnp.ndarray,    # (d, B)  post-decay (suppress) effective vectors
    decay: jnp.ndarray,    # (N,)    temporal factor (ones if no decay)
) -> jnp.ndarray:          # (N, B)  float32 scores
    m = matrix.astype(jnp.float32)
    pre = m @ q_pre.astype(jnp.float32)
    sup = m @ q_sup.astype(jnp.float32)
    return decay.astype(jnp.float32)[:, None] * pre + sup
