"""Public jit'd wrapper for the fused PEM scoring kernel.

Handles: folding a :class:`~repro.core.modulations.ModulationPlan` batch into
the two effective vectors, padding (N -> block_n multiple, B -> block_b
multiple), dtype policy (bf16 corpus matrix, f32 accumulation), and the
interpret switch for CPU validation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modulations import fold_plan, fold_plans  # noqa: F401  (re-export)
from repro.kernels.pem_score.kernel import BLOCK_B, BLOCK_N, pem_score_pallas
from repro.kernels.pem_score.ref import pem_score_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_n", "block_b", "interpret", "use_kernel"))
def pem_score(
    matrix: jnp.ndarray,          # (N, d)
    q_pre: jnp.ndarray,           # (d, B)
    q_sup: jnp.ndarray,           # (d, B)
    decay: Optional[jnp.ndarray] = None,   # (N,) or None
    *,
    block_n: int = BLOCK_N,
    block_b: int = BLOCK_B,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Batched modulated scores (N, B), padding-safe public entry point."""
    n, d = matrix.shape
    b = q_pre.shape[1]
    if decay is None:
        decay = jnp.ones((n,), jnp.float32)
    if not use_kernel:
        return pem_score_ref(matrix, q_pre, q_sup, decay)

    n_pad = _round_up(n, block_n)
    b_pad = _round_up(b, block_b)
    if n_pad != n:
        matrix = jnp.pad(matrix, ((0, n_pad - n), (0, 0)))
        decay = jnp.pad(decay, (0, n_pad - n))
    if b_pad != b:
        q_pre = jnp.pad(q_pre, ((0, 0), (0, b_pad - b)))
        q_sup = jnp.pad(q_sup, ((0, 0), (0, b_pad - b)))
    out = pem_score_pallas(
        matrix, q_pre, q_sup, decay,
        block_n=block_n, block_b=block_b, interpret=interpret,
    )
    return out[:n, :b]


def decay_factor(days_ago: jnp.ndarray, half_life: Optional[float]) -> jnp.ndarray:
    """Reciprocal decay (paper Table 1); ones when decay is off."""
    if half_life is None:
        return jnp.ones_like(days_ago, dtype=jnp.float32)
    return (1.0 / (1.0 + days_ago / half_life)).astype(jnp.float32)
