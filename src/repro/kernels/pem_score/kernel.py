"""Fused PEM scoring Pallas kernel (TPU target, interpret-validated on CPU).

One pass over the corpus matrix computes modulated scores for a whole batch
of queries:

    out[n, b] = decay[n] * (M[n, :] . Qpre[:, b]) + M[n, :] . Qsup[:, b]

TPU mapping (DESIGN.md §2.1):
* corpus tile (BLOCK_N x d) streams HBM->VMEM exactly once per query block —
  vs the paper's numpy engine which re-reads M for every direction;
* d = 128 Matryoshka dims align exactly with MXU lanes; both matmuls hit the
  MXU with fp32 accumulation (``preferred_element_type``);
* decay multiply + sum is a VPU epilogue fused in-register;
* grid is fully parallel (no cross-block state).

VMEM budget at defaults (BLOCK_N=1024, d=128, BLOCK_B=128, bf16 matrix):
M tile 256KB + Q tiles 128KB + out tile 512KB + decay 4KB << 16MB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

BLOCK_N = 1024   # corpus rows per tile (multiple of 8 sublanes)
BLOCK_B = 128    # query columns per tile (multiple of 128 lanes)


def _pem_score_kernel(m_ref, qpre_ref, qsup_ref, decay_ref, out_ref):
    m = m_ref[...].astype(jnp.float32)                       # (bn, d)
    pre = jnp.dot(m, qpre_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)        # (bn, bq) MXU
    sup = jnp.dot(m, qsup_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)        # (bn, bq) MXU
    out_ref[...] = decay_ref[...] * pre + sup                # VPU epilogue


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_b", "interpret")
)
def pem_score_pallas(
    matrix: jnp.ndarray,   # (N, d), N % block_n == 0
    q_pre: jnp.ndarray,    # (d, B), B % block_b == 0
    q_sup: jnp.ndarray,    # (d, B)
    decay: jnp.ndarray,    # (N,)
    *,
    block_n: int = BLOCK_N,
    block_b: int = BLOCK_B,
    interpret: bool = False,
) -> jnp.ndarray:
    n, d = matrix.shape
    b = q_pre.shape[1]
    assert n % block_n == 0 and b % block_b == 0, (n, b, block_n, block_b)
    grid = (n // block_n, b // block_b)
    return pl.pallas_call(
        _pem_score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_b), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_b), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="pem_score",
    )(matrix, q_pre, q_sup, decay.reshape(n, 1).astype(jnp.float32))
