"""Public jit'd wrapper for MMR selection: pool padding + masking."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mmr.kernel import NEG, mmr_pallas
from repro.kernels.mmr.ref import mmr_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k", "lam", "interpret", "use_kernel"))
def mmr_select(
    embeds: jnp.ndarray,  # (B, n, d) pool embeddings (L2-normalized)
    rel: jnp.ndarray,     # (B, n) relevance scores
    k: int,
    lam: float = 0.7,
    *,
    interpret: bool = False,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MMR-select k of n (selection order) -> (indices int32, mmr scores)."""
    b, n, d = embeds.shape
    assert k <= n, (k, n)
    if not use_kernel:
        return mmr_ref(embeds, rel, k, lam)
    n_pad = _round_up(n, 128)
    d_pad = _round_up(d, 128)
    if (n_pad, d_pad) != (n, d):
        embeds = jnp.pad(embeds, ((0, 0), (0, n_pad - n), (0, d_pad - d)))
        # Padded rows: rel = NEG so they are never argmaxed while k <= n.
        rel = jnp.pad(rel, ((0, 0), (0, n_pad - n)), constant_values=NEG)
    return mmr_pallas(
        embeds.astype(jnp.float32), rel.astype(jnp.float32), k, lam,
        interpret=interpret,
    )
