"""MMR diverse-selection Pallas kernel (TPU target).

`diverse` is the paper's only modulation with data-dependent control flow:
k iterations of (argmax over pool) -> (rank-1 similarity update). Pool sizes
are small (3x oversample of K=500 -> n <= 4096), so the WHOLE pool lives in
VMEM and the loop never touches HBM:

* pool embeddings tile  (n x d)  : <= 4096 x 128 x 4B = 2MB VMEM
* the selected row e[j] is extracted MXU-style with a one-hot matmul
  (onehot(j) @ E), avoiding dynamic gather which TPUs dislike;
* similarity update  E @ e[j]  is a (n x d)x(d,) matvec on the MXU;
* running state (max_sim, taken) stays in VMEM scratch across iterations.

Grid: one program per query (fully parallel across the serving batch).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG = -1e30


def _mmr_kernel(e_ref, rel_ref, idx_out, val_out, *, k: int, lam: float):
    e = e_ref[0].astype(jnp.float32)          # (n, d)
    rel = rel_ref[...].astype(jnp.float32)    # (1, n)
    n = rel.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    invalid = rel <= NEG * 0.5                # NEG-padded slots

    def body(i, carry):
        max_sim, taken = carry                # (1, n), (1, n) bool
        penalty = jnp.where(max_sim <= NEG * 0.5, 0.0, max_sim)
        mmr = lam * rel - (1.0 - lam) * penalty
        # padding must stay NEG even at lam=0, where lam*rel zeroes the
        # sentinel and -penalty alone would leave padded slots finite
        mmr = jnp.where(jnp.logical_or(taken, invalid), NEG, mmr)
        j = jnp.argmax(mmr[0]).astype(jnp.int32)
        chosen = iota == j                    # (1, n) one-hot row mask
        # e[j] without dynamic gather: onehot(j) @ E -> (1, d) on the MXU.
        ej = jnp.dot(chosen.astype(jnp.float32), e,
                     preferred_element_type=jnp.float32)
        sim_j = jnp.dot(e, ej[0], preferred_element_type=jnp.float32)  # (n,)
        max_sim = jnp.maximum(max_sim, sim_j[None, :])
        taken = jnp.logical_or(taken, chosen)
        idx_out[0, i] = j
        val_out[0, i] = jnp.max(mmr[0])
        return max_sim, taken

    init = (jnp.full((1, n), NEG, jnp.float32), jnp.zeros((1, n), bool))
    jax.lax.fori_loop(0, k, body, init)


@functools.partial(jax.jit, static_argnames=("k", "lam", "interpret"))
def mmr_pallas(
    embeds: jnp.ndarray,  # (B, n, d)
    rel: jnp.ndarray,     # (B, n)
    k: int,
    lam: float = 0.7,
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, n, d = embeds.shape
    kern = functools.partial(_mmr_kernel, k=k, lam=lam)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="mmr_select",
    )(embeds, rel)
