"""Pure-jnp oracle for MMR iterative selection (paper Table 1, `diverse`).

    score_i = lam * rel_i - (1 - lam) * max_{j in selected} sim(i, j)

Iteratively argmax over the unselected pool; first pick is pure relevance
(empty-selection max_sim contributes 0, matching `mmr_select_np`).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def mmr_ref(
    embeds: jnp.ndarray,   # (B, n, d) L2-normalized pool embeddings
    rel: jnp.ndarray,      # (B, n)    relevance (modulated scores)
    k: int,
    lam: float = 0.7,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (indices (B, k) int32 in selection order, mmr scores (B, k))."""

    def one(e, r):
        n = r.shape[0]

        def body(i, carry):
            max_sim, taken, out_idx, out_val = carry
            # Empty-selection sentinel contributes 0 penalty; a genuinely
            # negative max_sim is kept (diversity bonus), matching
            # modulations.mmr_select_np exactly.
            penalty = jnp.where(max_sim <= NEG * 0.5, 0.0, max_sim)
            mmr = lam * r - (1.0 - lam) * penalty
            mmr = jnp.where(taken, NEG, mmr)
            j = jnp.argmax(mmr)
            sim_j = e @ e[j]
            max_sim = jnp.maximum(max_sim, sim_j)
            taken = taken.at[j].set(True)
            out_idx = out_idx.at[i].set(j.astype(jnp.int32))
            out_val = out_val.at[i].set(mmr[j])
            return max_sim, taken, out_idx, out_val

        init = (
            jnp.full((n,), NEG, jnp.float32),
            jnp.zeros((n,), bool),
            jnp.zeros((k,), jnp.int32),
            jnp.zeros((k,), jnp.float32),
        )
        _, _, idx, val = jax.lax.fori_loop(0, k, body, init)
        return idx, val

    return jax.vmap(one)(embeds.astype(jnp.float32), rel.astype(jnp.float32))
