"""Public jit'd wrapper for streaming top-K: padding + tie/pad safety."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.topk.kernel import BLOCK_B, BLOCK_N, topk_pallas
from repro.kernels.topk.ref import topk_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_n", "interpret", "use_kernel"))
def topk(
    scores: jnp.ndarray,  # (B, N)
    k: int,
    *,
    block_b: int = BLOCK_B,
    block_n: int = BLOCK_N,
    interpret: bool = False,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise top-k of a score panel; (values desc, int32 indices)."""
    b, n = scores.shape
    if not use_kernel:
        return topk_ref(scores, k)
    b_pad = _round_up(b, block_b)
    n_pad = _round_up(max(n, k), block_n)
    padded = jnp.full((b_pad, n_pad), -jnp.inf, scores.dtype)
    padded = padded.at[:b, :n].set(scores)
    v, i = topk_pallas(
        padded.astype(jnp.float32), k,
        block_b=block_b, block_n=block_n, interpret=interpret,
    )
    return v[:b], i[:b]
