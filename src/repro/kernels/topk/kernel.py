"""Streaming top-K Pallas kernel (TPU target).

The paper's engine materializes all N scores in memory and argpartitions.
On TPU we never spill the (B, N) score panel back to HBM: the scoring grid
streams blocks of N, and this kernel keeps a per-query running top-K buffer
in VMEM scratch, merging each incoming block with ``lax.top_k`` over the
(K + BLOCK_N) concatenation. HBM sees only the final (B, K) candidates.

Grid: (B blocks [parallel], N blocks [arbitrary/sequential innermost]).
Scratch persists across the sequential N dimension; it is initialized at
n==0 and flushed to the output block at the last N step (standard Pallas
accumulator pattern, cf. flash-attention).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

BLOCK_B = 8      # queries per tile (sublane-friendly)
BLOCK_N = 2048   # corpus scores per tile (lane multiple)


def _topk_kernel(s_ref, vals_out, idx_out, vals_s, idx_s, *, k: int, block_n: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        vals_s[...] = jnp.full_like(vals_s, -jnp.inf)
        idx_s[...] = jnp.full_like(idx_s, -1)

    block = s_ref[...]                                        # (bb, bn)
    base = ni * block_n
    iota = jax.lax.broadcasted_iota(jnp.int32, block.shape, 1) + base
    cand_v = jnp.concatenate([vals_s[...], block], axis=1)    # (bb, k+bn)
    cand_i = jnp.concatenate([idx_s[...], iota], axis=1)
    v, sel = jax.lax.top_k(cand_v, k)                         # merge step
    vals_s[...] = v
    idx_s[...] = jnp.take_along_axis(cand_i, sel, axis=1)

    @pl.when(ni == pl.num_programs(1) - 1)
    def _flush():
        vals_out[...] = vals_s[...]
        idx_out[...] = idx_s[...]


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_n", "interpret"))
def topk_pallas(
    scores: jnp.ndarray,  # (B, N) float32, B % block_b == 0, N % block_n == 0
    k: int,
    *,
    block_b: int = BLOCK_B,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, n = scores.shape
    assert b % block_b == 0 and n % block_n == 0, (b, n, block_b, block_n)
    grid = (b // block_b, n // block_n)
    kern = functools.partial(_topk_kernel, k=k, block_n=block_n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.VMEM((block_b, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="streaming_topk",
    )(scores)
