"""Pure-jnp oracle for the streaming top-K kernel: row-wise lax.top_k."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_ref(scores: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """scores (B, N) -> (values (B, k) desc, indices (B, k) int32)."""
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)
