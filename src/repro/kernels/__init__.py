# Performance-critical compute of the paper: modulated scoring (the Phase-2
# matmul + modulation epilogue), top-K selection, and MMR diverse selection.
# Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# public wrapper with padding/layout), ref.py (pure-jnp oracle).
