"""Serving launcher: FLEXVEC retrieval service with batched PEM scoring.

    PYTHONPATH=src python -m repro.launch.serve --chunks 50000 \
        --queries 64 [--sql "SELECT ..."]

Builds a production-like corpus, starts the micro-batching engine + the
agent-facing SQL endpoint, serves a concurrent workload, prints latency
stats. (On a TPU fleet the engine's scoring pass runs the pem_score kernel
over the row-sharded corpus; here it runs the same math on CPU.)
"""

from __future__ import annotations

import argparse
import concurrent.futures as cf
import sqlite3
import time

from repro.data.corpus import build_database, generate_corpus
from repro.embed import HashEmbedder
from repro.serve.engine import BatchedRetrievalEngine
from repro.serve.retrieval import RetrievalService

NOW = 1_770_000_000.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--sql", default=None,
                    help="run one SQL statement through flex_search and exit")
    ap.add_argument("--sync-core", action="store_true",
                    help="serialize the host tail behind the device pass "
                         "(the pre-async engine behavior, for comparison)")
    args = ap.parse_args()

    emb = HashEmbedder(128)
    chunks = generate_corpus(n_chunks=args.chunks,
                             n_sessions=max(20, args.chunks // 50),
                             seed=0, now=NOW)
    conn = sqlite3.connect(":memory:", check_same_thread=False)
    build_database(conn, chunks, emb)
    svc = RetrievalService(conn, dim=128, embedder=emb, now=NOW)

    if args.sql:
        res = svc.flex_search(args.sql)
        if not res.ok:
            raise SystemExit(f"error: {res.error}")
        print(",".join(res.columns))
        for r in res.rows[:50]:
            print(r)
        print(f"-- {len(res.rows)} rows in {res.latency_ms:.1f} ms")
        return

    engine = BatchedRetrievalEngine(svc.cache, max_batch=32, now=NOW,
                                    pipeline=not args.sync_core)
    topics = ["server lifecycle", "identity provenance", "rendering pipeline",
              "auth token", "database migration"]
    reqs = [f"similar:{topics[i % len(topics)]} diverse decay:30"
            for i in range(args.queries)]
    t0 = time.time()
    with cf.ThreadPoolExecutor(max_workers=32) as ex:
        for out in ex.map(lambda q: engine.search(q, args.k), reqs):
            assert len(out) == args.k
    wall = time.time() - t0
    stats = engine.stats()
    core = "sync-core" if args.sync_core else "pipelined"
    print(f"served {args.queries} queries in {wall*1e3:.0f} ms "
          f"({args.queries/wall:.0f} q/s) across "
          f"{stats['batches_served']} fused batches [{core}; "
          f"{stats['overlapped_batches']} overlapped]")
    engine.close()


if __name__ == "__main__":
    main()
