import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 host placeholder devices.
(Smoke tests and benches never import this module — they see 1 device.)

Usage:
    python -m repro.launch.dryrun --arch granite-34b --shape train_4k
    python -m repro.launch.dryrun --arch granite-34b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all            # driver: subprocess per cell
    python -m repro.launch.dryrun --report         # render EXPERIMENTS tables

Each cell writes reports/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective-byte breakdown and the three
roofline terms; the sweep is resumable (existing JSONs are skipped).
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def _compile_spec(spec):
    import jax

    t0 = time.time()
    jitted = jax.jit(spec.fn, donate_argnums=spec.donate_argnums)
    lowered = jitted.lower(*spec.args)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    return lowered, compiled, dict(cost), t_lower, t_compile


def run_cell(arch_id: str, shape: str, multi_pod: bool,
             rules_name: str = "default", arch_obj=None) -> dict:
    from repro.configs import get_arch
    from repro.dist.tuned import get_rules
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import analyze, collective_bytes_from_hlo

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    rules = get_rules(rules_name, mesh)
    arch = arch_obj if arch_obj is not None else get_arch(arch_id)
    cell = arch.cells()[shape]

    spec = arch.build(shape, mesh, rules)
    with mesh:
        lowered, compiled, cost, t_lower, t_compile = _compile_spec(spec)
        spmd_hlo = compiled.as_text()  # post-partitioning: collectives visible

        flops_pd = float(cost.get("flops", 0.0))
        bytes_pd = float(cost.get("bytes accessed", 0.0))
        col_pd, col_by_op = collective_bytes_from_hlo(spmd_hlo)
        probes = None

        # lax.scan bodies are cost-counted once; extrapolate per-layer cost
        # from two UNROLLED probe compiles (exact for identical layers).
        # Probes run on the single-pod mesh only: the multi-pod pass proves
        # the 'pod' axis shards; the roofline table is single-pod (§Roofline).
        if hasattr(arch, "cost_probe_configs") and not multi_pod:
            probe_cfgs, n_layers = arch.cost_probe_configs(shape)
            vals = []
            for l, cfg_l in probe_cfgs:
                spec_l = arch.build(shape, mesh, rules, cfg=cfg_l)
                _, comp_l, cost_l, _, _ = _compile_spec(spec_l)
                cb_l, _ = collective_bytes_from_hlo(comp_l.as_text())
                vals.append((l, float(cost_l.get("flops", 0.0)),
                             float(cost_l.get("bytes accessed", 0.0)), cb_l))
            (l2, f2, b2, c2), (l4, f4, b4, c4) = vals
            dl = l4 - l2
            flops_pd = f2 + (n_layers - l2) * (f4 - f2) / dl
            bytes_pd = b2 + (n_layers - l2) * (b4 - b2) / dl
            col_pd = c2 + (n_layers - l2) * (c4 - c2) / dl
            probes = {"l2": [f2, b2, c2], "l4": [f4, b4, c4],
                      "n_layers": n_layers}

        # fori_loop corrections (MMR) — analytic, per device
        if hasattr(arch, "cost_corrections"):
            ef, eb = arch.cost_corrections(shape, chips)
            flops_pd += ef
            bytes_pd += eb

    mem = compiled.memory_analysis()
    mem_stats = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_stats[k] = float(v)

    model_flops = arch.model_flops(shape)

    rep = analyze(
        arch_id, shape, mesh_name, chips, cost, spmd_hlo,
        model_flops=model_flops, memory_stats=mem_stats,
        flops_override=flops_pd, bytes_override=bytes_pd,
        collective_override=col_pd, collective_by_op=col_by_op,
    )
    out = rep.to_dict()
    out.update({
        "rules": rules_name,
        "skip_reason": cell.skip_reason,
        "beyond_assignment": cell.beyond_assignment,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "probes": probes,
        "cost_raw": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))},
    })
    return out


def cell_list(include_beyond: bool = True):
    from repro.configs import ASSIGNED, get_arch

    assigned, beyond = [], []
    arch_ids = ASSIGNED + ["flexvec"]
    for aid in arch_ids:
        arch = get_arch(aid)
        for shape, cell in arch.cells().items():
            if cell.beyond_assignment or cell.skip_reason or aid == "flexvec":
                if include_beyond and (not cell.skip_reason or cell.beyond_assignment):
                    beyond.append((aid, shape))
                continue
            assigned.append((aid, shape))
    return assigned + beyond


def drive_all(multi_pod_too: bool = True, rules_name: str = "default",
              timeout: int = 7200) -> None:
    """Subprocess per cell: crash isolation + fresh memory + resumability."""
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    cells = cell_list()
    meshes = [False, True] if multi_pod_too else [False]
    todo = []
    for aid, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            suffix = "" if rules_name == "default" else f"__{rules_name}"
            path = REPORT_DIR / f"{aid}__{shape}__{mesh_name}{suffix}.json"
            if path.exists():
                continue
            todo.append((aid, shape, mp, path))
    print(f"[dryrun] {len(todo)} cells to run", flush=True)
    for i, (aid, shape, mp, path) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", aid, "--shape", shape, "--rules", rules_name,
               "--out", str(path)]
        if mp:
            cmd.append("--multi-pod")
        print(f"[dryrun {i+1}/{len(todo)}] {aid}/{shape} "
              f"mesh={'2x16x16' if mp else '16x16'}", flush=True)
        t = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
        if r.returncode != 0:
            err = {"arch": aid, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": r.stderr[-4000:]}
            path.write_text(json.dumps(err, indent=2))
            print(f"  FAILED in {time.time()-t:.0f}s: {r.stderr.splitlines()[-1] if r.stderr else '?'}",
                  flush=True)
        else:
            print(f"  ok in {time.time()-t:.0f}s", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--out")
    args = ap.parse_args()

    if args.all:
        drive_all(rules_name=args.rules)
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    out = run_cell(args.arch, args.shape, args.multi_pod, args.rules)
    text = json.dumps(out, indent=2, default=str)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(text)
    print(text)


if __name__ == "__main__":
    main()
