import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (§Perf): the three chosen cells, one iteration per
invocation step, each a (hypothesis -> change -> re-lower -> measure) cycle.

    PYTHONPATH=src python -m repro.launch.hillclimb [iteration ...]

Iterations (see EXPERIMENTS.md §Perf for hypotheses and outcomes):
    flexvec-1   corpus_all rules    (score on 256 chips, not 16)
    flexvec-2   + bf16 corpus       (halve the scoring stream)
    flexvec-3   + MMR-in-VMEM       (Pallas kernel pool residency)
    qwen3-1     serve_weights rules (EPxTP resident weights for decode)
    granite-1   remat_policy=dots   (stop recomputing matmuls in bwd)
    granite-2   remat off           (flops floor; memory measured)

Writes reports/perf/<name>.json (same schema as the dry-run cells).
"""

import dataclasses
import json
import sys
from pathlib import Path

PERF_DIR = Path(__file__).resolve().parents[3] / "reports" / "perf"


def _save(name: str, out: dict) -> None:
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    (PERF_DIR / f"{name}.json").write_text(json.dumps(out, indent=2, default=str))
    print(f"[{name}] bottleneck={out['bottleneck']} "
          f"t_comp={out['t_compute_s']:.4g}s t_mem={out['t_memory_s']:.4g}s "
          f"t_coll={out['t_collective_s']:.4g}s "
          f"useful={out.get('useful_flops_ratio')} "
          f"frac={out['roofline_fraction']:.5f}", flush=True)


def flexvec_iters(which: str) -> None:
    import jax.numpy as jnp

    from repro.configs.flexvec import FlexvecArch
    from repro.launch.dryrun import run_cell

    if which == "flexvec-1":
        out = run_cell("flexvec", "corpus_1m", False, "corpus_all",
                       arch_obj=FlexvecArch())
    elif which == "flexvec-2":
        out = run_cell("flexvec", "corpus_1m", False, "corpus_all",
                       arch_obj=FlexvecArch(dtype=jnp.bfloat16))
    elif which == "flexvec-3":
        out = run_cell("flexvec", "corpus_1m", False, "corpus_all",
                       arch_obj=FlexvecArch(dtype=jnp.bfloat16, mmr_vmem=True))
    elif which == "flexvec-4":
        out = run_cell("flexvec", "corpus_1m", False, "corpus_all",
                       arch_obj=FlexvecArch(dtype=jnp.bfloat16, mmr_vmem=True,
                                            two_stage=True))
    elif which == "flexvec-6":
        arch = FlexvecArch(dtype=jnp.bfloat16, mmr_vmem=True, two_stage=True)
        arch.mmr_shards = 16
        out = run_cell("flexvec", "corpus_1m", False, "corpus_all",
                       arch_obj=arch)
    else:
        raise KeyError(which)
    _save(which, out)


def qwen3_iters(which: str) -> None:
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.configs.lm import LMArch
    from repro.launch.dryrun import run_cell

    if which == "qwen3-1":
        out = run_cell("qwen3-moe-235b-a22b", "decode_32k", False, "serve_weights")
    elif which == "qwen3-2":
        base = get_arch("qwen3-moe-235b-a22b")
        cfg = dc.replace(base.cfg, moe=dc.replace(base.cfg.moe, decode_group=8))
        variant = LMArch("qwen3-moe-235b-a22b", base.source, cfg, base.smoke_cfg)
        out = run_cell("qwen3-moe-235b-a22b", "decode_32k", False,
                       "serve_weights", arch_obj=variant)
    else:
        raise KeyError(which)
    _save(which, out)


def granite_iters(which: str) -> None:
    from repro.configs.lm import LMArch
    from repro.configs import get_arch
    from repro.launch.dryrun import run_cell

    base = get_arch("granite-34b")
    if which == "granite-1":
        cfg = dataclasses.replace(base.cfg, remat_policy="dots")
    elif which == "granite-2":
        cfg = dataclasses.replace(base.cfg, remat=False)
    else:
        raise KeyError(which)
    variant = LMArch("granite-34b", base.source, cfg, base.smoke_cfg)
    out = run_cell("granite-34b", "train_4k", False, "default",
                   arch_obj=variant)
    _save(which, out)


def flexvec_scale(which: str) -> None:
    """Beyond-paper scale: the 67M-chunk corpus with every flexvec
    optimization, single- and multi-pod (EXPERIMENTS.md §Perf extras)."""
    import jax.numpy as jnp

    from repro.configs.flexvec import FlexvecArch
    from repro.launch.dryrun import run_cell

    arch = FlexvecArch(dtype=jnp.bfloat16, mmr_vmem=True, two_stage=True)
    arch.mmr_shards = 16
    out = run_cell("flexvec", "corpus_67m", which == "flexvec-67m-multipod",
                   "corpus_all", arch_obj=arch)
    _save(which, out)


RUNNERS = {
    "flexvec-67m": flexvec_scale, "flexvec-67m-multipod": flexvec_scale,
    "flexvec-1": flexvec_iters, "flexvec-2": flexvec_iters,
    "flexvec-3": flexvec_iters, "flexvec-4": flexvec_iters,
    "flexvec-6": flexvec_iters,
    "qwen3-1": qwen3_iters, "qwen3-2": qwen3_iters,
    "granite-1": granite_iters, "granite-2": granite_iters,
}


def main() -> None:
    want = sys.argv[1:] or list(RUNNERS)
    for name in want:
        RUNNERS[name](name)


if __name__ == "__main__":
    main()
