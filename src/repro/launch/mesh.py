"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` BEFORE any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh for CPU smoke tests / benches (1 visible device)."""
    return jax.make_mesh((1, 1), ("data", "model"))
