"""Training launcher: ``--arch`` selects any assigned architecture.

On this CPU container the launcher executes REDUCED configs end-to-end
(real steps, checkpoints, resume); on a TPU fleet the same entry point
runs the full config — the step builders in repro/configs are identical,
only the mesh and scale change.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 50 [--ckpt-dir /tmp/ck] [--resume]
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_arch
from repro.dist.sharding import default_rules
from repro.train.loop import TrainLoopConfig, Trainer
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def lm_trainer(arch, args, mesh, rules):
    from repro.data.loader import LMDataConfig, SyntheticLMStream
    from repro.models import transformer as T

    cfg = arch.smoke_cfg if not args.full else arch.cfg
    params = T.init_params(cfg, jax.random.key(args.seed))
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg, rules)
        params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    stream = SyntheticLMStream(
        LMDataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq))
    return Trainer(
        jax.jit(step_fn), params, init_opt_state(params), stream,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        log_every=max(1, args.steps // 10),
                        ckpt_dir=args.ckpt_dir),
        to_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full published config (TPU-scale; not for CPU)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)

    if arch.family == "lm":
        trainer = lm_trainer(arch, args, mesh, rules)
        if args.resume and trainer.try_resume():
            print(f"resumed from step {trainer.step}")
        with mesh:
            out = trainer.run()
        for h in out["history"]:
            print(f"step {h['step']:>5}  loss {h['loss']:.4f}  "
                  f"{h['sec_per_step']*1e3:7.1f} ms")
        print(f"final loss {out['final_loss']:.4f}")
        return

    # GNN / recsys: run the arch's training smoke path N times as a demo
    # loop (their full-scale steps are exercised by the dry-run).
    print(f"[{args.arch}] family={arch.family}: running reduced train steps")
    out = arch.smoke_run()
    print(f"one-step diagnostics: {out}")


if __name__ == "__main__":
    main()
