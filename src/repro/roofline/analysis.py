"""Three-term roofline from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs      / (chips x peak_FLOP/s)
    memory term     = HLO_bytes      / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the (pre-partitioning) HLO text: the summed
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*(?:\([^)]*\)|[\w\[\],{}:\s]*?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum operand byte-sizes of every collective instruction.

    We scan each instruction line whose op is a collective and sum the sizes
    of the shapes appearing in its operand list. `-done` variants are skipped
    (their `-start` already carries the operands).
    """
    total = 0
    per_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*(?:\([^=]*?\)\s+)?([a-z0-9\-]+)?\s*"  # result shape gunk
            , line)
        # direct approach: find the op name token before '('
        op_m = re.search(
            r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", line)
        if not op_m or "-done(" in line:
            continue
        op = op_m.group(1)
        # operand shapes are the shapes AFTER the op's '('; result shape(s)
        # appear before '='. Split at the op call site.
        call_part = line[op_m.end():]
        bytes_here = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(call_part)
        )
        if bytes_here == 0:
            # fallback: use the result shape (e.g. operands referenced by name
            # only); result of all-reduce == operand size.
            head = line[: op_m.start()]
            bytes_here = sum(
                _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head)
            )
        total += bytes_here
        per_op[op] = per_op.get(op, 0) + bytes_here
    return total, per_op


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_op: Dict[str, int]
    model_flops: Optional[float] = None   # 6*N*D (dense) / 6*N_active*D (MoE)
    per_device_memory: Optional[Dict[str, float]] = None
    hw: Hardware = HW

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.hw.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hw.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.hw.link_bw)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or self.hlo_flops == 0:
            return None
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """max-term model: fraction of the binding roof actually utilized by
        useful work. For compute-bound cells this is MODEL_FLOPS/(chips*peak)
        over the step's critical time (= max term)."""
        tmax = max(self.t_compute, self.t_memory, self.t_collective)
        if tmax == 0:
            return 0.0
        useful = (self.model_flops or self.hlo_flops) / (self.chips * self.hw.peak_flops)
        return useful / tmax

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_op": self.collective_by_op,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_memory": self.per_device_memory,
        }


def analyze(
    arch: str, shape: str, mesh_name: str, chips: int,
    cost: Dict, hlo_text: str,
    model_flops: Optional[float] = None,
    memory_stats: Optional[Dict[str, float]] = None,
    *,
    per_device_inputs: bool = True,
    flops_override: Optional[float] = None,
    bytes_override: Optional[float] = None,
    collective_override: Optional[float] = None,
    collective_by_op: Optional[Dict[str, int]] = None,
) -> RooflineReport:
    """Build a report from compiled artifacts.

    NOTE (verified empirically on this backend): ``compiled.cost_analysis()``
    reports the PER-DEVICE SPMD module, and while-loop bodies (lax.scan /
    fori_loop) are counted ONCE, not x trip-count. Callers therefore pass
    loop-extrapolated per-device numbers via the ``*_override`` args (see
    launch/dryrun.py); this function scales per-device -> fleet totals.
    """
    if collective_override is None:
        cbytes, per_op = collective_bytes_from_hlo(hlo_text)
    else:
        cbytes, per_op = collective_override, (collective_by_op or {})
    scale = chips if per_device_inputs else 1
    flops = flops_override if flops_override is not None else float(cost.get("flops", 0.0))
    nbytes = bytes_override if bytes_override is not None else float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops * scale,
        hlo_bytes=nbytes * scale,
        collective_bytes=float(cbytes) * scale,
        collective_by_op={k: int(v) * scale for k, v in per_op.items()},
        model_flops=model_flops,
        per_device_memory=memory_stats,
    )
