"""Render dry-run JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report [--dir reports/dryrun]

Emits: §Dry-run summary (per cell x mesh: compile ok, per-device memory,
collective mix) and §Roofline (single-pod three-term table).
No jax import — safe anywhere.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional


def load_cells(report_dir: Path, rules: str = "default") -> List[Dict]:
    cells = []
    for p in sorted(report_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if "error" in d:
            continue
        if d.get("rules", "default") != rules:
            continue
        cells.append(d)
    return cells


def _fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def _fmt_b(x: Optional[float]) -> str:
    if not x:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(cells: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compile | HLO FLOPs | HLO bytes | coll. bytes | arg/dev | temp/dev | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        mem = d.get("per_device_memory") or {}
        note = ""
        if d.get("skip_reason"):
            note = "skip-noted; run beyond-assignment"
        elif d.get("beyond_assignment"):
            note = "beyond-assignment"
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"ok ({d.get('compile_s', 0):.0f}s) | "
            f"{d['hlo_flops']:.3g} | {_fmt_b(d['hlo_bytes'])} | "
            f"{_fmt_b(d['collective_bytes'])} | "
            f"{_fmt_b(mem.get('argument_size_in_bytes'))} | "
            f"{_fmt_b(mem.get('temp_size_in_bytes'))} | {note} |"
        )
    return "\n".join(out)


def roofline_table(cells: List[Dict]) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        if d["mesh"] != "16x16":
            continue
        ur = d.get("useful_flops_ratio")
        out.append(
            f"| {d['arch']} | {d['shape']} | {_fmt_s(d['t_compute_s'])} | "
            f"{_fmt_s(d['t_memory_s'])} | {_fmt_s(d['t_collective_s'])} | "
            f"**{d['bottleneck']}** | "
            f"{(d.get('model_flops') or 0):.3g} | "
            f"{ur:.3f} | {d['roofline_fraction']:.4f} |"
            if ur is not None else
            f"| {d['arch']} | {d['shape']} | - | - | - | - | - | - | - |"
        )
    return "\n".join(out)


def collective_mix_table(cells: List[Dict]) -> str:
    out = ["| arch | shape | mesh | all-gather | all-reduce | reduce-scatter | all-to-all | collective-permute |",
           "|---|---|---|---|---|---|---|---|"]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        ops = d.get("collective_by_op") or {}
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            + " | ".join(_fmt_b(ops.get(k)) for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")) + " |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--out", default="reports/roofline_report.md")
    args = ap.parse_args()
    cells = load_cells(Path(args.dir), args.rules)
    single = [c for c in cells if c["mesh"] == "16x16"]
    multi = [c for c in cells if c["mesh"] == "2x16x16"]
    text = "\n\n".join([
        f"## Dry-run summary ({len(cells)} compiled cells: "
        f"{len(single)} single-pod, {len(multi)} multi-pod)",
        dryrun_table(cells),
        "## Roofline (single-pod 16x16, 256 chips)",
        roofline_table(cells),
        "## Collective mix",
        collective_mix_table(cells),
    ])
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(text)
    print(f"wrote {args.out}: {len(cells)} cells")


if __name__ == "__main__":
    main()
