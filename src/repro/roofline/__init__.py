from repro.roofline.analysis import RooflineReport, analyze, HW

__all__ = ["RooflineReport", "analyze", "HW"]
