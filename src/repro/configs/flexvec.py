"""FLEXVEC itself as a servable architecture (the paper's system).

Cells lower the distributed Phase-2 engine: fused modulated scoring over a
row-sharded corpus matrix, streaming top-k, MMR diverse selection — i.e.
the TPU-native PEM retrieval kernel serving a BATCH of agent queries.

corpus_240k / corpus_1m mirror the paper's two headline corpus sizes
(§4.1/§4.3); corpus_67m is the beyond-paper scale point (256 chips x the
paper's 1M-chunk working set is pointless — scale the corpus instead:
67M chunks x 128d x f32 = 34 GB, row-sharded = 134 MB/chip).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchSpec, LoweredSpec, ShapeCell, with_sharding
from repro.dist.sharding import ShardingRules, default_rules
from repro.kernels.mmr.ref import mmr_ref

SHAPES = {
    "corpus_240k": dict(n=240_000, batch=64, pool=500, over=1500),
    "corpus_1m": dict(n=1_000_000, batch=64, pool=500, over=1500),
    "corpus_67m": dict(n=67_108_864, batch=256, pool=500, over=1500),
}

DIM = 128  # Nomic Embed v1.5, Matryoshka-truncated (paper §2.1)


def pem_serve_step(corpus, days, q_pre, q_sup, *, pool: int, over: int):
    """The paper's Phase 2 as one jitted graph (pjit baseline path).

    scores = decay * (M @ q_pre) + M @ q_sup       (Table 1, fixed order)
    top-`over` pool -> MMR(lambda=0.7) -> `pool` selected ids + scores.
    On TPU the matmuls execute as the fused pem_score Pallas kernel; this
    pure-JAX body is the lowering used for dry-run/roofline (identical
    FLOP/byte profile).
    """
    decay = 1.0 / (1.0 + days / 30.0)
    scores = decay[:, None] * (corpus @ q_pre) + corpus @ q_sup   # (N, B)
    v, i = jax.lax.top_k(scores.T, over)                          # (B, over)
    emb = jnp.take(corpus, i, axis=0)                             # (B, over, d)
    sel, _ = mmr_ref(emb, v, pool)                                # diverse
    idx = jnp.take_along_axis(i, sel, axis=1)
    val = jnp.take_along_axis(v, sel, axis=1)
    return idx, val


class FlexvecArch(ArchSpec):
    family = "retrieval"

    def __init__(self, *, dtype=jnp.float32, mmr_vmem: bool = False,
                 two_stage: bool = False, arch_id: str = "flexvec"):
        """Hillclimb knobs (§Perf flexvec iterations):
        dtype     — corpus matrix dtype (bf16 halves the scoring stream);
        mmr_vmem  — account MMR with the Pallas kernel's VMEM-resident pool
                    (ONE HBM read) instead of the jnp fori_loop's per-
                    iteration re-read; the kernel is interpret-validated in
                    tests/test_kernels.py.
        two_stage — shard_map local-topk + union merge instead of the naive
                    pjit global top_k (which all-gathers the (N,B) scores)."""
        self.arch_id = arch_id
        self.source = "this paper"
        self.dtype = dtype
        self.mmr_vmem = mmr_vmem
        self.two_stage = two_stage
        # queries the MMR stage shards over (1 = replicated); §Perf flexvec-6
        self.mmr_shards = 1

    def cells(self) -> Dict[str, ShapeCell]:
        return {
            name: ShapeCell(
                name=name, kind="retrieval",
                desc=f"corpus={s['n']} queries={s['batch']} pool={s['pool']}",
                beyond_assignment=True,
            )
            for name, s in SHAPES.items()
        }

    def cost_corrections(self, shape: str, chips: int):
        """MMR's fori_loop body is counted once by cost_analysis; add the
        remaining (pool-1) iterations analytically (replicated per device):
        per iter per query: one-hot matmul (2*over*d) + sim matvec (2*over*d)
        + O(over) elementwise. With mmr_vmem the Pallas kernel keeps the pool
        resident in VMEM (2MB/query << 16MB), so HBM sees ONE pool read; the
        per-iteration traffic drops to the O(over) state vectors."""
        s = SHAPES[shape]
        b_local = max(1, s["batch"] // max(self.mmr_shards, 1))
        per_iter = b_local * (4.0 * s["over"] * DIM + 6.0 * s["over"])
        extra_flops = (s["pool"] - 1) * per_iter
        if self.mmr_vmem:
            extra_bytes = (s["pool"] - 1) * b_local * 3 * s["over"] * 4.0
        else:
            extra_bytes = (s["pool"] - 1) * b_local * (
                s["over"] * DIM * 4.0 + 3 * s["over"] * 4.0)
        return extra_flops, extra_bytes

    def model_flops(self, shape: str) -> float:
        s = SHAPES[shape]
        N, B, pool, over = s["n"], s["batch"], s["pool"], s["over"]
        scoring = 2.0 * N * DIM * B * 2          # two effective directions
        mmr = 2.0 * B * pool * over * DIM        # k x n pairwise updates
        return scoring + mmr

    def build(self, shape: str, mesh: Mesh, rules: ShardingRules) -> LoweredSpec:
        s = SHAPES[shape]
        N, B = s["n"], s["batch"]
        shards = max(rules.size_of("corpus"), 1)
        N = (N + shards - 1) // shards * shards  # pad rows to the shard grid
        corpus = with_sharding(
            jax.ShapeDtypeStruct((N, DIM), self.dtype),
            rules.spec("corpus", None), mesh)
        days = with_sharding(
            jax.ShapeDtypeStruct((N,), jnp.float32), rules.spec("corpus"), mesh)
        q_pre = with_sharding(
            jax.ShapeDtypeStruct((DIM, B), jnp.float32), rules.spec(None, None), mesh)
        q_sup = with_sharding(
            jax.ShapeDtypeStruct((DIM, B), jnp.float32), rules.spec(None, None), mesh)

        pool, over = s["pool"], s["over"]

        if self.two_stage:
            from repro.dist.pem_sharded import make_pem_topk

            local_topk = make_pem_topk(mesh, rules, over, raw=True)

            mmr_shards = self.mmr_shards

            def step(corpus, days, q_pre, q_sup):
                # stage 1: shard-local scoring + local top-over, union merge
                # (collective = shards*over*B candidates, NOT the N*B panel)
                i, v = local_topk(corpus, days, q_pre, q_sup)   # (B, over)
                # stage 2: gather pool embeddings + MMR diverse selection;
                # MMR queries are independent -> shard the batch instead of
                # replicating 500 iterations on every chip (flexvec-6)
                emb = jnp.take(corpus, i, axis=0)
                if mmr_shards > 1:
                    from jax.sharding import PartitionSpec as P
                    emb = jax.lax.with_sharding_constraint(
                        emb, P("data", None, None))
                    v = jax.lax.with_sharding_constraint(v, P("data", None))
                sel, _ = mmr_ref(emb, v, pool)
                idx = jnp.take_along_axis(i, sel, axis=1)
                val = jnp.take_along_axis(v, sel, axis=1)
                return idx, val
        else:
            def step(corpus, days, q_pre, q_sup):
                return pem_serve_step(corpus, days, q_pre, q_sup,
                                      pool=pool, over=over)

        return LoweredSpec(fn=step, args=(corpus, days, q_pre, q_sup),
                           static_desc=f"flexvec/{shape}")

    def smoke_run(self) -> Dict[str, Any]:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules(mesh)
        with mesh:
            k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
            corpus = jax.random.normal(k1, (512, DIM))
            corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
            days = jax.random.uniform(k2, (512,), minval=0.0, maxval=90.0)
            q = jax.random.normal(k3, (DIM, 2))
            idx, val = pem_serve_step(corpus, days, q, -0.5 * q, pool=8, over=24)
        return {
            "idx_shape": tuple(idx.shape),
            "val_finite": bool(jnp.isfinite(val).all()),
            "loss": float(val.mean()),
        }


FLEXVEC_ARCHS = [FlexvecArch()]
