"""Common machinery for architecture specs and dry-run cells."""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import ShardingRules


@dataclasses.dataclass
class ShapeCell:
    """One (arch x input-shape) dry-run unit."""

    name: str
    kind: str                      # train | prefill | decode | serve | retrieval
    desc: str
    skip_reason: Optional[str] = None  # e.g. long_500k on full-attention archs
    beyond_assignment: bool = False    # extra cells we run anyway


@dataclasses.dataclass
class LoweredSpec:
    """What dryrun.py feeds to jax.jit(...).lower(...)."""

    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs with shardings attached
    donate_argnums: Tuple[int, ...] = ()
    static_desc: str = ""


def with_sharding(tree, spec_tree, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""

    def att(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s))

    return jax.tree.map(att, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


class ArchSpec(abc.ABC):
    """One selectable architecture (``--arch``)."""

    arch_id: str
    family: str                    # lm | gnn | recsys | retrieval
    source: str                    # public-literature citation

    @abc.abstractmethod
    def cells(self) -> Dict[str, ShapeCell]:
        ...

    @abc.abstractmethod
    def build(self, shape: str, mesh: Mesh, rules: ShardingRules) -> LoweredSpec:
        """Build the jit-able step + ShapeDtypeStruct inputs for a cell."""

    @abc.abstractmethod
    def smoke_run(self) -> Dict[str, Any]:
        """Reduced-config forward/train step on CPU; returns diagnostics
        (loss, shapes) for the per-arch smoke tests."""

    def model_flops(self, shape: str) -> Optional[float]:
        """Analytic useful-work FLOPs for the cell (6ND convention for LM
        training, 2ND for forward-only; analytic op counts elsewhere).
        Used for the roofline's MODEL_FLOPS / HLO_FLOPs ratio."""
        return None
