"""The five assigned LM architectures (published configs, exact dims).

Shapes (assignment):
    train_4k     seq 4096  global_batch 256   -> train_step
    prefill_32k  seq 32768 global_batch 32    -> prefill (serve)
    decode_32k   seq 32768 global_batch 128   -> decode_step (1 tok, KV cache)
    long_500k    seq 524288 global_batch 1    -> decode; SKIPPED for these
                 pure full-attention archs per assignment (DESIGN.md §3.5),
                 but additionally lowered as a beyond-assignment cell since
                 decode against a KV cache is linear in context.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchSpec, LoweredSpec, ShapeCell, with_sharding
from repro.dist.sharding import ShardingRules, default_rules
from repro.models import transformer as T
from repro.models.layers import LMConfig, MoEConfig
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

_SKIP_500K = (
    "long_500k requires sub-quadratic attention; this arch is pure "
    "full-attention (published config) -> skipped per assignment. A "
    "beyond-assignment decode lowering (linear-in-context KV-cache decode "
    "with sequence-sharded cache) is reported separately."
)

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


class LMArch(ArchSpec):
    family = "lm"

    def __init__(self, arch_id: str, source: str, cfg: LMConfig, smoke_cfg: LMConfig):
        self.arch_id = arch_id
        self.source = source
        self.cfg = cfg
        self.smoke_cfg = smoke_cfg

    def cells(self) -> Dict[str, ShapeCell]:
        out = {}
        for name, s in LM_SHAPES.items():
            skip = _SKIP_500K if name == "long_500k" else None
            out[name] = ShapeCell(
                name=name, kind=s["kind"],
                desc=f"seq={s['seq']} batch={s['batch']}",
                skip_reason=skip,
                beyond_assignment=(name == "long_500k"),
            )
        return out

    def model_flops(self, shape: str) -> float:
        s = LM_SHAPES[shape]
        n = self.cfg.n_active_params
        if s["kind"] == "train":
            return 6.0 * n * s["batch"] * s["seq"]
        if s["kind"] == "prefill":
            return 2.0 * n * s["batch"] * s["seq"]
        # decode: one token per sequence + KV-cache attention reads
        cfg = self.cfg
        att = 4.0 * s["batch"] * cfg.n_heads * cfg.head_dim * s["seq"] * cfg.n_layers
        return 2.0 * n * s["batch"] + att

    # -- dry-run builders ----------------------------------------------------

    def _abstract_params(self):
        return jax.eval_shape(lambda: T.init_params(self.cfg, jax.random.key(0)))

    def build(self, shape: str, mesh: Mesh, rules: ShardingRules,
              cfg: LMConfig = None) -> LoweredSpec:
        cfg = cfg or self.cfg
        s = LM_SHAPES[shape]
        p_struct = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
        p_spec = T.param_shardings(cfg, rules)
        params = with_sharding(p_struct, p_spec, mesh)

        if s["kind"] == "train":
            o_struct = jax.eval_shape(init_opt_state, p_struct)
            o_spec = OptState(
                step=rules.spec(), m=p_spec,
                v=jax.tree.map(lambda x: x, p_spec),
            )
            opt = with_sharding(o_struct, o_spec, mesh)
            batch = {
                "tokens": jax.ShapeDtypeStruct((s["batch"], s["seq"]), jnp.int32),
                "labels": jax.ShapeDtypeStruct((s["batch"], s["seq"]), jnp.int32),
            }
            bspec = {"tokens": rules.spec("batch", "seq"),
                     "labels": rules.spec("batch", "seq")}
            batch = with_sharding(batch, bspec, mesh)
            ocfg = AdamWConfig()

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg, rules)
                params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
                return params, opt_state, {"loss": loss, **metrics}

            return LoweredSpec(
                fn=train_step, args=(params, opt, batch),
                donate_argnums=(0, 1),
                static_desc=f"{self.arch_id}/train_4k",
            )

        if s["kind"] == "prefill":
            tokens = with_sharding(
                jax.ShapeDtypeStruct((s["batch"], s["seq"]), jnp.int32),
                rules.spec("batch", "seq"), mesh,
            )

            def prefill(params, tokens):
                return T.prefill_step(params, tokens, cfg, rules)

            return LoweredSpec(fn=prefill, args=(params, tokens),
                               static_desc=f"{self.arch_id}/{shape}")

        # decode: one new token against a KV cache of length seq
        B, S = s["batch"], s["seq"]
        if B % max(rules.size_of("batch"), 1) != 0:
            # long_500k: batch=1 cannot shard -> sequence-shard the KV cache
            # over the data axes instead (context parallelism for decode).
            new_rules = dict(rules.rules)
            new_rules["seq"] = rules.rules["batch"]
            new_rules["batch"] = None
            rules = dataclasses.replace(rules, rules=new_rules)
        cache_struct = jax.eval_shape(lambda: T.make_cache(cfg, B, S))
        cspec = T.cache_shardings(cfg, rules)
        cache = with_sharding(cache_struct, cspec, mesh)
        token = with_sharding(
            jax.ShapeDtypeStruct((B, 1), jnp.int32), rules.spec("batch", None), mesh)
        clen = with_sharding(
            jax.ShapeDtypeStruct((), jnp.int32), rules.spec(), mesh)

        def decode(params, token, cache, cache_len):
            return T.decode_step(params, token, cache, cache_len, cfg, rules)

        return LoweredSpec(
            fn=decode, args=(params, token, cache, clen),
            donate_argnums=(2,),
            static_desc=f"{self.arch_id}/{shape}",
        )

    # -- loop-aware cost extrapolation ----------------------------------------

    def cost_probe_configs(self, shape: str):
        """Two unrolled low-layer-count variants for cost extrapolation.

        The production lowering scans layers (one while loop, flat compile
        time) but XLA cost_analysis counts loop bodies ONCE. These probes
        unroll {2,4} layers with single-chunk attention; dryrun.py takes the
        per-layer delta and extrapolates to n_layers (layers are identical,
        so the extrapolation is exact for matmul work).
        """
        s = LM_SHAPES[shape]
        out = []
        for l in (2, 4):
            out.append((l, dataclasses.replace(
                self.cfg, n_layers=l, scan_unroll=l, q_chunk=s["seq"],
            )))
        return out, self.cfg.n_layers

    # -- smoke ----------------------------------------------------------------

    def smoke_run(self) -> Dict[str, Any]:
        cfg = self.smoke_cfg
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules(mesh)
        with mesh:
            params = T.init_params(cfg, jax.random.key(0))
            B, S = 2, 16
            tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
            batch = {"tokens": tokens, "labels": tokens}
            loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg, rules)
            opt = init_opt_state(params)
            params2, opt2, metrics = adamw_update(AdamWConfig(), params, grads, opt)
            logits_last, cache = T.prefill_step(params, tokens, cfg, rules)
            big = T.make_cache(cfg, B, S + 4)
            big = tuple(
                jax.lax.dynamic_update_slice(b, c, (0, 0, 0, 0, 0))
                for b, c in zip(big, cache)
            )
            dec_logits, _ = T.decode_step(
                params, tokens[:, :1], big, jnp.int32(S), cfg, rules)
        return {
            "loss": float(loss),
            "grad_norm": float(metrics["grad_norm"]),
            "logits_shape": tuple(logits_last.shape),
            "decode_shape": tuple(dec_logits.shape),
            "vocab": cfg.vocab,
        }


def _smoke_of(cfg: LMConfig) -> LMConfig:
    """Same family (mlp type, GQA ratio, MoE-ness), tiny dims."""
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=min(8, cfg.moe.n_experts), top_k=min(2, cfg.moe.top_k))
    kv = max(1, min(2, cfg.n_kv_heads))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=kv, head_dim=16,
        d_ff=96 if moe is None else 32,
        vocab=128, dtype=jnp.float32, q_chunk=8, remat=False, moe=moe,
    )


def _mk(arch_id, source, **kw) -> LMArch:
    cfg = LMConfig(name=arch_id, **kw)
    return LMArch(arch_id, source, cfg, _smoke_of(cfg))


LM_ARCHS = [
    # 88L d6144 48H MQA(kv=1) dff 24576 vocab 49152, non-gated GELU (~34B)
    _mk("granite-34b", "arXiv:2405.04324; hf",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
        d_ff=24576, vocab=49152, mlp_type="gelu"),
    # 32L d3072 24H GQA(kv=8) dff 9216 vocab 256000, squared-ReLU (~4B)
    _mk("minitron-4b", "arXiv:2407.14679; hf",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=9216, vocab=256000, mlp_type="relu2"),
    # 24L d2048 16H GQA(kv=8) dff 8192 vocab 92544, SwiGLU (~1.9B)
    _mk("internlm2-1.8b", "arXiv:2403.17297; hf",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=92544, mlp_type="swiglu"),
    # 24L d1024 16H GQA(kv=8) per-expert dff 512, MoE 32e top-8 (~1.4B/0.4B)
    _mk("granite-moe-1b-a400m", "hf:ibm-granite/granite-3.0-1b-a400m-base",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49155, mlp_type="swiglu",
        moe=MoEConfig(n_experts=32, top_k=8)),
    # 94L d4096 64H GQA(kv=4) per-expert dff 1536, MoE 128e top-8 (~235B/22B)
    _mk("qwen3-moe-235b-a22b", "hf:Qwen/Qwen3-30B-A3B (scaled cfg per assignment)",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936, mlp_type="swiglu",
        moe=MoEConfig(n_experts=128, top_k=8)),
]
