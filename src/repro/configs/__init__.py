"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures + the paper's own retrieval config (flexvec).
Each ArchSpec knows its published full config, a reduced smoke config, its
shape cells, and how to build (step_fn, ShapeDtypeStruct inputs) for the
multi-pod dry-run.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchSpec
from repro.configs.lm import LM_ARCHS
from repro.configs.gnn import GNN_ARCHS
from repro.configs.recsys_archs import RECSYS_ARCHS
from repro.configs.flexvec import FLEXVEC_ARCHS

REGISTRY: Dict[str, ArchSpec] = {}
for _a in (*LM_ARCHS, *GNN_ARCHS, *RECSYS_ARCHS, *FLEXVEC_ARCHS):
    REGISTRY[_a.arch_id] = _a

ASSIGNED = [
    "granite-34b", "minitron-4b", "internlm2-1.8b",
    "granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
    "pna",
    "bst", "autoint", "dlrm-mlperf", "two-tower-retrieval",
]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
