"""PNA architecture cells [arXiv:2004.05718].

Shapes (assignment):
    full_graph_sm  n=2,708  e=10,556   d_feat=1,433 (Cora-scale, full batch)
    minibatch_lg   n=232,965 e=114.6M  seeds=1,024 fanout 15-10 (Reddit-scale,
                   REAL neighbor sampler -> padded subgraph, static shapes)
    ogb_products   n=2,449,029 e=61.9M d_feat=100 (full-batch-large)
    molecule       30 nodes / 64 edges x batch 128 (graph-level task)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchSpec, LoweredSpec, ShapeCell, with_sharding
from repro.data.graph import (
    CSRGraph,
    _max_edges,
    _max_nodes,
    make_graph,
    make_molecule_batch,
    sample_subgraph,
)
from repro.dist.sharding import ShardingRules, default_rules
from repro.models import pna
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state
import numpy as np

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(kind="train", n=2708, e=10556, d_feat=1433,
                          n_classes=7, task="node"),
    "minibatch_lg": dict(kind="train", seeds=1024, fanouts=(15, 10), d_feat=602,
                         n_classes=41, task="node"),
    "ogb_products": dict(kind="train", n=2_449_029, e=61_859_140, d_feat=100,
                         n_classes=47, task="node"),
    "molecule": dict(kind="train", batch=128, nodes=30, edges=64, d_feat=28,
                     n_classes=2, task="graph"),
}


def _round512(x: int) -> int:
    """Pad node/edge budgets to a 512 multiple so they shard evenly on any
    production mesh axis combination. Padding is masked (sink node), exactly
    as the data pipeline pads sampled subgraphs (data/graph.py)."""
    return (x + 511) // 512 * 512


def _shape_dims(s: Dict[str, Any]):
    if "seeds" in s:
        n = _max_nodes(s["seeds"], s["fanouts"]) + 1
        e = _max_edges(s["seeds"], s["fanouts"])
    elif "batch" in s:
        n, e = s["batch"] * s["nodes"], s["batch"] * s["edges"]
    else:
        n, e = s["n"], s["e"]
    return _round512(n), _round512(e)


class PNAArch(ArchSpec):
    family = "gnn"

    def __init__(self):
        self.arch_id = "pna"
        self.source = "arXiv:2004.05718; paper"
        self.n_layers = 4
        self.d_hidden = 75

    def cells(self) -> Dict[str, ShapeCell]:
        out = {}
        for name, s in GNN_SHAPES.items():
            n, e = _shape_dims(s)
            out[name] = ShapeCell(name=name, kind="train",
                                  desc=f"nodes={n} edges={e} d_feat={s['d_feat']}")
        return out

    def model_flops(self, shape: str) -> float:
        s = GNN_SHAPES[shape]
        n, e = _shape_dims(s)
        d = self.d_hidden
        per_layer = 2.0 * e * (2 * d) * d + 2.0 * n * (13 * d) * d
        fwd = (2.0 * n * s["d_feat"] * d
               + self.n_layers * per_layer
               + 2.0 * n * d * s["n_classes"])
        return 3.0 * fwd  # train step (fwd + bwd)

    def _cfg(self, s: Dict[str, Any]) -> pna.PNAConfig:
        return pna.PNAConfig(
            name="pna", n_layers=self.n_layers, d_hidden=self.d_hidden,
            d_feat=s["d_feat"], n_classes=s["n_classes"], task=s["task"],
            n_graphs=s.get("batch", 1),
        )

    def build(self, shape: str, mesh: Mesh, rules: ShardingRules) -> LoweredSpec:
        s = GNN_SHAPES[shape]
        cfg = self._cfg(s)
        n, e = _shape_dims(s)
        p_struct = jax.eval_shape(lambda: pna.init_params(cfg, jax.random.key(0)))
        p_spec = jax.tree.map(lambda _: rules.spec(), p_struct)  # tiny: replicate
        params = with_sharding(p_struct, p_spec, mesh)
        o_struct = jax.eval_shape(init_opt_state, p_struct)
        opt = with_sharding(
            o_struct,
            OptState(step=rules.spec(), m=p_spec, v=jax.tree.map(lambda x: x, p_spec)),
            mesh,
        )
        batch = {
            "feats": jax.ShapeDtypeStruct((n, s["d_feat"]), jnp.float32),
            "edge_src": jax.ShapeDtypeStruct((e,), jnp.int32),
            "edge_dst": jax.ShapeDtypeStruct((e,), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (s.get("batch", n) if s["task"] == "graph" else n,), jnp.int32),
            "node_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
            "edge_mask": jax.ShapeDtypeStruct((e,), jnp.bool_),
        }
        bspec = {
            "feats": rules.spec("nodes", None),
            "edge_src": rules.spec("edges"),
            "edge_dst": rules.spec("edges"),
            "labels": rules.spec("nodes" if s["task"] == "node" else None),
            "node_mask": rules.spec("nodes"),
            "edge_mask": rules.spec("edges"),
        }
        if s["task"] == "graph":
            batch["graph_ids"] = jax.ShapeDtypeStruct((n,), jnp.int32)
            bspec["graph_ids"] = rules.spec("nodes")
        batch = with_sharding(batch, bspec, mesh)
        ocfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(pna.loss_fn)(params, batch, cfg, rules)
            params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
            return params, opt_state, {"loss": loss, **metrics}

        return LoweredSpec(fn=train_step, args=(params, opt, batch),
                           donate_argnums=(0, 1),
                           static_desc=f"pna/{shape}")

    def smoke_run(self) -> Dict[str, Any]:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules(mesh)
        out: Dict[str, Any] = {}
        with mesh:
            # node task on a small graph THROUGH the real sampler
            g = make_graph(400, 1600, 24, n_classes=5, seed=0)
            csr = CSRGraph(400, g.edge_src, g.edge_dst)
            sub = sample_subgraph(g, csr, np.arange(32), [4, 3],
                                  np.random.default_rng(0))
            cfg = pna.PNAConfig(name="pna-smoke", n_layers=2, d_hidden=16,
                                d_feat=24, n_classes=5)
            params = pna.init_params(cfg, jax.random.key(0))
            batch = {
                "feats": jnp.asarray(sub.feats),
                "edge_src": jnp.asarray(sub.edge_src),
                "edge_dst": jnp.asarray(sub.edge_dst),
                "labels": jnp.asarray(sub.labels),
                "node_mask": jnp.asarray(sub.node_mask),
                "edge_mask": jnp.asarray(sub.edge_mask),
            }
            loss, grads = jax.value_and_grad(pna.loss_fn)(params, batch, cfg, rules)
            out["loss"] = float(loss)
            out["grad_finite"] = all(
                bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(grads))
            # graph task
            mol = make_molecule_batch(8, 10, 20, 24, n_classes=5, seed=1)
            cfg_g = dataclasses.replace(cfg, task="graph", n_graphs=8)
            mb = {
                "feats": jnp.asarray(mol.feats),
                "edge_src": jnp.asarray(mol.edge_src),
                "edge_dst": jnp.asarray(mol.edge_dst),
                "labels": jnp.asarray(mol.labels),
                "node_mask": jnp.asarray(mol.node_mask),
                "edge_mask": jnp.asarray(mol.edge_mask),
                "graph_ids": jnp.asarray(mol.graph_ids),
            }
            logits = pna.forward(params, mb, cfg_g, rules)
            out["graph_logits_shape"] = tuple(logits.shape)
            out["graph_loss"] = float(pna.loss_fn(params, mb, cfg_g, rules))
        return out


GNN_ARCHS = [PNAArch()]
