"""The four assigned recsys architectures.

Shapes (assignment):
    train_batch    batch=65,536        -> train_step
    serve_p99      batch=512           -> serve_step (forward)
    serve_bulk     batch=262,144       -> serve_step (offline scoring)
    retrieval_cand batch=1, 1M cands   -> retrieval scoring. For two-tower
                   this is the paper's PEM surface (modulated scoring +
                   top-k + MMR over a 1M-row candidate matrix); for the
                   pointwise CTR models it lowers bulk candidate scoring.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchSpec, LoweredSpec, ShapeCell, with_sharding
from repro.data import recsys as RD
from repro.data.recsys import CRITEO_1TB_VOCAB_SIZES
from repro.dist.sharding import ShardingRules, constrain, default_rules
from repro.kernels.mmr.ref import mmr_ref
from repro.models import recsys as R
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


class RecsysArch(ArchSpec):
    family = "recsys"

    def __init__(self, arch_id: str, source: str, cfg, init_fn, loss_fn,
                 fwd_fn, batch_fn, shardings_fn, smoke_cfg):
        self.arch_id = arch_id
        self.source = source
        self.cfg = cfg
        self.smoke_cfg = smoke_cfg
        self._init = init_fn
        self._loss = loss_fn
        self._fwd = fwd_fn
        self._batch = batch_fn           # (cfg, batch_size) -> struct dict+specs
        self._shardings = shardings_fn   # (cfg, rules) -> param spec tree

    def cells(self) -> Dict[str, ShapeCell]:
        out = {}
        for name, s in SHAPES.items():
            desc = f"batch={s['batch']}"
            if name == "retrieval_cand":
                desc += f" n_candidates={s['n_candidates']}"
                if self.arch_id != "two-tower-retrieval":
                    desc += " (pointwise CTR: lowered as bulk candidate scoring)"
            out[name] = ShapeCell(name=name, kind=s["kind"], desc=desc)
        return out

    def model_flops(self, shape: str) -> float:
        s = SHAPES[shape]
        if shape == "retrieval_cand" and self.arch_id == "two-tower-retrieval":
            # step scores a PRECOMPUTED candidate matrix: dot per candidate
            # + one user tower + MMR over the oversample pool (B=1)
            D = self.cfg.tower_mlp[-1]
            dims = (2 * self.cfg.embed_dim,) + self.cfg.tower_mlp
            tower = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
            return (2.0 * s["n_candidates"] * D + tower
                    + 2.0 * 500 * 1500 * D)
        b = s["batch"] if shape != "retrieval_cand" else s["n_candidates"]
        per_ex = _flops_per_example(self.arch_id, self.cfg)
        mult = 3.0 if s["kind"] == "train" else 1.0
        return mult * per_ex * b

    def cost_corrections(self, shape: str, chips: int):
        if shape == "retrieval_cand" and self.arch_id == "two-tower-retrieval":
            D = self.cfg.tower_mlp[-1]
            pool, over, b = 500, 1500, 1
            per_iter = b * (4.0 * over * D + 6.0 * over)
            return (pool - 1) * per_iter, (pool - 1) * b * over * D * 4.0
        return 0.0, 0.0

    def build(self, shape: str, mesh: Mesh, rules: ShardingRules) -> LoweredSpec:
        s = SHAPES[shape]
        cfg = self.cfg
        p_struct = jax.eval_shape(lambda: self._init(cfg, jax.random.key(0)))
        p_spec = self._shardings(cfg, rules)
        params = with_sharding(p_struct, p_spec, mesh)

        if shape == "retrieval_cand" and self.arch_id == "two-tower-retrieval":
            return self._build_retrieval(s, mesh, rules, params, p_struct)

        batch_size = s["batch"] if shape != "retrieval_cand" else s["n_candidates"]
        batch_struct, batch_spec = self._batch(cfg, batch_size)
        batch = with_sharding(batch_struct, batch_spec(rules), mesh)

        if s["kind"] == "train":
            o_struct = jax.eval_shape(init_opt_state, p_struct)
            opt = with_sharding(
                o_struct,
                OptState(step=rules.spec(), m=p_spec, v=jax.tree.map(lambda x: x, p_spec)),
                mesh,
            )
            ocfg = AdamWConfig()
            loss_fn = self._loss

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg, rules)
                params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
                return params, opt_state, {"loss": loss, **metrics}

            return LoweredSpec(fn=train_step, args=(params, opt, batch),
                               donate_argnums=(0, 1),
                               static_desc=f"{self.arch_id}/{shape}")

        fwd = self._fwd

        def serve_step(params, batch):
            return fwd(params, batch, cfg, rules)

        return LoweredSpec(fn=serve_step, args=(params, batch),
                           static_desc=f"{self.arch_id}/{shape}")

    def _build_retrieval(self, s, mesh, rules, params, p_struct) -> LoweredSpec:
        """Two-tower retrieval_cand: the paper's Phase-2 on 1M candidates."""
        cfg = self.cfg
        shards = max(rules.size_of("candidates"), 1)
        N = (s["n_candidates"] + shards - 1) // shards * shards  # pad to shard
        D = cfg.tower_mlp[-1]
        batch_struct = {
            "user_id": jax.ShapeDtypeStruct((1,), jnp.int32),
            "hist": jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.int32),
        }
        bspec = {"user_id": rules.spec(None), "hist": rules.spec(None, None)}
        batch = with_sharding(batch_struct, bspec, mesh)
        cand = with_sharding(
            jax.ShapeDtypeStruct((N, D), jnp.float32),
            rules.spec("candidates", None), mesh)
        days = with_sharding(
            jax.ShapeDtypeStruct((N,), jnp.float32), rules.spec("candidates"), mesh)
        pool, over = 500, 1500

        def retrieval_step(params, batch, cand, days):
            # PEM fixed order on candidate scores: similarity -> decay -> MMR
            scores = R.retrieval_scores(params, batch, cand, cfg, rules)  # (N, B)
            scores = scores * (1.0 / (1.0 + days / 30.0))[:, None]        # decay:30
            v, i = jax.lax.top_k(scores.T, over)                          # (B, over)
            emb = jnp.take(cand, i, axis=0)                               # (B, over, D)
            sel, mmr_scores = mmr_ref(emb, v, pool)                       # diverse
            final_idx = jnp.take_along_axis(i, sel, axis=1)
            final_scores = jnp.take_along_axis(v, sel, axis=1)
            return final_idx, final_scores

        return LoweredSpec(fn=retrieval_step, args=(params, batch, cand, days),
                           static_desc=f"{self.arch_id}/retrieval_cand")

    def smoke_run(self) -> Dict[str, Any]:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules(mesh)
        cfg = self.smoke_cfg
        with mesh:
            params = self._init(cfg, jax.random.key(0))
            batch_struct, _ = self._batch(cfg, 16)
            data = _smoke_data(self.arch_id, cfg, 16)
            loss, grads = jax.value_and_grad(self._loss)(params, data, cfg, rules)
            fwd_out = self._fwd(params, data, cfg, rules)
        return {
            "loss": float(loss),
            "grad_finite": all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(grads)),
            "fwd_shape": tuple(jnp.asarray(fwd_out).shape),
        }


def _flops_per_example(arch_id: str, cfg) -> float:
    """Analytic forward FLOPs per example (matmul-dominated terms)."""
    def mlp_flops(dims):
        return sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))

    if arch_id == "dlrm-mlperf":
        n_int = cfg.n_sparse + 1
        inter = 2.0 * n_int * n_int * cfg.embed_dim
        d_inter = n_int * (n_int - 1) // 2
        return (mlp_flops((cfg.n_dense,) + cfg.bot_mlp)
                + inter
                + mlp_flops((cfg.bot_mlp[-1] + d_inter,) + cfg.top_mlp))
    if arch_id == "bst":
        S, D = cfg.seq_len + 1, cfg.embed_dim
        attn = cfg.n_blocks * (4 * 2.0 * S * D * D + 2 * 2.0 * S * S * D
                               + 2.0 * S * D * cfg.d_ff * 2)
        return attn + mlp_flops((S * D + cfg.n_other_feats,) + cfg.mlp_dims)
    if arch_id == "autoint":
        F = cfg.n_fields
        d_in, total = cfg.embed_dim, 0.0
        for _ in range(cfg.n_attn_layers):
            d_out = cfg.n_heads * cfg.d_attn
            total += 4 * 2.0 * F * d_in * d_out + 2 * 2.0 * F * F * d_out
            d_in = d_out
        return total + 2.0 * F * d_in
    if arch_id == "two-tower-retrieval":
        # retrieval path: item tower per candidate + dot
        return (mlp_flops((cfg.embed_dim,) + cfg.tower_mlp)
                + 2.0 * cfg.tower_mlp[-1])
    raise KeyError(arch_id)


def _smoke_data(arch_id: str, cfg, b: int):
    if arch_id == "dlrm-mlperf":
        return {k: jnp.asarray(v) for k, v in RD.dlrm_batch(b, cfg.n_dense, cfg.vocab_sizes).items()}
    if arch_id == "bst":
        return {k: jnp.asarray(v) for k, v in
                RD.bst_batch(b, cfg.seq_len, cfg.vocab_items, cfg.n_other_feats).items()}
    if arch_id == "autoint":
        return {k: jnp.asarray(v) for k, v in
                RD.autoint_batch(b, cfg.n_fields, cfg.vocab_per_field).items()}
    if arch_id == "two-tower-retrieval":
        return {k: jnp.asarray(v) for k, v in
                RD.twotower_batch(b, cfg.vocab_user, cfg.vocab_item, cfg.hist_len).items()}
    raise KeyError(arch_id)


# ---------------------------------------------------------------------------
# batch-spec builders (struct, specs) per model
# ---------------------------------------------------------------------------


def _dlrm_batch(cfg: R.DLRMConfig, b: int):
    struct = {
        "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
        "sparse": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    return struct, lambda r: {
        "dense": r.spec("batch", None),
        "sparse": r.spec("batch", None),
        "labels": r.spec("batch"),
    }


def _bst_batch(cfg: R.BSTConfig, b: int):
    struct = {
        "hist": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
        "target": jax.ShapeDtypeStruct((b,), jnp.int32),
        "other": jax.ShapeDtypeStruct((b, cfg.n_other_feats), jnp.float32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    return struct, lambda r: {
        "hist": r.spec("batch", None),
        "target": r.spec("batch"),
        "other": r.spec("batch", None),
        "labels": r.spec("batch"),
    }


def _autoint_batch(cfg: R.AutoIntConfig, b: int):
    struct = {
        "sparse": jax.ShapeDtypeStruct((b, cfg.n_fields), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    return struct, lambda r: {
        "sparse": r.spec("batch", None),
        "labels": r.spec("batch"),
    }


def _twotower_batch(cfg: R.TwoTowerConfig, b: int):
    struct = {
        "user_id": jax.ShapeDtypeStruct((b,), jnp.int32),
        "hist": jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.int32),
        "pos_item": jax.ShapeDtypeStruct((b,), jnp.int32),
        "logq": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    return struct, lambda r: {
        "user_id": r.spec("batch"),
        "hist": r.spec("batch", None),
        "pos_item": r.spec("batch"),
        "logq": r.spec("batch"),
    }


def _dlrm_shardings(cfg: R.DLRMConfig, rules: ShardingRules):
    return R.dlrm_shardings(cfg, rules)


def _bst_shardings(cfg: R.BSTConfig, rules: ShardingRules):
    p_struct = jax.eval_shape(lambda: R.bst_init(cfg, jax.random.key(0)))
    spec = jax.tree.map(lambda _: rules.spec(), p_struct)
    spec["item_table"] = rules.spec("table_rows", None)
    return spec


def _autoint_shardings(cfg: R.AutoIntConfig, rules: ShardingRules):
    p_struct = jax.eval_shape(lambda: R.autoint_init(cfg, jax.random.key(0)))
    spec = jax.tree.map(lambda _: rules.spec(), p_struct)
    spec["table"] = rules.spec("table_rows", None)
    return spec


def _twotower_shardings(cfg: R.TwoTowerConfig, rules: ShardingRules):
    p_struct = jax.eval_shape(lambda: R.twotower_init(cfg, jax.random.key(0)))
    spec = jax.tree.map(lambda _: rules.spec(), p_struct)
    spec["user_table"] = rules.spec("table_rows", None)
    spec["item_table"] = rules.spec("table_rows", None)
    return spec


# ---------------------------------------------------------------------------
# The four archs (published configs)
# ---------------------------------------------------------------------------

_dlrm_cfg = R.DLRMConfig(
    name="dlrm-mlperf", n_dense=13, embed_dim=128,
    vocab_sizes=CRITEO_1TB_VOCAB_SIZES,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
)
_dlrm_smoke = dataclasses.replace(
    _dlrm_cfg, name="dlrm-smoke",
    vocab_sizes=tuple(min(v, 50) for v in CRITEO_1TB_VOCAB_SIZES),
    bot_mlp=(32, 16), top_mlp=(32, 16, 1), embed_dim=16,
)

_bst_cfg = R.BSTConfig(
    name="bst", vocab_items=2_097_152, embed_dim=32, seq_len=20,
    n_blocks=1, n_heads=8, d_ff=128, mlp_dims=(1024, 512, 256, 1),
)
_bst_smoke = dataclasses.replace(
    _bst_cfg, name="bst-smoke", vocab_items=500, seq_len=8,
    mlp_dims=(32, 16, 1), d_ff=32,
)

_autoint_cfg = R.AutoIntConfig(
    name="autoint", n_fields=39, vocab_per_field=131_072, embed_dim=16,
    n_attn_layers=3, n_heads=2, d_attn=32,
)
_autoint_smoke = dataclasses.replace(
    _autoint_cfg, name="autoint-smoke", n_fields=8, vocab_per_field=50,
)

_twotower_cfg = R.TwoTowerConfig(
    name="two-tower-retrieval", vocab_user=4_194_304, vocab_item=8_388_608,
    hist_len=20, embed_dim=256, tower_mlp=(1024, 512, 256),
)
_twotower_smoke = dataclasses.replace(
    _twotower_cfg, name="twotower-smoke", vocab_user=300, vocab_item=500,
    hist_len=8, embed_dim=32, tower_mlp=(64, 32),
)

RECSYS_ARCHS = [
    RecsysArch("dlrm-mlperf", "arXiv:1906.00091; MLPerf Criteo 1TB",
               _dlrm_cfg, R.dlrm_init, R.dlrm_loss, R.dlrm_forward,
               _dlrm_batch, _dlrm_shardings, _dlrm_smoke),
    RecsysArch("bst", "arXiv:1905.06874 (Alibaba)",
               _bst_cfg, R.bst_init, R.bst_loss, R.bst_forward,
               _bst_batch, _bst_shardings, _bst_smoke),
    RecsysArch("autoint", "arXiv:1810.11921",
               _autoint_cfg, R.autoint_init, R.autoint_loss, R.autoint_forward,
               _autoint_batch, _autoint_shardings, _autoint_smoke),
    RecsysArch("two-tower-retrieval", "Yi et al. RecSys'19 (YouTube)",
               _twotower_cfg, R.twotower_init, R.twotower_loss,
               lambda p, b, c, r: R.user_tower(p, b, c, r),
               _twotower_batch, _twotower_shardings, _twotower_smoke),
]
