from repro.embed.hashing import HashEmbedder

__all__ = ["HashEmbedder"]
