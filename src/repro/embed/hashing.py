"""Deterministic hash-projection embedder — offline stand-in for Nomic Embed.

The paper embeds with nomic-embed-text-v1.5 truncated to 128 dims
(Matryoshka).  That model is unavailable offline, so the framework ships a
deterministic embedder with the properties the paper's algebra and behavioral
suites actually rely on:

* fixed-length L2-normalized vectors,
* Matryoshka-style truncation (any prefix of dims is a valid embedding),
* token overlap => higher cosine similarity (bag of hashed token vectors),
* full determinism across processes (blake2b-seeded Gaussian directions).

DESIGN.md records this as a changed assumption: algebraic correctness is
embedder-independent; behavioral metrics are validated in direction/band.
"""

from __future__ import annotations

import hashlib
import re
from functools import lru_cache
from typing import List, Sequence

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+")
_FULL_DIM = 256  # pre-truncation dimension (Matryoshka parent space)


def _token_seed(token: str, salt: str) -> int:
    digest = hashlib.blake2b(
        f"{salt}\x00{token}".encode("utf-8"), digest_size=8
    ).digest()
    return int.from_bytes(digest, "little")


@lru_cache(maxsize=1 << 16)
def _token_vector(token: str, salt: str, full_dim: int) -> np.ndarray:
    rng = np.random.Generator(np.random.PCG64(_token_seed(token, salt)))
    v = rng.standard_normal(full_dim).astype(np.float32)
    # Matryoshka-style importance taper: earlier dims carry more signal, so
    # truncation keeps most of the norm (mirrors MRL training incentives).
    taper = (1.0 / np.sqrt(1.0 + np.arange(full_dim) / 64.0)).astype(np.float32)
    return v * taper


class HashEmbedder:
    """text -> (dim,) float32 unit vector. Callable; batch via embed_batch."""

    def __init__(self, dim: int = 128, salt: str = "flexvec", full_dim: int = _FULL_DIM):
        if dim > full_dim:
            raise ValueError(f"dim {dim} exceeds parent space {full_dim}")
        self.dim = dim
        self.salt = salt
        self.full_dim = full_dim

    def tokens(self, text: str) -> List[str]:
        return _TOKEN_RE.findall(text.lower())

    def embed_full(self, text: str) -> np.ndarray:
        toks = self.tokens(text)
        if not toks:
            return np.zeros(self.full_dim, dtype=np.float32)
        acc = np.zeros(self.full_dim, dtype=np.float32)
        for t in toks:
            acc += _token_vector(t, self.salt, self.full_dim)
        return acc

    def __call__(self, text: str) -> np.ndarray:
        return self.truncate(self.embed_full(text), self.dim)

    def embed_batch(self, texts: Sequence[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), dtype=np.float32)
        for i, t in enumerate(texts):
            out[i] = self(t)
        return out

    @staticmethod
    def truncate(full: np.ndarray, dim: int) -> np.ndarray:
        """Matryoshka truncation: take a prefix, renormalize."""
        v = np.asarray(full, dtype=np.float32)[..., :dim]
        nrm = np.sqrt((v * v).sum(axis=-1, keepdims=True))
        return np.where(nrm > 1e-12, v / np.maximum(nrm, 1e-12), v)
