"""Ranking metrics used by the behavioral suite (paper §4.4).

RBO  — Rank-Biased Overlap [Webber et al., TOIS 2010], extrapolated form.
ILS  — Intra-List Similarity: mean pairwise cosine among top-K results.
nDCG — standard graded formulation, log2 discount.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def rbo(list_a: Sequence[int], list_b: Sequence[int], p: float = 0.9) -> float:
    """Extrapolated RBO (eq. 32 of Webber et al.) for two finite rankings."""
    a, b = list(list_a), list(list_b)
    k = min(len(a), len(b))
    if k == 0:
        return 1.0
    seen_a, seen_b = set(), set()
    overlap = 0
    summand = 0.0
    x_k = 0
    for d in range(1, k + 1):
        ai, bi = a[d - 1], b[d - 1]
        if ai == bi:
            overlap += 1
        else:
            if ai in seen_b:
                overlap += 1
            if bi in seen_a:
                overlap += 1
        seen_a.add(ai)
        seen_b.add(bi)
        x_k = overlap
        summand += (overlap / d) * (p ** d)
    rbo_min = (1 - p) / p * summand
    # extrapolation term: assume agreement continues at depth-k rate
    return float(rbo_min + (x_k / k) * (p ** k))


def ils(embeds: np.ndarray) -> float:
    """Mean pairwise cosine among a result list's embeddings (K, d)."""
    e = np.asarray(embeds, np.float32)
    e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-9)
    sim = e @ e.T
    k = sim.shape[0]
    if k < 2:
        return 0.0
    off = sim[np.triu_indices(k, 1)]
    return float(off.mean())


def ndcg_at_k(ranked_ids: Sequence[int], qrels: Dict[int, int], k: int = 10) -> float:
    gains = [qrels.get(int(d), 0) for d in list(ranked_ids)[:k]]
    dcg = sum((2 ** g - 1) / np.log2(i + 2) for i, g in enumerate(gains))
    ideal = sorted(qrels.values(), reverse=True)[:k]
    idcg = sum((2 ** g - 1) / np.log2(i + 2) for i, g in enumerate(ideal))
    return float(dcg / idcg) if idcg > 0 else 0.0


def centroid_similarity(result_embeds: np.ndarray, seed_embeds: np.ndarray) -> float:
    """Mean cosine(result, centroid(seeds)) — the paper's centroid metric."""
    c = np.asarray(seed_embeds, np.float32).mean(axis=0)
    c = c / max(np.linalg.norm(c), 1e-9)
    e = np.asarray(result_embeds, np.float32)
    e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-9)
    return float((e @ c).mean())
