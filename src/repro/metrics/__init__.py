from repro.metrics.ranking import rbo, ils, ndcg_at_k, centroid_similarity

__all__ = ["rbo", "ils", "ndcg_at_k", "centroid_similarity"]
